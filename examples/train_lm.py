"""End-to-end driver (deliverable b): train a ~100M-parameter dense LM
with the full production stack — Model + ShardedDasha (compressed,
partially-participating aggregation) + server optimizer + data pipeline
+ checkpointing — for a few hundred steps.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_lm.py --steps 300

On CPU this uses a 4x2 host mesh (4 nodes x 2-way model parallel); the
same script runs unchanged on a TPU pod with the production mesh.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--p-a", type=float, default=0.5)
    ap.add_argument("--ratio", type=float, default=1 / 32)
    ap.add_argument("--ckpt", default="results/train_lm_ckpt")
    ap.add_argument("--log", default="results/train_lm")
    args = ap.parse_args()

    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro.compat import use_mesh
    from repro.core.sharded import ShardedDashaConfig
    from repro.data.synthetic import DataConfig, make_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models import ArchConfig, Model, count_params
    from repro.training.loop import train
    from repro.training.metrics import MetricsLogger
    from repro.training.optim import adamw_server
    from repro.training.trainer import Trainer, TrainerConfig

    mesh = make_host_mesh(data=4, model=2)
    cfg = ArchConfig(
        name="lm-100m", arch_type="dense",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=4, d_ff=4 * args.d_model,
        vocab_size=args.vocab, dtype="float32", remat=False,
        scan_layers=False)
    model = Model(cfg)
    n_params = count_params(jax.eval_shape(model.init_params,
                                           jax.random.key(0)))
    print(f"model: {n_params/1e6:.1f}M params; mesh {dict(mesh.shape)}")

    omega = 1.0 / args.ratio - 1.0
    dcfg = ShardedDashaConfig(
        gamma=0.0,                      # server step comes from AdamW below
        a=args.p_a / (2 * omega + 1),   # theory momenta
        b=args.p_a / (2 - args.p_a),
        p_a=args.p_a, sampler="independent",
        compression_ratio=args.ratio, block_size=128,
        aggregation="sparse_allgather", data_axes=("data",))
    trainer = Trainer(model, mesh, TrainerConfig(
        dasha=dcfg, server=adamw_server(lr=3e-4, warmup=50)))
    state = trainer.init(jax.random.key(0))

    data = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                      num_nodes=4, vocab_size=args.vocab, zipf_a=1.4)

    def batches():
        step = 0
        while True:
            yield make_batch(cfg, data, step, dtype="float32")
            step += 1

    with use_mesh(mesh):
        state = train(trainer, state, batches(), num_steps=args.steps,
                      logger=MetricsLogger(args.log, print_every=20),
                      checkpoint_dir=args.ckpt,
                      checkpoint_every=max(50, args.steps // 4),
                      log_every=20)
    print("done; uplink per node per round:",
          f"{trainer.engine.uplink_bits_per_round(n_params)/8/1e6:.2f} MB",
          f"(dense would be {n_params*4/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
