"""Quickstart: DASHA-PP on a 100-node federated logistic regression in
~40 lines (the paper's §A setting, shrunk to run in seconds on CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LogisticSigmoidProblem, RandK, SNice, dasha_pp_page,
                        make_synthetic_classification, theory)

# --- a federated problem: n nodes, each holding its own data shard ----
n_nodes, m_per_node, d = 50, 24, 120
feats, labels = make_synthetic_classification(
    jax.random.key(0), n_nodes, m_per_node, d)
problem = LogisticSigmoidProblem(feats, labels)

# --- DASHA-PP-PAGE: compression + partial participation + VR ----------
compressor = RandK(k=d // 20)                  # each node uploads 5% of d
sampler = SNice(n=n_nodes, s=10)               # 20% of nodes per round
L, L_hat, L_max, L_sigma = problem.smoothness()
consts = theory.ProblemConstants(L=L, L_hat=L_hat, L_max=L_max,
                                 L_sigma=L_sigma, n=n_nodes,
                                 m=m_per_node, d=d)
hp = theory.dasha_pp_page(consts, compressor.omega(d), sampler.p_a,
                          sampler.p_aa, batch_size=2)
algo = dasha_pp_page(problem, compressor, sampler,
                     gamma=hp.gamma * 512,     # theory gamma, finetuned over {2^i}
                     a=hp.a, b=hp.b, p_page=hp.p_page, batch_size=2)

# --- run ---------------------------------------------------------------
state, metrics = jax.jit(
    lambda key: algo.run(key, jnp.zeros(d), num_rounds=1500))(
        jax.random.key(1))

g = np.asarray(metrics.grad_norm_sq)
bits = float(np.sum(np.asarray(metrics.bits_sent))) / n_nodes / 1e6
print(f"rounds:            1500")
print(f"||grad f||^2:      {g[0]:.3e} -> {g[-1]:.3e}")
print(f"uplink per node:   {bits:.2f} Mbit "
      f"(vs {1500 * 32 * d * sampler.p_a / 1e6:.2f} Mbit uncompressed)")
assert g[-1] < 1e-2 * g[0], "did not converge"
print("OK: compressed, partially-participating, variance-reduced training")
