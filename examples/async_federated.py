"""Async federated DASHA-PP: time-to-accuracy instead of
rounds-to-accuracy (DESIGN.md §9).

A heterogeneous fleet (lognormal compute, bandwidth-proportional
uplink, dropouts) runs DASHA-PP-MVR under three server policies:

* full barrier            — wait for the whole sampled cohort,
* buffered first-K        — commit the first K arrivals per step,
* buffered + dropouts     — same, with 10% of jobs lost and rejoining.

Same dispatch budget everywhere; what changes is how long the virtual
clock says it took and how stale the committed work is.

    PYTHONPATH=src python examples/async_federated.py [--smoke]

Writes trajectories + staleness histograms to results/async/.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LogisticSigmoidProblem, RandK, SNice,
                        make_synthetic_classification)
from repro.core.dasha_pp import DashaPPConfig
from repro.fl import AsyncConfig, AsyncDashaServer, LognormalLatency


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="few rounds (CI)")
    ap.add_argument("--out", default="results/async")
    args = ap.parse_args()

    n, m, d = 20, 12, 60
    rounds = 60 if args.smoke else 600
    feats, y = make_synthetic_classification(jax.random.key(0), n, m, d)
    prob = LogisticSigmoidProblem(feats, y)
    comp = RandK(k=d // 20)
    samp = SNice(n=n, s=10)                 # 50% cohort per round
    cfg = DashaPPConfig("mvr", gamma=0.05, a=0.1, b=0.3, batch_size=2)
    lat = lambda drop: LognormalLatency(
        compute_s=1.0, sigma=0.8, client_sigma=0.8,
        bandwidth_bps=2e5, bandwidth_sigma=0.4,
        dropout=drop, rejoin_s=4.0, seed=11)

    policies = {
        "barrier": (AsyncConfig(buffer_size=None), lat(0.0)),
        "first-5": (AsyncConfig(buffer_size=5), lat(0.0)),
        "first-5+dropout": (AsyncConfig(buffer_size=5, max_staleness=20),
                            lat(0.10)),
    }
    os.makedirs(args.out, exist_ok=True)
    results, t_barrier = {}, None
    for name, (acfg, latency) in policies.items():
        srv = AsyncDashaServer(prob, comp, samp, cfg, acfg, latency)
        _, res = srv.run(jax.random.key(1), jnp.zeros(d), rounds)
        if name == "barrier":
            t_barrier = res.total_time
        results[name] = {
            "t_virtual": res.total_time,
            "speedup_vs_barrier": t_barrier / res.total_time,
            "final_gnorm_sq": float(np.median(
                res.grad_norm_sq[-max(1, rounds // 10):])),
            "staleness_hist": {str(k): v
                               for k, v in res.staleness_hist.items()},
            "utilization_mean": float(np.mean(res.utilization)),
            "dropped": res.dropped,
            "mbits_on_wire": res.bits_cum[-1] / 1e6,
            "time": res.time[:: max(1, rounds // 100)].tolist(),
            "grad_norm_sq": res.grad_norm_sq[
                :: max(1, rounds // 100)].tolist(),
        }
        r = results[name]
        print(f"{name:16s} t={r['t_virtual']:8.1f}s "
              f"({r['speedup_vs_barrier']:.2f}x)  "
              f"gnorm^2={r['final_gnorm_sq']:.3e}  "
              f"util={r['utilization_mean']:.2f}  "
              f"dropped={r['dropped']}  "
              f"stale[s>0]={sum(v for k, v in res.staleness_hist.items() if k > 0)}")

    assert results["first-5"]["speedup_vs_barrier"] > 1.0, \
        "buffered first-K should beat the barrier on this fleet"
    with open(os.path.join(args.out, "async_federated.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}/async_federated.json")
    print("OK: same dispatch budget, wall-clock set by the K-th "
          "arrival, not the slowest straggler")


if __name__ == "__main__":
    main()
