"""Paper §A reproduction driver: DASHA-PP vs DASHA vs MARINA vs FRECON
on the synthetic federated classification problem, across participation
levels — the experiment behind Figures 1-5.

    PYTHONPATH=src python examples/federated_logreg.py [--full]

``--full`` uses n=100 nodes / paper-scale rounds (minutes on CPU);
default is a fast shrunk run with identical qualitative behaviour.
Writes per-method gradient-norm trajectories to results/federated/.
"""
import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

# repo root (for benchmarks.common) — the example lives in examples/
sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import (constants_of, gamma_grid_around,  # noqa: E402
                               make_paper_problem, run_method)
from repro.core import (Frecon, FreconConfig, Marina, MarinaConfig, RandK,
                        SNice, dasha_page, dasha_pp_page, theory)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="results/federated")
    args = ap.parse_args()
    quick = not args.full

    n = 100 if args.full else 20
    rounds = 3000 if args.full else 700
    prob = make_paper_problem(setting="finite_sum", n=n,
                              m=36 if args.full else 12,
                              d=300 if args.full else 60)
    c = constants_of(prob)
    comp = RandK(k=max(1, prob.d // 20))
    omega = comp.omega(prob.d)
    x0 = jnp.zeros(prob.d)
    key = jax.random.key(7)
    os.makedirs(args.out, exist_ok=True)

    results = {}
    for frac in ((0.01, 0.1, 0.9) if args.full else (0.25, 0.75)):
        s = max(1, int(round(frac * prob.n)))
        samp = SNice(n=prob.n, s=s)
        hp = theory.dasha_pp_page(c, omega, samp.p_a, samp.p_aa, 1)
        grid = gamma_grid_around(hp.gamma)
        entries = {
            "dasha-pp": lambda g, _s=samp, _h=hp: dasha_pp_page(
                prob, comp, _s, gamma=g, a=_h.a, b=_h.b,
                p_page=_h.p_page, batch_size=1),
            "marina": lambda g, _s=samp: Marina(
                prob, comp, _s,
                MarinaConfig(gamma=g, p_sync=1 / (1 + omega))),
            "frecon": lambda g, _s=samp: Frecon(
                prob, comp, _s, FreconConfig(gamma=g, batch_size=1)),
        }
        # full-participation DASHA reference
        hp_full = theory.dasha_pp_page(c, omega, 1.0, 1.0, 1)
        entries["dasha(full)"] = lambda g, _h=hp_full: dasha_page(
            prob, comp, gamma=g, a=_h.a, b=_h.b, p_page=_h.p_page,
            batch_size=1)

        for name, mk in entries.items():
            res = run_method(mk, key, x0, rounds, gamma_grid=grid,
                             n_nodes=prob.n)
            results[f"{name}@pa={frac}"] = {
                "gamma": res.gamma,
                "grad_norm_sq": np.asarray(res.grad_norm_sq)[
                    :: max(1, rounds // 200)].tolist(),
                "final": float(np.median(res.grad_norm_sq[-30:])),
            }
            print(f"pa={frac:4} {name:12s} final gnorm^2 = "
                  f"{results[f'{name}@pa={frac}']['final']:.3e} "
                  f"(gamma={res.gamma:.2e})")

    with open(os.path.join(args.out, "figs_1_to_5.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}/figs_1_to_5.json")


if __name__ == "__main__":
    main()
