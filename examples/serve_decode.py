"""Serving example: batched greedy decoding with KV-cache ring buffers
through the DecodeServer (continuous-batching inner loop).

    PYTHONPATH=src python examples/serve_decode.py [--arch xlstm-350m]

Uses the reduced smoke config of the chosen architecture so it runs on
CPU; the same serve_step is what the decode dry-run shapes lower on the
production mesh.
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.models import Model, get_smoke_config
    from repro.serving.decode import DecodeServer, Request

    cfg = get_smoke_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    server = DecodeServer(model, params, batch_size=args.batch,
                          max_seq_len=64)

    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
                max_new_tokens=args.new_tokens)
        for i in range(args.batch * 2)]
    t0 = time.time()
    done = server.run(requests)
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    for r in done[:4]:
        print(f"req {r.uid}: prompt={r.prompt} -> {r.generated}")
    print(f"\n{total} tokens across {len(done)} requests in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU, batch={args.batch})")


if __name__ == "__main__":
    main()
