"""Serving example: batched greedy decoding on CPU through either
engine —

    PYTHONPATH=src python examples/serve_decode.py [--arch granite-3-2b]
        [--engine {dense,paged}] [--page-size 8]

``dense``: the ring-cache DecodeServer (token-by-token prefill).
``paged``: the PagedEngine (DESIGN.md §11) — shared page pool, ONE bulk
prefill forward per prompt, continuous batching with preemption, and
per-request p50/p95 latency / time-to-first-token reporting.

Uses the reduced smoke config of the chosen architecture so it runs on
CPU; the same serve steps are what the decode dry-run shapes lower on
the production mesh.
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--engine", choices=("dense", "paged"), default="paged")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=0,
                    help="pool pages (0 = dense-equivalent capacity)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.models import Model, get_smoke_config
    from repro.serving import DecodeServer, PagedEngine, Request

    cfg = get_smoke_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    if args.engine == "dense":
        server = DecodeServer(model, params, batch_size=args.batch,
                              max_seq_len=64)
    else:
        server = PagedEngine(model, params, batch_size=args.batch,
                             max_seq_len=64, page_size=args.page_size,
                             num_pages=args.pages or None)

    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
                max_new_tokens=args.new_tokens)
        for i in range(args.batch * 2)]
    t0 = time.time()
    done = server.run(requests)
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    for r in done[:4]:
        print(f"req {r.uid}: prompt={r.prompt} -> {r.generated}")
    print(f"\n{total} tokens across {len(done)} requests in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU, engine={args.engine}, "
          f"batch={args.batch})")
    if args.engine == "paged":
        m = server.metrics()
        print(f"prefill: {m['prefill_forwards']} prompt-ingesting passes "
              f"(dense would take {sum(len(r.prompt) or 1 for r in done)} "
              f"token-by-token serve steps)")
        print(f"pool: {m['pool']['allocs']} allocs, "
              f"{m['pool']['prefix_hits']} prefix hits, "
              f"{m['pool']['cow_copies']} COW copies, "
              f"peak {m['pool']['peak_in_use']}/{server.num_pages} pages, "
              f"{m['cache_hbm_bytes']} cache bytes")
        if m["latency_p50"] is not None:
            print(f"latency (serve-passes): p50={m['latency_p50']:.0f} "
                  f"p95={m['latency_p95']:.0f}; "
                  f"ttft p50={m['ttft_p50']:.0f} p95={m['ttft_p95']:.0f}")


if __name__ == "__main__":
    main()
