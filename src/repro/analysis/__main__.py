"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (or everything suppressed/baselined), 1 open
findings, 2 usage/baseline errors.  ``--json`` writes the findings
artifact that ``python -m repro.obs.validate --analysis`` schema-checks
and CI archives.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis import (Baseline, BaselineError, default_checkers,
                            run)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jax/Pallas contract linter for this repo's own "
                    "bug classes (see DESIGN.md §14)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files/directories to scan (default: src)")
    parser.add_argument("--baseline", default="analysis_baseline.json",
                        help="committed debt ledger (default: "
                             "%(default)s; missing file = empty)")
    parser.add_argument("--json", dest="json_out", metavar="FILE",
                        help="write the findings artifact (use '-' for "
                             "stdout)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="write current open findings to the "
                             "baseline as a skeleton (justifications "
                             "must then be filled in by hand)")
    parser.add_argument("--select", action="append", metavar="ID",
                        help="run only this checker id (repeatable)")
    parser.add_argument("--list", action="store_true",
                        help="list checker ids and exit")
    args = parser.parse_args(argv)

    checkers = default_checkers()
    if args.list:
        for c in checkers:
            print(f"{c.id:18s} [{c.severity}] {c.description}")
        return 0

    if args.select:
        known = {c.id for c in checkers}
        bad = [s for s in args.select if s not in known]
        if bad:
            print(f"unknown checker id(s): {', '.join(bad)}",
                  file=sys.stderr)
            return 2

    try:
        baseline = Baseline.load(args.baseline)
    except BaselineError as e:
        print(f"baseline error: {e}", file=sys.stderr)
        return 2

    try:
        result = run(args.paths, checkers, baseline=baseline,
                     select=args.select)
    except FileNotFoundError as e:
        print(f"no such path: {e}", file=sys.stderr)
        return 2

    if args.update_baseline:
        Baseline.write(args.baseline, result.all_findings)
        print(f"wrote {len(result.all_findings)} entr"
              f"{'y' if len(result.all_findings) == 1 else 'ies'} to "
              f"{args.baseline}; fill in each 'justification'")
        return 0

    if args.json_out:
        doc = json.dumps(result.to_json(args.paths), indent=2)
        if args.json_out == "-":
            print(doc)
        else:
            with open(args.json_out, "w") as fh:
                fh.write(doc + "\n")

    for f in result.all_findings:
        print(f.render())
    s = result.to_json(args.paths)["summary"]
    print(f"{s['files']} files: {s['open']} open "
          f"({s['errors']} error / {s['warnings']} warn), "
          f"{s['suppressed']} suppressed, {s['baselined']} baselined",
          file=sys.stderr)
    return 1 if result.all_findings else 0


if __name__ == "__main__":
    sys.exit(main())
