"""Shared AST machinery for the contract linter (DESIGN.md §14).

Everything here is stdlib-``ast`` only, mirroring ``repro.obs``'s
zero-dependency discipline.  Three layers:

* :class:`ImportMap` — canonicalizes dotted references through the
  module's import aliases, so a checker matches ``jax.numpy.asarray``
  whether the file spelled it ``jnp.asarray``, ``jax.numpy.asarray``
  or ``from jax import numpy``.  This is what lets the checkers be
  written against *semantic* names instead of surface spellings.
* :class:`FunctionIndex` + :func:`set_parents` — function/method
  discovery with qualified names and upward links, the skeleton every
  scope-based checker walks.
* :func:`safe_eval` + :func:`module_constants` — a tiny static
  evaluator for the constant arithmetic the ``pallas-contract``
  checker needs (tile shapes like ``(block_rows, LANES)``, budgets
  like ``4 << 20``).  Anything it cannot prove evaluates to
  :data:`UNKNOWN` rather than guessing.
"""
from __future__ import annotations

import ast
from typing import Any, Dict, Iterator, List, Optional, Tuple, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]


class _Unknown:
    """Sentinel for statically-unresolvable values (repr aids messages)."""

    _instance: Optional["_Unknown"] = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<?>"


UNKNOWN = _Unknown()


def is_known(value: Any) -> bool:
    return value is not UNKNOWN


# ----------------------------------------------------------------------
# Parent links
# ----------------------------------------------------------------------

def set_parents(tree: ast.AST) -> None:
    """Attach ``._parent`` to every node (idempotent)."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_parent", None)


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


def enclosing_function(node: ast.AST) -> Optional[FunctionNode]:
    for anc in ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def enclosing_loop(node: ast.AST,
                   within: Optional[ast.AST] = None
                   ) -> Optional[Union[ast.For, ast.While]]:
    """Nearest For/While ancestor, stopping at ``within`` (exclusive) —
    pass the enclosing function so loops outside it don't count."""
    for anc in ancestors(node):
        if anc is within:
            return None
        if isinstance(anc, (ast.For, ast.While)):
            return anc
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return None
    return None


# ----------------------------------------------------------------------
# Import canonicalization
# ----------------------------------------------------------------------

class ImportMap:
    """Maps local aliases to canonical dotted module paths."""

    def __init__(self, tree: ast.Module):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    local = a.asname or a.name.split(".")[0]
                    target = a.name if a.asname else a.name.split(".")[0]
                    self.aliases[local] = target
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name == "*":
                        continue
                    local = a.asname or a.name
                    self.aliases[local] = f"{node.module}.{a.name}"

    def dotted(self, node: ast.AST) -> Optional[str]:
        """Raw dotted path of a Name/Attribute chain (no alias expansion);
        ``self.foo`` stays ``self.foo``; anything else (calls, subscripts)
        is None."""
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if isinstance(cur, ast.Name):
            parts.append(cur.id)
            return ".".join(reversed(parts))
        return None

    def canonical(self, node: ast.AST) -> Optional[str]:
        """Alias-expanded dotted path: with ``import jax.numpy as jnp``,
        ``jnp.asarray`` -> ``jax.numpy.asarray``."""
        raw = self.dotted(node)
        if raw is None:
            return None
        head, _, rest = raw.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    def call_name(self, call: ast.Call) -> Optional[str]:
        return self.canonical(call.func)


# ----------------------------------------------------------------------
# Function index
# ----------------------------------------------------------------------

class FunctionIndex:
    """All functions/methods of a module with dotted qualnames
    (``Class.method``, ``outer.<locals>.inner``)."""

    def __init__(self, tree: ast.Module):
        self.by_qualname: Dict[str, FunctionNode] = {}
        self.qualname_of: Dict[FunctionNode, str] = {}
        self.class_of: Dict[FunctionNode, Optional[str]] = {}
        self._walk(tree.body, prefix="", cls=None)

    def _walk(self, body: List[ast.stmt], prefix: str,
              cls: Optional[str]) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{node.name}"
                self.by_qualname[qn] = node
                self.qualname_of[node] = qn
                self.class_of[node] = cls
                self._walk(node.body, prefix=f"{qn}.<locals>.", cls=cls)
            elif isinstance(node, ast.ClassDef):
                self._walk(node.body, prefix=f"{node.name}.",
                           cls=node.name)

    def functions(self) -> Iterator[Tuple[str, FunctionNode]]:
        yield from self.by_qualname.items()

    def methods_of(self, cls: str) -> Iterator[Tuple[str, FunctionNode]]:
        for qn, fn in self.by_qualname.items():
            if self.class_of.get(fn) == cls:
                yield qn, fn


# ----------------------------------------------------------------------
# Static evaluation
# ----------------------------------------------------------------------

_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.FloorDiv: lambda a, b: a // b,
    ast.Div: lambda a, b: a / b,
    ast.Mod: lambda a, b: a % b,
    ast.Pow: lambda a, b: a ** b,
    ast.LShift: lambda a, b: a << b,
    ast.RShift: lambda a, b: a >> b,
}


def safe_eval(node: ast.AST, env: Dict[str, Any]) -> Any:
    """Evaluate constant arithmetic / tuples against ``env``; returns
    :data:`UNKNOWN` where any leaf is unresolvable.  Tuples/lists keep
    their LENGTH even when elements are unknown — arity checks only
    need structure, footprints need values."""
    if isinstance(node, ast.Constant):
        if isinstance(node.value, bool) or node.value is None:
            return node.value
        if isinstance(node.value, (int, float)):
            return node.value
        if isinstance(node.value, str):
            return node.value
        return UNKNOWN
    if isinstance(node, ast.Name):
        return env.get(node.id, UNKNOWN)
    if isinstance(node, ast.Tuple):
        return tuple(safe_eval(e, env) for e in node.elts)
    if isinstance(node, ast.List):
        return [safe_eval(e, env) for e in node.elts]
    if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
        left = safe_eval(node.left, env)
        right = safe_eval(node.right, env)
        # list * N repeats structure even with unknown elements
        if isinstance(node.op, ast.Mult):
            if isinstance(left, list) and isinstance(right, int):
                return left * right
            if isinstance(right, list) and isinstance(left, int):
                return right * left
        if not is_known(left) or not is_known(right):
            return UNKNOWN
        try:
            return _BINOPS[type(node.op)](left, right)
        except Exception:
            return UNKNOWN
    if isinstance(node, ast.UnaryOp):
        val = safe_eval(node.operand, env)
        if not is_known(val):
            return UNKNOWN
        if isinstance(node.op, ast.USub):
            return -val
        if isinstance(node.op, ast.UAdd):
            return +val
        return UNKNOWN
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in ("min", "max", "len"):
            args = [safe_eval(a, env) for a in node.args]
            if fn.id == "len" and len(args) == 1 \
                    and isinstance(args[0], (tuple, list)):
                return len(args[0])
            if all(is_known(a) and not isinstance(a, (tuple, list))
                   for a in args) and args:
                try:
                    return (min if fn.id == "min" else max)(args)
                except Exception:
                    return UNKNOWN
        return UNKNOWN
    return UNKNOWN


def module_constants(tree: ast.Module) -> Dict[str, Any]:
    """Top-level ``NAME = <const expr>`` bindings, evaluated in order."""
    env: Dict[str, Any] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            val = safe_eval(node.value, env)
            if is_known(val):
                env[node.targets[0].id] = val
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name):
            val = safe_eval(node.value, env)
            if is_known(val):
                env[node.target.id] = val
    return env


def param_names(fn: FunctionNode) -> List[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def param_defaults(fn: FunctionNode, env: Dict[str, Any]) -> Dict[str, Any]:
    """Statically-evaluable parameter defaults (the pallas checker uses
    these as the footprint's representative values)."""
    out: Dict[str, Any] = {}
    args = fn.args
    pos = args.posonlyargs + args.args
    for name_node, default in zip(pos[len(pos) - len(args.defaults):],
                                  args.defaults):
        val = safe_eval(default, env)
        if is_known(val):
            out[name_node.arg] = val
    for name_node, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            val = safe_eval(default, env)
            if is_known(val):
                out[name_node.arg] = val
    return out


def keyword_map(call: ast.Call) -> Dict[str, ast.expr]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg}
