"""Findings, inline suppressions, and the committed baseline.

A finding is one violated contract at one site.  Two escape hatches,
both requiring a *written justification*:

* inline — ``# repro: ignore[checker-id] -- reason`` on the flagged
  line (or on its own line directly above).  A suppression with no
  ``-- reason`` tail, or naming an unknown checker, is itself a
  finding (checker id ``suppression``): the syntax exists to record
  intent, not to silence output.
* baseline — entries in ``analysis_baseline.json`` keyed by
  ``(checker, path, message)`` (line-agnostic, so unrelated edits
  above a known finding don't churn the file).  Every entry must carry
  a non-empty ``justification``; the loader refuses the file otherwise.
"""
from __future__ import annotations

import dataclasses
import io
import json
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Set, Tuple

SEVERITIES = ("error", "warn")

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore\[(?P<ids>[^\]]*)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    checker: str
    path: str          # repo-relative, posix separators
    line: int
    col: int
    severity: str      # "error" | "warn"
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Line-agnostic identity used by the baseline."""
        return (self.checker, self.path, self.message)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity}[{self.checker}] {self.message}")

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int          # first code line the suppression applies to
    end_line: int      # last line (standalone form covers the whole
                       # logical statement it precedes)
    comment_line: int  # where the comment physically lives
    checkers: Tuple[str, ...]
    reason: Optional[str]


class SuppressionSet:
    """Per-file suppression index parsed from comments."""

    def __init__(self, source: str):
        self.suppressions: List[Suppression] = []
        self.malformed: List[Tuple[int, str]] = []
        comments: List[Tuple[int, bool, str]] = []  # (row, inline, text)
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(source).readline))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return
        # logical statement spans: rows of code tokens between NEWLINE
        # tokens, so a standalone suppression covers a multi-line call
        spans: List[Tuple[int, int]] = []
        cur: List[int] = []
        for tok in tokens:
            if tok.type == tokenize.COMMENT:
                inline = tok.start[1] > 0 and bool(
                    source.splitlines()[tok.start[0] - 1]
                    [:tok.start[1]].strip())
                comments.append((tok.start[0], inline, tok.string))
            elif tok.type == tokenize.NEWLINE:
                if cur:
                    spans.append((min(cur), max(cur)))
                    cur = []
            elif tok.type not in (tokenize.NL, tokenize.INDENT,
                                  tokenize.DEDENT, tokenize.ENDMARKER):
                cur.extend(range(tok.start[0], tok.end[0] + 1))
        if cur:
            spans.append((min(cur), max(cur)))
        for row, inline, text in comments:
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            ids = tuple(s.strip() for s in m.group("ids").split(",")
                        if s.strip())
            reason = m.group("reason")
            if inline:
                target = (row, row)
            else:
                target = next(((a, b) for a, b in spans if a > row),
                              (row, row))
            if not ids or reason is None or not reason.strip():
                self.malformed.append(
                    (row, "suppression needs [checker-id] and a "
                          "'-- reason' justification"))
                continue
            self.suppressions.append(Suppression(
                line=target[0], end_line=target[1], comment_line=row,
                checkers=ids, reason=reason.strip()))

    def matches(self, finding: Finding) -> bool:
        for sup in self.suppressions:
            if sup.line <= finding.line <= sup.end_line and (
                    finding.checker in sup.checkers
                    or "all" in sup.checkers):
                return True
        return False

    def unknown_ids(self, known: Iterable[str]) -> List[Tuple[int, str]]:
        known_set = set(known) | {"all"}
        out = []
        for sup in self.suppressions:
            for cid in sup.checkers:
                if cid not in known_set:
                    out.append((sup.comment_line, cid))
        return out


class BaselineError(ValueError):
    pass


class Baseline:
    """The committed debt ledger: known findings with justifications."""

    def __init__(self, entries: Optional[List[dict]] = None):
        self.entries = entries or []
        self._keys: Set[Tuple[str, str, str]] = set()
        for i, e in enumerate(self.entries):
            for field in ("checker", "path", "message", "justification"):
                if not isinstance(e.get(field), str):
                    raise BaselineError(
                        f"baseline entry {i}: missing/invalid "
                        f"'{field}'")
            if not e["justification"].strip():
                raise BaselineError(
                    f"baseline entry {i} ({e['checker']} at "
                    f"{e['path']}): empty justification — every "
                    "baselined finding must say WHY it is accepted")
            self._keys.add((e["checker"], e["path"], e["message"]))

    @classmethod
    def load(cls, path: str) -> "Baseline":
        try:
            with open(path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            return cls([])
        except ValueError as e:
            raise BaselineError(f"{path}: not valid JSON: {e}")
        if isinstance(doc, dict):
            doc = doc.get("entries", [])
        if not isinstance(doc, list):
            raise BaselineError(f"{path}: expected a list of entries")
        return cls(doc)

    def contains(self, finding: Finding) -> bool:
        return finding.key() in self._keys

    @staticmethod
    def write(path: str, findings: List[Finding]) -> None:
        """Emit a baseline skeleton for the given findings; the empty
        justification fields are deliberate — the loader rejects them
        until a human writes the reasons in."""
        entries = [{"checker": f.checker, "path": f.path,
                    "message": f.message, "justification": ""}
                   for f in findings]
        with open(path, "w") as fh:
            json.dump(entries, fh, indent=2)
            fh.write("\n")
