"""repro.analysis — the jax/Pallas contract linter (DESIGN.md §14).

Static analysis over this repo's own bug classes: stdlib-``ast`` only
(the same zero-dependency discipline as :mod:`repro.obs`), one checker
per class of bug a past PR actually fixed:

==================  ==================================================
checker id          contract
==================  ==================================================
host-sync           no device→host sync in per-step hot paths
host-aliasing       numpy buffers handed to jax must be snapshotted
prng-reuse          a key is consumed once, then re-derived
pallas-contract     BlockSpec/grid/index-map arity + VMEM budgets
recompile-hazard    nothing retraces per iteration
bit-accounting      wire costs come from core/, not local literals
suppression         ignore-comments carry an id and a reason
==================  ==================================================

Run ``python -m repro.analysis src/`` (see ``--help``); suppress a
deliberate site with ``# repro: ignore[checker-id] -- reason``; park
accepted debt in ``analysis_baseline.json`` with a justification.
"""
from __future__ import annotations

from typing import List

from repro.analysis import _astutil, findings  # noqa: F401  (import order)
from repro.analysis.engine import (ARTIFACT_VERSION, Checker, ModuleCtx,
                                   RunResult, TOOL_NAME, run)
from repro.analysis.findings import (Baseline, BaselineError, Finding,
                                     SuppressionSet)
from repro.analysis.bits_provenance import BitsProvenanceChecker
from repro.analysis.host_aliasing import HostAliasingChecker
from repro.analysis.host_sync import HostSyncChecker
from repro.analysis.pallas_contract import PallasContractChecker
from repro.analysis.prng_reuse import PrngReuseChecker
from repro.analysis.recompile import RecompileChecker


def default_checkers() -> List[Checker]:
    """All registered checkers, in stable id order."""
    return sorted([
        BitsProvenanceChecker(),
        HostAliasingChecker(),
        HostSyncChecker(),
        PallasContractChecker(),
        PrngReuseChecker(),
        RecompileChecker(),
    ], key=lambda c: c.id)


CHECKER_IDS = [c.id for c in default_checkers()]

__all__ = [
    "ARTIFACT_VERSION", "Baseline", "BaselineError", "CHECKER_IDS",
    "Checker", "Finding", "ModuleCtx", "RunResult", "SuppressionSet",
    "TOOL_NAME", "default_checkers", "run",
]
