"""Findings engine: file discovery, per-module context, checker runs.

The engine parses each file once into a :class:`ModuleCtx` (AST with
parent links, import canonicalization, module constants, function
index) and hands it to every registered checker.  Suppressions and the
baseline are applied *after* collection so the JSON artifact can
report what was silenced and why-shaped metadata stays auditable.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis import _astutil
from repro.analysis.findings import (Baseline, Finding, SuppressionSet)

TOOL_NAME = "repro.analysis"
ARTIFACT_VERSION = 1


@dataclasses.dataclass
class ModuleCtx:
    """Everything a checker needs about one parsed file."""
    path: str                      # filesystem path as given
    relpath: str                   # repo-relative posix path (finding key)
    source: str
    lines: List[str]
    tree: ast.Module
    imports: _astutil.ImportMap
    constants: Dict[str, object]
    functions: _astutil.FunctionIndex

    @classmethod
    def parse(cls, path: str, relpath: str,
              source: str) -> "ModuleCtx":
        tree = ast.parse(source, filename=path)
        _astutil.set_parents(tree)
        return cls(path=path, relpath=relpath, source=source,
                   lines=source.splitlines(), tree=tree,
                   imports=_astutil.ImportMap(tree),
                   constants=_astutil.module_constants(tree),
                   functions=_astutil.FunctionIndex(tree))

    def finding(self, checker: str, severity: str, node: ast.AST,
                message: str) -> Finding:
        return Finding(checker=checker, path=self.relpath,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0),
                       severity=severity, message=message)

    def in_core(self) -> bool:
        return "/core/" in self.relpath or self.relpath.startswith("core/")


class Checker:
    """Base class: subclasses set ``id``/``severity`` and implement
    :meth:`check` yielding findings for one module."""

    id: str = ""
    severity: str = "error"
    description: str = ""

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


def _repo_relpath(path: str, roots: Sequence[str]) -> str:
    """Path relative to the repo root when recognizable (the component
    before ``src``), else relative to cwd, posix separators."""
    norm = os.path.normpath(os.path.abspath(path))
    parts = norm.split(os.sep)
    for anchor in ("src", "tests", "benchmarks", "examples"):
        if anchor in parts:
            idx = parts.index(anchor)
            return "/".join(parts[idx:])
    rel = os.path.relpath(norm)
    return rel.replace(os.sep, "/")


def discover_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d != "__pycache__"
                                 and not d.startswith("."))
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        else:
            raise FileNotFoundError(p)
    return out


@dataclasses.dataclass
class RunResult:
    findings: List[Finding]            # actionable (not suppressed/baselined)
    suppressed: List[Finding]
    baselined: List[Finding]
    files: int
    parse_errors: List[Finding]

    @property
    def all_findings(self) -> List[Finding]:
        return self.findings + self.parse_errors

    def to_json(self, paths: Sequence[str]) -> Dict[str, object]:
        def dump(fs: List[Finding], status: str) -> List[dict]:
            return [dict(f.to_json(), status=status) for f in fs]
        findings = (dump(self.all_findings, "open")
                    + dump(self.suppressed, "suppressed")
                    + dump(self.baselined, "baselined"))
        errors = sum(1 for f in self.all_findings
                     if f.severity == "error")
        return {
            "ts": time.time(),
            "tool": TOOL_NAME,
            "version": ARTIFACT_VERSION,
            "paths": list(paths),
            "findings": findings,
            "summary": {
                "files": self.files,
                "open": len(self.all_findings),
                "errors": errors,
                "warnings": len(self.all_findings) - errors,
                "suppressed": len(self.suppressed),
                "baselined": len(self.baselined),
            },
        }


def run(paths: Sequence[str], checkers: Sequence[Checker],
        baseline: Optional[Baseline] = None,
        select: Optional[Sequence[str]] = None) -> RunResult:
    baseline = baseline or Baseline([])
    active = [c for c in checkers
              if select is None or c.id in select]
    known_ids = [c.id for c in checkers] + ["suppression"]

    collected: List[Finding] = []
    parse_errors: List[Finding] = []
    sup_by_path: Dict[str, SuppressionSet] = {}
    files = discover_files(paths)
    for path in files:
        relpath = _repo_relpath(path, paths)
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        except (OSError, UnicodeDecodeError) as e:
            parse_errors.append(Finding(
                "parse", relpath, 1, 0, "error", f"unreadable: {e}"))
            continue
        sups = SuppressionSet(source)
        sup_by_path[relpath] = sups
        for row, msg in sups.malformed:
            collected.append(Finding("suppression", relpath, row, 0,
                                     "error", msg))
        for row, cid in sups.unknown_ids(known_ids):
            collected.append(Finding(
                "suppression", relpath, row, 0, "error",
                f"unknown checker id {cid!r} in suppression"))
        try:
            mod = ModuleCtx.parse(path, relpath, source)
        except SyntaxError as e:
            parse_errors.append(Finding(
                "parse", relpath, e.lineno or 1, 0, "error",
                f"syntax error: {e.msg}"))
            continue
        for checker in active:
            collected.extend(checker.check(mod))

    open_f: List[Finding] = []
    suppressed: List[Finding] = []
    baselined: List[Finding] = []
    for f in sorted(collected, key=lambda f: (f.path, f.line, f.col,
                                              f.checker)):
        sups = sup_by_path.get(f.path)
        # suppression-hygiene findings cannot suppress themselves
        if f.checker != "suppression" and sups is not None \
                and sups.matches(f):
            suppressed.append(f)
        elif baseline.contains(f):
            baselined.append(f)
        else:
            open_f.append(f)
    return RunResult(findings=open_f, suppressed=suppressed,
                     baselined=baselined, files=len(files),
                     parse_errors=parse_errors)
