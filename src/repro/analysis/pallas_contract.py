"""``pallas-contract`` — dimensional + VMEM contracts at pallas_call sites.

Every ``pl.pallas_call`` in ``kernels/`` encodes an implicit contract:

* each BlockSpec's index_map takes one parameter per grid axis (plus
  one per scalar-prefetch operand under ``PrefetchScalarGridSpec``)
  and returns one coordinate per block dimension;
* the number of runtime operands matches ``in_specs`` (plus the
  scalar-prefetch operands, which come first);
* ``out_specs`` and ``out_shape`` agree in arity;
* the per-grid-step VMEM footprint — Σ block-shape bytes over
  in/out specs and scratch — fits the module's own budget: a
  ``*VMEM_BUDGET*`` constant when the module defines one, else the
  ``~N MB VMEM`` comment-contract in its docstring (the dasha_update
  "comfortably inside ~16 MB VMEM" comment becomes an assertion).

Shapes are resolved by a bounded symbolic evaluator: module constants,
parameter defaults (``block_rows=512`` is the contract's representative
tile), simple local assignments, and single-return module-local helper
calls (``_batched_specs``).  A dimension that stays unresolvable makes
the checker *silent on the footprint* for that site — it never guesses
— while the arity checks still apply.
"""
from __future__ import annotations

import ast
import re
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

from repro.analysis import _astutil
from repro.analysis._astutil import UNKNOWN, is_known, safe_eval
from repro.analysis.engine import Checker, ModuleCtx
from repro.analysis.findings import Finding

_BUDGET_COMMENT_RE = re.compile(
    r"~?\s*(\d+(?:\.\d+)?)\s*MB\s+VMEM", re.IGNORECASE)
DEFAULT_BUDGET_BYTES = 16 << 20

_DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "float64": 8,
                "int64": 8, "bfloat16": 2, "float16": 2, "int16": 2,
                "int8": 1, "uint8": 1, "bool": 1, "bool_": 1}


class _BlockSpec:
    def __init__(self, shape: Any, index_map: Optional[ast.AST],
                 node: ast.AST):
        self.shape = shape          # tuple (possibly with UNKNOWN dims)
        self.index_map = index_map  # Lambda / FunctionDef / None
        self.node = node


class _ShapeStruct:
    def __init__(self, shape: Any, dtype: Optional[str], node: ast.AST):
        self.shape = shape
        self.dtype = dtype
        self.node = node


class _VMEMScratch(_ShapeStruct):
    pass


class _GridSpec:
    def __init__(self, grid: Any, num_scalar_prefetch: int,
                 in_specs: Any, out_specs: Any, node: ast.AST):
        self.grid = grid
        self.num_scalar_prefetch = num_scalar_prefetch
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.node = node


def _dtype_bytes(dtype: Optional[str]) -> int:
    if dtype is None:
        return 4
    return _DTYPE_BYTES.get(dtype.rsplit(".", 1)[-1], 4)


class _Resolver:
    """Bounded symbolic evaluation of local names inside one function."""

    MAX_DEPTH = 3

    def __init__(self, mod: ModuleCtx):
        self.mod = mod

    def function_env(self, fn: _astutil.FunctionNode,
                     bound: Optional[Dict[str, Any]] = None,
                     depth: int = 0) -> Dict[str, Any]:
        env: Dict[str, Any] = dict(self.mod.constants)
        env.update(_astutil.param_defaults(fn, self.mod.constants))
        if bound:
            env.update(bound)
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                val = self.resolve(stmt.value, env, depth)
                tgt = stmt.targets[0]
                if isinstance(tgt, ast.Name):
                    env[tgt.id] = val
                elif isinstance(tgt, ast.Tuple) \
                        and isinstance(val, tuple) \
                        and len(val) == len(tgt.elts):
                    for t, v in zip(tgt.elts, val):
                        if isinstance(t, ast.Name):
                            env[t.id] = v
        return env

    def resolve(self, node: ast.AST, env: Dict[str, Any],
                depth: int = 0) -> Any:
        if isinstance(node, ast.Call):
            return self._resolve_call(node, env, depth)
        if isinstance(node, ast.Lambda):
            return node
        # containers recurse through the full resolver (elements may be
        # BlockSpec calls safe_eval cannot see into)
        if isinstance(node, ast.Tuple):
            return tuple(self.resolve(e, env, depth) for e in node.elts)
        if isinstance(node, ast.List):
            return [self.resolve(e, env, depth) for e in node.elts]
        val = safe_eval(node, env)
        if is_known(val):
            return val
        if isinstance(node, ast.Name):
            fn = self.mod.functions.by_qualname.get(node.id)
            if fn is not None:
                return fn
            return env.get(node.id, UNKNOWN)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
            left = self.resolve(node.left, env, depth)
            right = self.resolve(node.right, env, depth)
            if isinstance(left, list) and isinstance(right, int):
                return left * right
            if isinstance(right, list) and isinstance(left, int):
                return right * left
        return UNKNOWN

    def _resolve_call(self, call: ast.Call, env: Dict[str, Any],
                      depth: int) -> Any:
        name = self.mod.imports.call_name(call)
        if name is None:
            return UNKNOWN
        tail = name.rsplit(".", 1)[-1]
        kwargs = _astutil.keyword_map(call)
        if tail == "BlockSpec":
            shape = (self.resolve(call.args[0], env, depth)
                     if call.args else
                     self.resolve(kwargs.get("block_shape"), env, depth)
                     if "block_shape" in kwargs else UNKNOWN)
            imap_node: Optional[ast.AST] = None
            if len(call.args) > 1:
                imap_node = call.args[1]
            elif "index_map" in kwargs:
                imap_node = kwargs["index_map"]
            imap = (self.resolve(imap_node, env, depth)
                    if imap_node is not None else None)
            if not isinstance(imap, (ast.Lambda, ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                imap = imap_node if isinstance(imap_node,
                                               ast.Lambda) else None
            return _BlockSpec(shape, imap, call)
        if tail == "ShapeDtypeStruct":
            shape = (self.resolve(call.args[0], env, depth)
                     if call.args else UNKNOWN)
            dtype = None
            dt_node = (call.args[1] if len(call.args) > 1
                       else kwargs.get("dtype"))
            if dt_node is not None:
                dtype = self.mod.imports.canonical(dt_node)
            return _ShapeStruct(shape, dtype, call)
        if tail == "VMEM":
            shape = (self.resolve(call.args[0], env, depth)
                     if call.args else UNKNOWN)
            dtype = (self.mod.imports.canonical(call.args[1])
                     if len(call.args) > 1 else None)
            return _VMEMScratch(shape, dtype, call)
        if tail == "PrefetchScalarGridSpec":
            nsp = (safe_eval(kwargs["num_scalar_prefetch"], env)
                   if "num_scalar_prefetch" in kwargs else 0)
            return _GridSpec(
                grid=(self.resolve(kwargs["grid"], env, depth)
                      if "grid" in kwargs else UNKNOWN),
                num_scalar_prefetch=nsp if is_known(nsp) else 0,
                in_specs=(self.resolve(kwargs["in_specs"], env, depth)
                          if "in_specs" in kwargs else UNKNOWN),
                out_specs=(self.resolve(kwargs["out_specs"], env, depth)
                           if "out_specs" in kwargs else UNKNOWN),
                node=call)
        # module-local helper with a single return of resolvable values
        local_fn = self.mod.functions.by_qualname.get(name) \
            if "." not in name else None
        if local_fn is not None and depth < self.MAX_DEPTH:
            bound: Dict[str, Any] = {}
            params = _astutil.param_names(local_fn)
            for pname, arg in zip(params, call.args):
                bound[pname] = self.resolve(arg, env, depth + 1)
            for kname, kval in kwargs.items():
                bound[kname] = self.resolve(kval, env, depth + 1)
            callee_env = self.function_env(local_fn, bound, depth + 1)
            for stmt in local_fn.body:
                if isinstance(stmt, ast.Return) \
                        and stmt.value is not None:
                    return self.resolve(stmt.value, callee_env,
                                        depth + 1)
        return UNKNOWN


def _lambda_params(imap: ast.AST) -> Optional[int]:
    if isinstance(imap, ast.Lambda):
        return len(imap.args.args) + len(imap.args.posonlyargs)
    if isinstance(imap, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return len(imap.args.args) + len(imap.args.posonlyargs)
    return None


def _lambda_return_arity(imap: ast.AST) -> Optional[int]:
    body: Optional[ast.AST] = None
    if isinstance(imap, ast.Lambda):
        body = imap.body
    elif isinstance(imap, (ast.FunctionDef, ast.AsyncFunctionDef)):
        returns = [s for s in ast.walk(imap) if isinstance(s, ast.Return)
                   and s.value is not None]
        if len(returns) != 1:
            return None
        body = returns[0].value
    if isinstance(body, ast.Tuple):
        return len(body.elts)
    if body is not None:
        return 1
    return None


def _as_spec_list(specs: Any) -> Optional[List[Any]]:
    if isinstance(specs, list):
        return specs
    if isinstance(specs, tuple):
        return list(specs)
    if specs is UNKNOWN or specs is None:
        return None
    return [specs]


def module_budget_bytes(mod: ModuleCtx) -> Tuple[int, str]:
    """The module's own VMEM budget: a ``*VMEM_BUDGET*`` constant wins,
    else the ``~N MB VMEM`` comment-contract, else the 16 MB default."""
    for name, val in mod.constants.items():
        if "VMEM_BUDGET" in name and isinstance(val, (int, float)):
            return int(val), name
    m = _BUDGET_COMMENT_RE.search(mod.source)
    if m:
        return int(float(m.group(1)) * (1 << 20)), \
            f"comment-contract '~{m.group(1)} MB VMEM'"
    return DEFAULT_BUDGET_BYTES, "default 16 MB"


class PallasContractChecker(Checker):
    id = "pallas-contract"
    severity = "error"
    description = ("BlockSpec/grid/index-map arity and static VMEM "
                   "footprint vs the module's budget at every "
                   "pl.pallas_call site")

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:
        resolver = _Resolver(mod)
        budget, budget_src = module_budget_bytes(mod)
        for _qn, fn in mod.functions.functions():
            sites = [n for n in ast.walk(fn) if isinstance(n, ast.Call)
                     and self._is_pallas_call(mod, n)
                     and _astutil.enclosing_function(n) is fn]
            if not sites:
                continue
            env = resolver.function_env(fn)
            for call in sites:
                yield from self._check_site(mod, fn, call, env,
                                            resolver, budget,
                                            budget_src)

    @staticmethod
    def _is_pallas_call(mod: ModuleCtx, call: ast.Call) -> bool:
        name = mod.imports.call_name(call)
        return name is not None and name.endswith(".pallas_call")

    def _check_site(self, mod: ModuleCtx, fn: _astutil.FunctionNode,
                    call: ast.Call, env: Dict[str, Any],
                    resolver: _Resolver, budget: int,
                    budget_src: str) -> Iterable[Finding]:
        kwargs = _astutil.keyword_map(call)

        grid: Any = UNKNOWN
        nsp = 0
        in_specs: Any = UNKNOWN
        out_specs: Any = UNKNOWN
        if "grid_spec" in kwargs:
            gs = resolver.resolve(kwargs["grid_spec"], env)
            if isinstance(gs, _GridSpec):
                grid = gs.grid
                nsp = gs.num_scalar_prefetch
                in_specs = gs.in_specs
                out_specs = gs.out_specs
        else:
            if "grid" in kwargs:
                grid = resolver.resolve(kwargs["grid"], env)
            if "in_specs" in kwargs:
                in_specs = resolver.resolve(kwargs["in_specs"], env)
            if "out_specs" in kwargs:
                out_specs = resolver.resolve(kwargs["out_specs"], env)

        grid_arity: Optional[int] = None
        if isinstance(grid, tuple):
            grid_arity = len(grid)
        elif isinstance(grid, int):
            grid_arity = 1

        in_list = _as_spec_list(in_specs)
        out_list = _as_spec_list(out_specs)

        # 1/2: index-map parameter count and return arity per BlockSpec
        for spec in (in_list or []) + (out_list or []):
            if not isinstance(spec, _BlockSpec):
                continue
            if spec.index_map is not None and grid_arity is not None:
                nparams = _lambda_params(spec.index_map)
                want = grid_arity + nsp
                if nparams is not None and nparams != want:
                    yield mod.finding(
                        self.id, self.severity, spec.node,
                        f"index_map takes {nparams} parameter(s) but "
                        f"the grid has {grid_arity} axis(es)"
                        + (f" + {nsp} scalar-prefetch operand(s)"
                           if nsp else "")
                        + f" = {want} expected")
            if spec.index_map is not None \
                    and isinstance(spec.shape, tuple):
                ret = _lambda_return_arity(spec.index_map)
                if ret is not None and ret != len(spec.shape):
                    yield mod.finding(
                        self.id, self.severity, spec.node,
                        f"index_map returns {ret} coordinate(s) for a "
                        f"{len(spec.shape)}-dim block "
                        f"{_fmt_shape(spec.shape)}")

        # 3: operand count at the immediate call
        outer = _astutil.parent(call)
        if isinstance(outer, ast.Call) and outer.func is call \
                and in_list is not None \
                and not any(isinstance(a, ast.Starred)
                            for a in outer.args):
            n_args = len(outer.args)
            want = len(in_list) + nsp
            if n_args != want:
                yield mod.finding(
                    self.id, self.severity, outer,
                    f"pallas_call receives {n_args} operand(s) but "
                    f"declares {len(in_list)} in_spec(s)"
                    + (f" + {nsp} scalar-prefetch" if nsp else ""))

        # 4: out_specs vs out_shape arity
        out_shape = (resolver.resolve(kwargs["out_shape"], env)
                     if "out_shape" in kwargs else UNKNOWN)
        shape_list = _as_spec_list(out_shape)
        if out_list is not None and shape_list is not None \
                and len(out_list) != len(shape_list):
            yield mod.finding(
                self.id, self.severity, call,
                f"out_specs has {len(out_list)} spec(s) but out_shape "
                f"has {len(shape_list)} result(s)")

        # 5: static VMEM footprint vs the module budget
        scratch = (resolver.resolve(kwargs["scratch_shapes"], env)
                   if "scratch_shapes" in kwargs else [])
        scratch_list = _as_spec_list(scratch) or []
        total = 0
        resolvable = True
        for spec in (in_list or []) + (out_list or []) + scratch_list:
            if isinstance(spec, (_BlockSpec, _ShapeStruct)):
                shape = spec.shape
                dtype = getattr(spec, "dtype", None)
            else:
                resolvable = False
                break
            if not isinstance(shape, tuple) \
                    or not all(isinstance(d, int) for d in shape):
                resolvable = False
                break
            n = 1
            for d in shape:
                n *= d
            total += n * _dtype_bytes(dtype)
        if resolvable and (in_list or out_list) and total > budget:
            yield mod.finding(
                self.id, self.severity, call,
                f"per-grid-step VMEM footprint {total} bytes "
                f"(~{total / (1 << 20):.2f} MB) exceeds the module "
                f"budget {budget} bytes ({budget_src})")


def _fmt_shape(shape: tuple) -> str:
    return "(" + ", ".join(
        str(d) if is_known(d) else "?" for d in shape) + ")"
