"""``prng-reuse`` — a PRNG key consumed twice without re-derivation.

The bug class PR 4 fixed: resumed training replayed round-0 randomness
because the same key reached the round sampler twice.  The paper's
variance-reduction guarantees assume fresh randomness per round — round
keys, the PAGE shared coin, and participation draws must never repeat
(DESIGN.md §8's shared-randomness contract), so key reuse is a
*correctness* bug here, not a style issue.

Model: a small abstract interpreter runs over each function body
tracking, per key variable, how many times it has been *consumed* —
passed bare to any call that is not a derivation (``split`` /
``fold_in`` / ``clone`` / ``*key(s)`` helpers like ``round_keys``).
Reassigning the name (``key, sub = split(key)``) resets the count.

* ``If``/``Try`` branches evaluate independently and merge by max —
  one use in each arm of an if/else is one use.
* Loop bodies evaluate **twice**: a key consumed in a loop without an
  interleaved re-derivation is consumed again on the next iteration —
  exactly the round-0 replay shape.
* ``f(key, key)`` is two consumptions in one call.

Key variables are parameters/locals whose name matches ``key``/``rng``
conventions or whose value flows from a key-producing call.  Elements
of key *arrays* (``keys[i]``) are not tracked — indexed fan-out is the
correct idiom.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import _astutil
from repro.analysis.engine import Checker, ModuleCtx
from repro.analysis.findings import Finding

KEY_NAME_RE = re.compile(
    r"(^|_)(key|keys|rng|prng)($|_)|(^|_)key[s]?$", re.IGNORECASE)

# canonical producers: their results are key-typed
PRODUCERS = {"jax.random.PRNGKey", "jax.random.key", "jax.random.split",
             "jax.random.fold_in", "jax.random.clone",
             "jax.random.wrap_key_data"}
# derivations: consume-exempt uses (they mint fresh keys from the base)
DERIVERS = {"jax.random.split", "jax.random.fold_in",
            "jax.random.clone", "jax.random.key_data"}
_DERIVER_TAIL_RE = re.compile(r"(^|_)keys?$")
# host introspection — passing a key (or key array) here is not a
# randomness consumption
NONCONSUMING = {"len", "sorted", "list", "tuple", "set", "dict",
                "enumerate", "zip", "reversed", "min", "max", "sum",
                "any", "all", "isinstance", "print", "repr", "str",
                "id", "type", "hash"}


def _is_producer(name: Optional[str]) -> bool:
    if name is None:
        return False
    if name in PRODUCERS:
        return True
    return bool(_DERIVER_TAIL_RE.search(name.rsplit(".", 1)[-1]))


def _terminates(block: List[ast.stmt]) -> bool:
    """The block always leaves the enclosing suite (so its state never
    reaches the code after the ``if``)."""
    if not block:
        return False
    last = block[-1]
    return isinstance(last, (ast.Return, ast.Raise, ast.Break,
                             ast.Continue))


def _is_deriver(name: Optional[str]) -> bool:
    if name is None:
        return False
    if name in DERIVERS:
        return True
    return bool(_DERIVER_TAIL_RE.search(name.rsplit(".", 1)[-1]))


class _State:
    """name -> (generation id, consumption count)."""

    def __init__(self):
        self.gen: Dict[str, int] = {}
        self.count: Dict[str, int] = {}
        self._next = 0

    def fresh(self, name: str) -> None:
        self._next += 1
        self.gen[name] = self._next
        self.count[name] = 0

    def is_key(self, name: str) -> bool:
        return name in self.gen

    def copy(self) -> "_State":
        st = _State()
        st.gen = dict(self.gen)
        st.count = dict(self.count)
        st._next = self._next
        return st

    def merge_max(self, other: "_State") -> None:
        for name in set(self.gen) | set(other.gen):
            if name in self.gen and name in other.gen:
                if self.gen[name] == other.gen[name]:
                    self.count[name] = max(self.count[name],
                                           other.count[name])
                else:   # rebound in one branch: conservatively fresh
                    self.count[name] = min(self.count[name],
                                           other.count[name])
            elif name in other.gen:
                self.gen[name] = other.gen[name]
                self.count[name] = other.count[name]
        self._next = max(self._next, other._next)


class PrngReuseChecker(Checker):
    id = "prng-reuse"
    severity = "error"
    description = ("PRNG key consumed by >=2 random ops / passed twice "
                   "without an interleaving split/fold_in")

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:
        for _qn, fn in mod.functions.functions():
            yield from self._check_function(mod, fn)

    def _check_function(self, mod: ModuleCtx,
                        fn: _astutil.FunctionNode) -> Iterable[Finding]:
        state = _State()
        for pname in _astutil.param_names(fn):
            if KEY_NAME_RE.search(pname):
                state.fresh(pname)
        findings: List[Finding] = []
        reported: Set[Tuple[str, int]] = set()
        self._exec_block(fn.body, state, mod, findings, reported)
        return findings

    # -- statement interpretation --------------------------------------

    def _exec_block(self, body: List[ast.stmt], state: _State,
                    mod: ModuleCtx, findings: List[Finding],
                    reported: Set[Tuple[str, int]]) -> None:
        for stmt in body:
            self._exec_stmt(stmt, state, mod, findings, reported)

    def _exec_stmt(self, stmt: ast.stmt, state: _State, mod: ModuleCtx,
                   findings: List[Finding],
                   reported: Set[Tuple[str, int]]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return      # nested scopes analyzed separately
        if isinstance(stmt, (ast.If,)):
            self._eval_expr(stmt.test, state, mod, findings, reported)
            b1 = state.copy()
            self._exec_block(stmt.body, b1, mod, findings, reported)
            b2 = state.copy()
            self._exec_block(stmt.orelse, b2, mod, findings, reported)
            # a branch that cannot fall through (trailing return/raise)
            # contributes nothing to the post-if state
            body_t = _terminates(stmt.body)
            else_t = bool(stmt.orelse) and _terminates(stmt.orelse)
            if body_t and not else_t:
                b1 = b2
            elif not (else_t and not body_t):
                b1.merge_max(b2)
            state.gen, state.count = b1.gen, b1.count
            state._next = b1._next
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._eval_expr(stmt.iter, state, mod, findings, reported)
            self._bind_target(stmt.target, None, state)
            # two symbolic iterations: reuse across iterations surfaces
            # on the second pass
            self._exec_block(stmt.body, state, mod, findings, reported)
            self._exec_block(stmt.body, state, mod, findings, reported)
            self._exec_block(stmt.orelse, state, mod, findings, reported)
            return
        if isinstance(stmt, ast.While):
            self._eval_expr(stmt.test, state, mod, findings, reported)
            self._exec_block(stmt.body, state, mod, findings, reported)
            self._exec_block(stmt.body, state, mod, findings, reported)
            self._exec_block(stmt.orelse, state, mod, findings, reported)
            return
        if isinstance(stmt, ast.Try):
            self._exec_block(stmt.body, state, mod, findings, reported)
            for handler in stmt.handlers:
                h = state.copy()
                self._exec_block(handler.body, h, mod, findings,
                                 reported)
                state.merge_max(h)
            self._exec_block(stmt.orelse, state, mod, findings, reported)
            self._exec_block(stmt.finalbody, state, mod, findings,
                             reported)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._eval_expr(item.context_expr, state, mod, findings,
                                reported)
            self._exec_block(stmt.body, state, mod, findings, reported)
            return
        if isinstance(stmt, ast.Assign):
            self._eval_expr(stmt.value, state, mod, findings, reported)
            for tgt in stmt.targets:
                self._bind_target(tgt, stmt.value, state, mod)
            return
        if isinstance(stmt, ast.AugAssign):
            self._eval_expr(stmt.value, state, mod, findings, reported)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._eval_expr(stmt.value, state, mod, findings, reported)
            self._bind_target(stmt.target, stmt.value, state, mod)
            return
        if isinstance(stmt, (ast.Expr, ast.Return)) \
                and stmt.value is not None:
            self._eval_expr(stmt.value, state, mod, findings, reported)
            return
        # everything else: evaluate child expressions for consumptions
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._eval_expr(child, state, mod, findings, reported)

    def _bind_target(self, target: ast.expr, value: Optional[ast.expr],
                     state: _State,
                     mod: Optional[ModuleCtx] = None) -> None:
        """(Re)binding a name makes it a fresh key when the RHS is
        key-producing or the name follows key conventions; any rebind
        of a tracked name resets its generation."""
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind_target(elt, value, state, mod)
            return
        if not isinstance(target, ast.Name):
            return
        name = target.id
        produced = False
        if value is not None and mod is not None \
                and isinstance(value, ast.Call):
            produced = _is_producer(mod.imports.call_name(value))
        if produced or KEY_NAME_RE.search(name):
            state.fresh(name)
        elif state.is_key(name):
            # overwritten with a non-key value: stop tracking
            del state.gen[name]
            del state.count[name]

    # -- expression interpretation -------------------------------------

    def _eval_expr(self, expr: ast.expr, state: _State, mod: ModuleCtx,
                   findings: List[Finding],
                   reported: Set[Tuple[str, int]]) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._eval_call(node, state, mod, findings, reported)

    def _eval_call(self, call: ast.Call, state: _State, mod: ModuleCtx,
                   findings: List[Finding],
                   reported: Set[Tuple[str, int]]) -> None:
        name = mod.imports.call_name(call)
        if _is_deriver(name) or name in NONCONSUMING:
            return
        args = list(call.args) + [kw.value for kw in call.keywords]
        for arg in args:
            if not isinstance(arg, ast.Name):
                continue
            if not state.is_key(arg.id):
                continue
            state.count[arg.id] = state.count.get(arg.id, 0) + 1
            if state.count[arg.id] >= 2:
                key = (arg.id, arg.lineno)
                if key in reported:
                    continue
                reported.add(key)
                callee = name or "<call>"
                findings.append(mod.finding(
                    self.id, self.severity, arg,
                    f"key '{arg.id}' is consumed again by "
                    f"'{callee}' without an interleaving "
                    "split/fold_in — identical randomness will be "
                    "replayed"))
