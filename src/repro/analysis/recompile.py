"""``recompile-hazard`` — patterns that retrace/recompile jitted code.

XLA compiles once per (function, static-arg values, input shapes).  Two
repo-relevant hazards:

* **jit inside a loop / per-step function** — ``jax.jit(f)`` minted
  fresh each iteration gets a fresh cache, so every call retraces.
  The jit belongs at module scope or in ``__init__``.  (A one-shot
  ``jit`` in a CLI ``main`` is fine and stays silent.)
* **loop-varying static arguments** — a value that changes across loop
  iterations passed as a ``static_argnames`` parameter of a
  same-module jitted function compiles a new executable per distinct
  value.  Loop *counters* (``for t in range(...)``) fed into a static
  parameter are the canonical miss.
* **mutable defaults in static position** — a list/dict default on a
  static parameter is unhashable and fails at the first call; flag it
  at the definition.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import _astutil
from repro.analysis.engine import Checker, ModuleCtx
from repro.analysis.findings import Finding
from repro.analysis.host_sync import PER_STEP_RE

_JIT_NAMES = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}


def _is_jit_call(mod: ModuleCtx, call: ast.Call) -> bool:
    return mod.imports.call_name(call) in _JIT_NAMES


def _static_names_of(mod: ModuleCtx,
                     call: ast.Call) -> Optional[List[str]]:
    """static_argnames of a jit/partial(jit, ...) call, when literal."""
    kwargs = _astutil.keyword_map(call)
    node = kwargs.get("static_argnames")
    if node is None:
        return None
    val = _astutil.safe_eval(node, {})
    if isinstance(val, str):
        return [val]
    if isinstance(val, (tuple, list)) \
            and all(isinstance(v, str) for v in val):
        return list(val)
    return None


class RecompileChecker(Checker):
    id = "recompile-hazard"
    severity = "warn"
    description = ("jax.jit in loops/per-step bodies, loop-varying "
                   "values into static_argnames, mutable static "
                   "defaults")

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:
        static_params = self._jitted_static_params(mod)
        yield from self._check_jit_placement(mod)
        yield from self._check_static_args(mod, static_params)
        yield from self._check_mutable_static_defaults(mod,
                                                       static_params)

    # -- jitted function discovery -------------------------------------

    def _jitted_static_params(self, mod: ModuleCtx
                              ) -> Dict[str, Set[str]]:
        """function name -> its static parameter names, for same-module
        functions decorated ``@jax.jit(...)`` or
        ``@partial(jax.jit, static_argnames=...)``."""
        out: Dict[str, Set[str]] = {}
        for qn, fn in mod.functions.functions():
            for deco in fn.decorator_list:
                if not isinstance(deco, ast.Call):
                    continue
                deco_name = mod.imports.call_name(deco)
                statics: Optional[List[str]] = None
                if deco_name in _JIT_NAMES:
                    statics = _static_names_of(mod, deco)
                elif deco_name in ("functools.partial", "partial") \
                        and deco.args:
                    inner = mod.imports.canonical(deco.args[0])
                    if inner in _JIT_NAMES:
                        statics = _static_names_of(mod, deco)
                if statics:
                    out[fn.name] = set(statics)
        return out

    # -- hazard 1: jit construction in hot code ------------------------

    def _check_jit_placement(self, mod: ModuleCtx) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) \
                    or not _is_jit_call(mod, node):
                continue
            # decorator positions are fine
            p = _astutil.parent(node)
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node in p.decorator_list:
                continue
            fn = _astutil.enclosing_function(node)
            loop = _astutil.enclosing_loop(node, within=fn)
            if loop is not None:
                yield mod.finding(
                    self.id, "error", node,
                    "jax.jit constructed inside a loop: each iteration "
                    "mints a fresh compilation cache and retraces; "
                    "hoist the jit out of the loop")
            elif fn is not None and PER_STEP_RE.search(fn.name) \
                    and not self._is_factory_use(node, fn):
                yield mod.finding(
                    self.id, self.severity, node,
                    f"jax.jit constructed inside per-step function "
                    f"'{fn.name}': the cache dies with each call; "
                    "build it once in __init__ or at module scope")

    @staticmethod
    def _is_factory_use(node: ast.Call,
                        fn: _astutil.FunctionNode) -> bool:
        """The jit is the function's *product* (``return jax.jit(...)``
        — builder methods like ``jit_train_step``), not a per-call
        construction."""
        for anc in _astutil.ancestors(node):
            if anc is fn:
                return False
            if isinstance(anc, ast.Return):
                return True
        return False

    # -- hazard 2: loop-varying value into a static parameter ----------

    def _check_static_args(self, mod: ModuleCtx,
                           static_params: Dict[str, Set[str]]
                           ) -> Iterable[Finding]:
        if not static_params:
            return
        for _qn, fn in mod.functions.functions():
            loop_vars = self._loop_vars(fn)
            if not loop_vars:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = mod.imports.call_name(node)
                if name is None:
                    continue
                tail = name.rsplit(".", 1)[-1]
                statics = static_params.get(tail)
                if statics is None:
                    continue
                loop = _astutil.enclosing_loop(node, within=fn)
                if loop is None:
                    continue
                for kw in node.keywords:
                    if kw.arg in statics \
                            and isinstance(kw.value, ast.Name) \
                            and kw.value.id in loop_vars.get(id(loop),
                                                             set()):
                        yield mod.finding(
                            self.id, self.severity, kw.value,
                            f"loop variable '{kw.value.id}' feeds "
                            f"static parameter '{kw.arg}' of jitted "
                            f"'{tail}': every distinct value compiles "
                            "a new executable; pass it as a traced "
                            "argument or hoist it")

    @staticmethod
    def _loop_vars(fn: _astutil.FunctionNode) -> Dict[int, Set[str]]:
        """Per-loop: names bound by the loop target (the values that
        vary across iterations)."""
        out: Dict[int, Set[str]] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                names = {leaf.id for leaf in ast.walk(node.target)
                         if isinstance(leaf, ast.Name)}
                out[id(node)] = names
        return out

    # -- hazard 3: mutable default on a static parameter ---------------

    def _check_mutable_static_defaults(self, mod: ModuleCtx,
                                       static_params: Dict[str, Set[str]]
                                       ) -> Iterable[Finding]:
        for _qn, fn in mod.functions.functions():
            statics = static_params.get(fn.name)
            if not statics:
                continue
            args = fn.args
            pos = args.posonlyargs + args.args
            pairs: List[Tuple[ast.arg, Optional[ast.expr]]] = list(
                zip(pos[len(pos) - len(args.defaults):], args.defaults))
            pairs += [(a, d) for a, d in zip(args.kwonlyargs,
                                             args.kw_defaults)]
            for arg, default in pairs:
                if default is None or arg.arg not in statics:
                    continue
                if isinstance(default, (ast.List, ast.Dict, ast.Set)) \
                        or (isinstance(default, ast.Call)
                            and mod.imports.call_name(default)
                            in ("list", "dict", "set")):
                    yield mod.finding(
                        self.id, "error", default,
                        f"static parameter '{arg.arg}' of jitted "
                        f"'{fn.name}' has an unhashable "
                        f"{type(default).__name__.lower()} default; "
                        "static args must be hashable (use a tuple)")
