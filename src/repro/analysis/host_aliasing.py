"""``host-aliasing`` — zero-copy jax conversions of live numpy buffers.

The PR 4/5 race class: ``jnp.asarray(buf)`` may alias ``buf``'s memory
on the CPU backend, and the conversion happens as part of jax's *async*
dispatch — mutating ``buf`` after handing it over corrupts the
still-in-flight computation (observed as nondeterministic greedy
decodes).  The repo-wide discipline is the synchronous-copy idiom:
``jnp.asarray(buf.copy())`` (or ``np.array(buf)``) before the handoff.

A conversion site fires when the buffer it captures is *provably live*:

* a local that is subscript-mutated at a later statement in the same
  function, or mutated anywhere inside the same loop body as the
  conversion (the next iteration races with this dispatch);
* a ``self.X`` attribute that any method of the class subscript-mutates
  — cross-method ordering is unknowable statically, so attribute
  buffers must be copied at the conversion site.

Wrapping the argument in ``.copy()`` / ``np.array(...)`` /
``np.ascontiguousarray(...)`` / ``.astype(...)`` exempts the site
(each produces an owned buffer).  Conversions of call results
(``jnp.asarray(store.gather(...))``) are fresh by construction and
never fire.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import _astutil
from repro.analysis.engine import Checker, ModuleCtx
from repro.analysis.findings import Finding

CONVERTERS = {"jax.numpy.asarray"}
COPY_CALLS = {"numpy.array", "numpy.ascontiguousarray", "numpy.copy"}
COPY_METHODS = {"copy", "astype", "tolist"}
INPLACE_METHODS = {"fill", "sort", "partition", "put", "resize",
                   "setfield", "itemset"}


def _buffer_of(mod: ModuleCtx, node: ast.AST) -> Optional[str]:
    """The dotted base buffer a conversion argument aliases: a Name, a
    ``self.X`` attribute, or a basic-slice view of either
    (``buf[i]`` / ``self._table[:, :W]`` are views of the base)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, (ast.Name, ast.Attribute)):
        return mod.imports.dotted(node)
    return None


def _is_copied(mod: ModuleCtx, arg: ast.AST) -> bool:
    if isinstance(arg, ast.Call):
        name = mod.imports.call_name(arg)
        if name in COPY_CALLS:
            return True
        if isinstance(arg.func, ast.Attribute) \
                and arg.func.attr in COPY_METHODS:
            return True
    return False


class HostAliasingChecker(Checker):
    id = "host-aliasing"
    severity = "error"
    description = ("jnp.asarray over a numpy buffer that is later "
                   "mutated (async-dispatch aliasing race); require "
                   "the synchronous-copy idiom")

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:
        attr_mutations = self._attribute_mutations(mod)
        for _qn, fn in mod.functions.functions():
            yield from self._check_function(mod, fn, attr_mutations)

    def _attribute_mutations(self, mod: ModuleCtx
                             ) -> Dict[Optional[str], Set[str]]:
        """Per class: the ``self.X`` buffers any method subscript-mutates
        or mutates in place."""
        out: Dict[Optional[str], Set[str]] = {}
        for _qn, fn in mod.functions.functions():
            cls = mod.functions.class_of.get(fn)
            for target in self._mutations(mod, fn):
                if target.startswith("self."):
                    out.setdefault(cls, set()).add(target)
        return out

    def _mutations(self, mod: ModuleCtx,
                   fn: _astutil.FunctionNode) -> List[str]:
        out = []
        for node, name in self._mutation_sites(mod, fn):
            out.append(name)
        return out

    def _mutation_sites(self, mod: ModuleCtx, fn: _astutil.FunctionNode
                        ) -> List[Tuple[ast.AST, str]]:
        sites: List[Tuple[ast.AST, str]] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    leaves = tgt.elts if isinstance(
                        tgt, (ast.Tuple, ast.List)) else [tgt]
                    for leaf in leaves:
                        if isinstance(leaf, ast.Subscript):
                            base = _buffer_of(mod, leaf)
                            if base is not None:
                                sites.append((node, base))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in INPLACE_METHODS:
                base = _buffer_of(mod, node.func.value)
                if base is not None:
                    sites.append((node, base))
        return sites

    def _check_function(self, mod: ModuleCtx, fn: _astutil.FunctionNode,
                        attr_mutations: Dict[Optional[str], Set[str]]
                        ) -> Iterable[Finding]:
        cls = mod.functions.class_of.get(fn)
        cls_mutated = attr_mutations.get(cls, set())
        local_sites = self._mutation_sites(mod, fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = mod.imports.call_name(node)
            if name not in CONVERTERS or not node.args:
                continue
            arg = node.args[0]
            if _is_copied(mod, arg):
                continue
            buf = _buffer_of(mod, arg)
            if buf is None:
                continue
            conv_line = node.lineno
            conv_loop = _astutil.enclosing_loop(node, within=fn)
            # a buffer wholly rebound inside the loop (keep = np.zeros
            # each iteration) is fresh per iteration: no cross-iteration
            # race through the old storage
            rebound_in_loop = (
                conv_loop is not None
                and self._rebound_inside(mod, conv_loop, buf))
            # (a) local buffer mutated after the conversion dispatches
            for site, base in local_sites:
                if base != buf:
                    continue
                site_line = site.lineno
                same_loop = (conv_loop is not None
                             and not rebound_in_loop
                             and self._inside(site, conv_loop))
                if site_line > conv_line or same_loop:
                    yield mod.finding(
                        self.id, self.severity, node,
                        f"jnp.asarray aliases '{buf}' which is mutated "
                        f"at line {site_line} while the conversion may "
                        "still be in flight; snapshot with a "
                        "synchronous copy (.copy() / np.array) before "
                        "the handoff")
                    break
            else:
                # (b) attribute buffer mutated by some method of the
                # class — ordering across methods is not static
                if buf.startswith("self.") and buf in cls_mutated:
                    yield mod.finding(
                        self.id, self.severity, node,
                        f"jnp.asarray aliases '{buf}', a buffer this "
                        "class mutates in place; cross-method ordering "
                        "with the async dispatch is not provable — "
                        "snapshot with a synchronous copy (.copy() / "
                        "np.array) at the conversion")

    @staticmethod
    def _inside(node: ast.AST, region: ast.AST) -> bool:
        return any(a is region for a in _astutil.ancestors(node))

    @staticmethod
    def _rebound_inside(mod: ModuleCtx, loop: ast.AST,
                        buf: str) -> bool:
        for node in ast.walk(loop):
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                leaves = tgt.elts if isinstance(
                    tgt, (ast.Tuple, ast.List)) else [tgt]
                for leaf in leaves:
                    if not isinstance(leaf, ast.Subscript) \
                            and mod.imports.dotted(leaf) == buf:
                        return True
        return False
