"""``bit-accounting`` — literal bit arithmetic outside ``core/``.

The paper's headline claim is the communication-complexity curve, so
every reported bit must trace to one place: the wire-format model in
``repro.core``.  PR 6's fleet and PR 7's serving layer both grew local
``32 * nnz``-style math that silently disagreed with the core model
until reconciled; the rule now is *provenance* — modules outside
``core/`` call the core helpers (``payload_bits``-style) instead of
re-deriving widths.

Fires on (a) arithmetic expressions that contain a bit-width literal
(8/16/32/64) in a bits-flavored context — assigned to / augmenting a
``*bits*`` name, passed to a ``*bits*`` parameter, or returned from a
``*bits*`` function — and (b) bare width literals bound to ``*bits*``
names (constants like ``GROUP_HEADER_BITS = 32.0`` or parameter
defaults like ``value_bits=32.0``): a hard-coded width IS a local wire
model, however small.  Pure core modules are exempt; so are
shift-by-width index computations (``x << 5``) with no bits-named
context.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional

from repro.analysis import _astutil
from repro.analysis.engine import Checker, ModuleCtx
from repro.analysis.findings import Finding

BITS_RE = re.compile(r"(^|_)bits?($|_)", re.IGNORECASE)
_WIDTH_LITERALS = {8, 16, 32, 64, 8.0, 16.0, 32.0, 64.0}


def _has_width_literal(expr: ast.AST) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Constant) \
                and isinstance(node.value, (int, float)) \
                and not isinstance(node.value, bool) \
                and node.value in _WIDTH_LITERALS:
            # a width literal used as a shift amount is indexing math,
            # not bit accounting
            p = _astutil.parent(node)
            if isinstance(p, ast.BinOp) \
                    and isinstance(p.op, (ast.LShift, ast.RShift)) \
                    and p.right is node:
                continue
            return True
    return False


def _is_width_literal(expr: Optional[ast.AST]) -> bool:
    return (isinstance(expr, ast.Constant)
            and isinstance(expr.value, (int, float))
            and not isinstance(expr.value, bool)
            and expr.value in _WIDTH_LITERALS)


def _is_arith(expr: ast.AST) -> bool:
    return isinstance(expr, ast.BinOp) and isinstance(
        expr.op, (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv))


class BitsProvenanceChecker(Checker):
    id = "bit-accounting"
    severity = "warn"
    description = ("literal bit-width arithmetic outside core/ — wire "
                   "costs must come from the core accounting helpers")

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:
        if mod.in_core():
            return
        for node in ast.walk(mod.tree):
            ctx = self._bits_context(mod, node)
            if ctx is None:
                continue
            expr = self._value_expr(node)
            if expr is None:
                continue
            if _is_arith(expr) and _has_width_literal(expr):
                yield mod.finding(
                    self.id, self.severity, expr,
                    f"literal bit-width arithmetic {ctx} outside "
                    "core/; derive wire costs from the core "
                    "accounting helpers (repro.core) so the "
                    "complexity curves stay single-sourced")
            elif _is_width_literal(expr):
                yield mod.finding(
                    self.id, self.severity, expr,
                    f"bit-width literal {ctx} outside core/; take the "
                    "width from the core wire model (repro.core) "
                    "instead of re-declaring it")
        yield from self._check_param_defaults(mod)

    def _check_param_defaults(self, mod: ModuleCtx) -> Iterable[Finding]:
        for _qn, fn in mod.functions.functions():
            args = fn.args
            pos = args.posonlyargs + args.args
            pairs = list(zip(pos[len(pos) - len(args.defaults):],
                             args.defaults))
            pairs += [(a, d) for a, d in zip(args.kwonlyargs,
                                             args.kw_defaults)
                      if d is not None]
            for arg, default in pairs:
                if BITS_RE.search(arg.arg) \
                        and _is_width_literal(default):
                    yield mod.finding(
                        self.id, self.severity, default,
                        f"bit-width literal default on parameter "
                        f"'{arg.arg}' of '{fn.name}' outside core/; "
                        "default it to the core wire model's width "
                        "constant instead")

    @staticmethod
    def _value_expr(node: ast.AST) -> Optional[ast.expr]:
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.Return)):
            return node.value
        if isinstance(node, ast.AnnAssign):
            return node.value
        if isinstance(node, ast.keyword):
            return node.value
        return None

    def _bits_context(self, mod: ModuleCtx,
                      node: ast.AST) -> Optional[str]:
        """A human-readable description of the bits-flavored context, or
        None when the node is not one."""
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                dotted = mod.imports.dotted(tgt)
                name = (dotted or "").rsplit(".", 1)[-1]
                if BITS_RE.search(name):
                    return f"assigned to '{dotted}'"
            return None
        if isinstance(node, ast.keyword) and node.arg \
                and BITS_RE.search(node.arg):
            return f"passed to parameter '{node.arg}'"
        if isinstance(node, ast.Return):
            fn = _astutil.enclosing_function(node)
            if fn is not None and BITS_RE.search(fn.name):
                return f"returned from '{fn.name}'"
            return None
        return None
