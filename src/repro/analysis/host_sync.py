"""``host-sync`` — device→host synchronization in per-step hot paths.

The bug class: PR 8 had to engineer per-step host syncs *out* of the
training loop (bits/participants are accumulated as device scalars and
summed once); a single ``.item()`` / ``np.asarray(device_value)`` /
``float(jitted_result)`` inside a per-token or per-round body silently
serializes the pipeline on every iteration.

Hot regions:

* **per-step functions** — names matching ``step``/``*_step`` /
  ``commit``/``dispatch`` (+ ``_impl`` forms) / ``*_pass``: the entire
  body is hot, and hotness propagates transitively through same-module
  calls (``self.helper()`` and bare local functions).
* **driver loops** — ``For``/``While`` bodies directly inside
  ``train``/``train_async``/``run``/``_run_impl``/``serve``: only the
  loop body's own statements are hot (admission/setup helpers called
  from a serve loop do per-request work, which is not the bug class),
  and only the *unambiguous* primitives fire there (``.item()``,
  ``block_until_ready``, ``jax.device_get``) — the fl/ simulators are
  event-driven host loops that legitimately build per-round metric
  rows with ``float()``/``int()``, which is their design, not the
  PR 8 pipeline-stall class.

A site only fires when the value being synced is *device-tainted*:
assigned from a ``jax.*`` call, from a ``self.method()`` call, or
derived from such a value.  ``np.asarray`` over a fresh host list, or
``int()`` over a numpy scalar, stays silent.  Where a sync is the
algorithm (greedy decode must read the sampled token back), the site
carries an inline ``# repro: ignore[host-sync] -- reason``.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import _astutil
from repro.analysis.engine import Checker, ModuleCtx
from repro.analysis.findings import Finding

PER_STEP_RE = re.compile(
    r"((^|_)step(_impl)?$)|((^|_)(commit|dispatch)(_impl)?$)|(_pass$)")
DRIVER_RE = re.compile(r"^(train|train_async|run|_run_impl|serve)$")

SYNC_NUMPY = {"numpy.asarray", "numpy.array"}
SYNC_ATTRS = {"item", "block_until_ready"}
SYNC_JAX = {"jax.device_get", "jax.block_until_ready"}
CAST_BUILTINS = {"float", "int", "bool"}

# call prefixes whose RESULTS live on the host (assigning from them does
# not taint) — numpy results are host arrays even when the call itself
# synced a device input (that sync is flagged at the call site).
_HOST_PREFIXES = ("numpy.", "time.", "math.", "os.", "collections.",
                  "itertools.", "random.")
_HOST_BUILTINS = {"len", "int", "float", "bool", "str", "sorted", "min",
                  "max", "sum", "abs", "range", "enumerate", "zip",
                  "list", "dict", "set", "tuple", "isinstance",
                  "getattr", "print", "repr", "any", "all", "id"}
_DEVICE_PREFIXES = ("jax.",)


class HostSyncChecker(Checker):
    id = "host-sync"
    severity = "warn"
    description = ("device→host sync (.item(), np.asarray(device), "
                   "float(jitted), block_until_ready) in a per-step "
                   "hot path")

    # -- taint ---------------------------------------------------------

    def _call_taint(self, call: ast.Call, mod: ModuleCtx,
                    local_taint: Set[str],
                    attr_taint: Set[str]) -> bool:
        name = mod.imports.call_name(call)
        if name is not None:
            if name.startswith(_DEVICE_PREFIXES):
                return True
            if name.startswith(_HOST_PREFIXES) or name in _HOST_BUILTINS:
                return False
            if name.startswith("self."):
                # a method on self may hand back device values (jitted
                # attributes like self._step)
                return True
        # method call on a known-host local stays host (q_lens.sum())
        if isinstance(call.func, ast.Attribute):
            return self._expr_taint(call.func.value, mod, local_taint,
                                    attr_taint)
        return True     # unknown callables taint conservatively

    def _expr_taint(self, node: ast.AST, mod: ModuleCtx,
                    local_taint: Set[str],
                    attr_taint: Set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in local_taint
        if isinstance(node, ast.Attribute):
            dotted = mod.imports.dotted(node)
            if dotted is not None and dotted in attr_taint:
                return True
            if dotted is not None:
                return False
            return self._expr_taint(node.value, mod, local_taint,
                                    attr_taint)
        if isinstance(node, ast.Call):
            return self._call_taint(node, mod, local_taint, attr_taint)
        if isinstance(node, ast.Subscript):
            return self._expr_taint(node.value, mod, local_taint,
                                    attr_taint)
        if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare,
                             ast.UnaryOp, ast.IfExp, ast.Starred)):
            return any(self._expr_taint(c, mod, local_taint, attr_taint)
                       for c in ast.iter_child_nodes(node)
                       if isinstance(c, ast.expr))
        return False

    def _local_taint(self, fn: _astutil.FunctionNode, mod: ModuleCtx,
                     attr_taint: Set[str]) -> Set[str]:
        taint: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue
            if isinstance(node, ast.Assign):
                tainted = self._expr_taint(node.value, mod, taint,
                                           attr_taint)
                if tainted:
                    for tgt in node.targets:
                        for leaf in ast.walk(tgt):
                            if isinstance(leaf, ast.Name):
                                taint.add(leaf.id)
        return taint

    def _attr_taint(self, mod: ModuleCtx) -> Set[str]:
        """Class-wide: ``self.X`` attributes assigned from tainted
        expressions anywhere in their class."""
        tainted: Set[str] = set()
        for _qn, fn in mod.functions.functions():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assign):
                    continue
                if not self._expr_taint(node.value, mod, set(), tainted):
                    continue
                for tgt in node.targets:
                    targets = tgt.elts if isinstance(
                        tgt, (ast.Tuple, ast.List)) else [tgt]
                    for t in targets:
                        dotted = mod.imports.dotted(t)
                        if dotted and dotted.startswith("self."):
                            tainted.add(dotted)
        return tainted

    # -- hot-region discovery ------------------------------------------

    def _callees(self, region: ast.AST, mod: ModuleCtx,
                 cls: Optional[str]) -> List[_astutil.FunctionNode]:
        out = []
        for node in ast.walk(region):
            if not isinstance(node, ast.Call):
                continue
            name = mod.imports.call_name(node)
            if name is None:
                continue
            target: Optional[_astutil.FunctionNode] = None
            if name.startswith("self.") and cls is not None:
                target = mod.functions.by_qualname.get(
                    f"{cls}.{name[5:]}")
            elif "." not in name:
                target = mod.functions.by_qualname.get(name)
            if target is not None:
                out.append(target)
        return out

    def _hot_regions(self, mod: ModuleCtx
                     ) -> List[Tuple[_astutil.FunctionNode, ast.AST]]:
        regions: List[Tuple[_astutil.FunctionNode, ast.AST]] = []
        hot_fns: Set[_astutil.FunctionNode] = set()
        work: List[_astutil.FunctionNode] = []
        for _qn, fn in mod.functions.functions():
            if PER_STEP_RE.search(fn.name):
                if fn not in hot_fns:
                    hot_fns.add(fn)
                    work.append(fn)
        while work:
            fn = work.pop()
            regions.append((fn, fn))
            cls = mod.functions.class_of.get(fn)
            for callee in self._callees(fn, mod, cls):
                if callee not in hot_fns:
                    hot_fns.add(callee)
                    work.append(callee)
        for _qn, fn in mod.functions.functions():
            if fn in hot_fns or not DRIVER_RE.match(fn.name):
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.While)) and \
                        _astutil.enclosing_function(node) is fn:
                    regions.append((fn, node))
        return regions

    # -- the check -----------------------------------------------------

    def check(self, mod: ModuleCtx) -> Iterable[Finding]:
        regions = self._hot_regions(mod)
        if not regions:
            return
        attr_taint = self._attr_taint(mod)
        taint_cache: Dict[_astutil.FunctionNode, Set[str]] = {}
        seen: Set[int] = set()
        for fn, region in regions:
            if fn not in taint_cache:
                taint_cache[fn] = self._local_taint(fn, mod, attr_taint)
            local = taint_cache[fn]
            strict = region is fn
            for node in ast.walk(region):
                if not isinstance(node, ast.Call) or id(node) in seen:
                    continue
                finding = self._check_call(node, mod, fn, local,
                                           attr_taint, strict)
                if finding is not None:
                    seen.add(id(node))
                    yield finding

    def _check_call(self, call: ast.Call, mod: ModuleCtx,
                    fn: _astutil.FunctionNode, local: Set[str],
                    attrs: Set[str], strict: bool) -> Optional[Finding]:
        where = f"in hot path '{fn.name}'"
        name = mod.imports.call_name(call)
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in SYNC_ATTRS:
            return mod.finding(
                self.id, self.severity, call,
                f".{call.func.attr}() forces a device sync {where}; "
                "accumulate on device and read back once after the "
                "loop")
        if name in SYNC_JAX:
            return mod.finding(
                self.id, self.severity, call,
                f"{name}() {where} blocks on the device every "
                "iteration; hoist it out of the loop")
        if not strict:
            return None
        if name in SYNC_NUMPY and any(
                self._expr_taint(a, mod, local, attrs)
                for a in call.args):
            return mod.finding(
                self.id, self.severity, call,
                f"{name.split('.')[-1]}() over a device value {where} "
                "synchronously materializes it on host each step")
        if isinstance(call.func, ast.Name) \
                and call.func.id in CAST_BUILTINS and call.args \
                and self._expr_taint(call.args[0], mod, local, attrs):
            return mod.finding(
                self.id, self.severity, call,
                f"{call.func.id}() of a device value {where} is a "
                "hidden blocking transfer; keep it on device until "
                "after the loop")
        return None
