"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1 = MQA) d_ff=16384
vocab=257216 — SigLIP vision tower + projector are a stub providing
patch embeddings (B, 256, d_model); the gemma decoder (this config) is
real.  Prefix-LM attention: full over the image prefix, causal over
text [arXiv:2407.07726]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    arch_type="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    frontend="vision",
    frontend_tokens=256,
    tie_embeddings=True,
    long_context_window=4096,     # long_500k via SWA variant
    source="arXiv:2407.07726",
)
