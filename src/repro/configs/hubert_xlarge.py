"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16 = MHA) d_ff=5120
vocab=504 — encoder-only transformer backbone; the mel-spectrogram +
conv feature extractor frontend is a stub per the assignment carve-out:
input_specs() provides frame embeddings (B, T, d_model)
[arXiv:2106.07447]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    is_encoder=True,
    frontend="audio",
    source="arXiv:2106.07447",
)
