"""Per-architecture configs (one file per assigned architecture).

Each module exposes ``CONFIG: ArchConfig`` with the exact assigned
hyperparameters (source cited in ``source``) and inherits a reduced
``.smoke()`` variant for CPU tests.
"""
from repro.models.registry import (ARCH_IDS, INPUT_SHAPES, InputShape,
                                   get_config, get_smoke_config,
                                   pair_supported)

__all__ = ["ARCH_IDS", "INPUT_SHAPES", "InputShape", "get_config",
           "get_smoke_config", "pair_supported"]
