"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    long_context_window=4096,     # long_500k via SWA variant
    source="arXiv:2407.21783",
)
