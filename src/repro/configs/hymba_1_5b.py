"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads fused per
layer; sliding-window attention (most layers in the paper use SWA),
making long_500k native [arXiv:2411.13676]."""
from repro.models.common import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    attention_window=1024,
    ssm=SSMConfig(state_dim=16, expand=2),
    scan_layers=True,
    source="arXiv:2411.13676",
)
