"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained)
[hf:databricks/dbrx-base]."""
from repro.models.common import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    arch_type="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    rope_theta=500_000.0,
    long_context_window=4096,     # long_500k via SWA variant
    moe=MoEConfig(num_experts=16, experts_per_token=4, d_expert=10752),
    source="hf:databricks/dbrx-base",
)
