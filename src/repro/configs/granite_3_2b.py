"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    arch_type="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    head_dim=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
    long_context_window=4096,    # long_500k via the SWA variant (DESIGN.md §4)
    source="hf:ibm-granite/granite-3.0-2b-base",
)
