"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks (every 4th block sLSTM, rest mLSTM; blocks carry their own
up/down projections, hence d_ff=0) [arXiv:2405.04517]."""
from repro.models.common import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    arch_type="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=4),
    scan_layers=False,            # mixed block types -> unrolled
    source="arXiv:2405.04517",
)
