"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (MLA) d_ff=1408
(per expert) vocab=102400, MoE 64 routed experts top-6 + 2 shared,
MLA kv_lora_rank=512 [arXiv:2405.04434].

Note (DESIGN.md §4): the assignment line lists 'MoE 64e top-6' and
'160 routed'; 160 belongs to full V2 — we follow the explicit
64e top-6 figure of V2-Lite."""
from repro.models.common import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    long_context_window=4096,     # long_500k via SWA variant
    moe=MoEConfig(num_experts=64, experts_per_token=6, d_expert=1408,
                  num_shared_experts=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434",
)
