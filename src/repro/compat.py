"""jax version-compat shims.

The production target is a recent jax (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.set_mesh``); the pinned container image
ships an older one where those live under ``jax.experimental`` or do not
exist.  Everything that touches the moved APIs goes through here so the
rest of the codebase is written against the new names only.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

__all__ = ["shard_map", "make_mesh", "use_mesh"]


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off (per-node
    randomness makes outputs intentionally non-replicated).  Falls back
    to ``jax.experimental.shard_map`` (spelled ``check_rep``) on older
    jax."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # pre-rename releases call it check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported (newer jax
    defaults to Explicit sharding under which the engine's untyped specs
    would be rejected); plain mesh on older jax."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def use_mesh(mesh) -> Any:
    """Context manager making ``mesh`` the ambient mesh:
    ``jax.set_mesh`` where it exists, the mesh's own context manager
    otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
