"""MARINA baseline (Gorbunov et al., 2021) with optional partial
participation, as compared against in paper Figs. 2-5.

MARINA alternates: with probability ``p`` a *synchronization* round where
every node sends its full, uncompressed gradient (this is exactly the
limitation DASHA-PP removes — MARINA cannot support PP on sync rounds,
paper Table 1 note (a)); otherwise nodes send compressed gradient
differences.

Partial-participation adaptation used in the paper's experimental
comparison: on non-sync rounds only the sampled nodes contribute, with
the unbiased 1/p_a scaling; sync rounds still require all nodes.

The stochastic variant replaces full local gradients by minibatch
estimates (no local variance reduction -> converges to a noise
neighbourhood; this is the qualitative gap in Figs. 4-5).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor
from repro.core.dasha_pp import StepMetrics
from repro.core.participation import FullParticipation, ParticipationSampler
from repro.core.problems import DistributedProblem, sample_batch_indices
from repro.core.variants import get_baseline

Array = jax.Array

RULE = get_baseline("marina")   # metadata + accounting (DESIGN.md §8)


class MarinaState(NamedTuple):
    x: Array        # (d,)
    g: Array        # (d,) server estimator
    step: Array


@dataclasses.dataclass(frozen=True)
class MarinaConfig:
    gamma: float
    p_sync: float                 # probability of a full-gradient round
    batch_size: Optional[int] = None   # None => exact local gradients


class Marina:
    def __init__(self, problem: DistributedProblem, compressor: Compressor,
                 sampler: Optional[ParticipationSampler], config: MarinaConfig):
        self.problem = problem
        self.compressor = compressor
        self.sampler = sampler or FullParticipation(n=problem.n)
        self.cfg = config

    def init(self, key: Array, x0: Array) -> MarinaState:
        del key
        g0 = self.problem.full_grad(x0)
        return MarinaState(x=x0, g=g0, step=jnp.zeros((), jnp.int32))

    def _local_grad(self, key: Array, x: Array) -> Tuple[Array, Array]:
        p = self.problem
        if self.cfg.batch_size is None:
            return p.grad(x), jnp.asarray(p.m * p.n)
        idx = sample_batch_indices(key, p.n, p.m, self.cfg.batch_size)
        return p.batch_grad(x, idx), jnp.asarray(self.cfg.batch_size * p.n)

    def step(self, key: Array, state: MarinaState
             ) -> Tuple[MarinaState, StepMetrics]:
        p, cfg, C = self.problem, self.cfg, self.compressor
        k_coin, k_part, k_g1, k_g2, k_comp = jax.random.split(key, 5)
        x_new = state.x - cfg.gamma * state.g

        sync = jax.random.bernoulli(k_coin, cfg.p_sync)
        gn, _ = self._local_grad(k_g1, x_new)            # (n, d)
        go, _ = self._local_grad(k_g2, state.x)

        # Sync round: g^{t+1} = mean_i ∇f_i(x^{t+1}) EXACT (VR-MARINA:
        # minibatches only on compressed-difference rounds), uncompressed,
        # all nodes — MARINA's full-participation requirement.
        g_sync = jnp.mean(p.grad(x_new), axis=0)

        # Compressed round: sampled nodes send C_i(diff), 1/p_a scaled.
        mask = self.sampler.sample(k_part).astype(state.x.dtype)[:, None]
        node_keys = jax.vmap(lambda i: jax.random.fold_in(k_comp, i))(
            jnp.arange(p.n))
        comp = jax.vmap(C.compress)(node_keys, gn - go)
        g_comp = state.g + jnp.mean(mask * comp, axis=0) / self.sampler.p_a

        g_new = jnp.where(sync, g_sync, g_comp)
        n_part = jnp.where(sync, p.n, jnp.sum(mask))
        bits = RULE.round_bits(p.n, p.d, jnp.sum(mask), C.wire_bits(p.d),
                               sync=sync)

        metrics = StepMetrics(
            loss=p.loss(state.x),
            grad_norm_sq=jnp.sum(p.full_grad(state.x) ** 2),
            bits_sent=bits,
            grad_oracle_calls=RULE.oracle_calls(p.n, p.m, cfg.batch_size,
                                                coin=sync),
            participants=n_part,
            x_norm=jnp.linalg.norm(state.x),
        )
        return MarinaState(x=x_new, g=g_new, step=state.step + 1), metrics

    def run(self, key: Array, x0: Array, num_rounds: int):
        init_key, run_key = jax.random.split(key)
        state = self.init(init_key, x0)

        def body(st, i):
            st, met = self.step(jax.random.fold_in(run_key, i), st)
            return st, met

        return jax.lax.scan(body, state, jnp.arange(num_rounds))
