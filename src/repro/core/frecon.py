"""FRECON baseline (Zhao et al., 2021a) — compressed federated learning
with client-variance reduction and partial participation.

Faithful-in-spirit reimplementation (see DESIGN.md §3): FRECON maintains
per-client anchors ``c_i`` (what the server last knew about client i's
gradient) and a global tracker; each round the sampled clients send a
compressed correction toward their fresh (mini-batch) gradient:

    d_i  = C_i( grad_i(x^t; xi) - c_i )           i in S_t
    g^t  = c_bar + (1/s) sum_{i in S} d_i          (unbiased around fresh grads)
    c_i <- c_i + alpha * d_i                       (anchor drift, i in S)
    x^{t+1} = x^t - gamma * g^t

FRECON reduces the *compressor* and *client-sampling* variance (paper
Table 1: PP=yes, CC=yes) but has **no local stochastic-gradient variance
reduction** (VR=no): with minibatch gradients it converges only to a
noise neighbourhood — the qualitative behaviour of paper Figs. 2-5.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor
from repro.core.dasha_pp import StepMetrics
from repro.core.participation import ParticipationSampler
from repro.core.problems import DistributedProblem, sample_batch_indices
from repro.core.variants import get_baseline

Array = jax.Array

RULE = get_baseline("frecon")   # metadata + accounting (DESIGN.md §8)


class FreconState(NamedTuple):
    x: Array     # (d,)
    c_i: Array   # (n, d) client anchors
    step: Array


@dataclasses.dataclass(frozen=True)
class FreconConfig:
    gamma: float
    alpha: float = 0.5                # anchor step
    batch_size: Optional[int] = None  # None => exact local gradients


class Frecon:
    def __init__(self, problem: DistributedProblem, compressor: Compressor,
                 sampler: ParticipationSampler, config: FreconConfig):
        self.problem = problem
        self.compressor = compressor
        self.sampler = sampler
        self.cfg = config

    def init(self, key: Array, x0: Array) -> FreconState:
        del key
        return FreconState(x=x0, c_i=self.problem.grad(x0),
                           step=jnp.zeros((), jnp.int32))

    def step(self, key: Array, state: FreconState
             ) -> Tuple[FreconState, StepMetrics]:
        p, cfg, C = self.problem, self.cfg, self.compressor
        k_part, k_batch, k_comp = jax.random.split(key, 3)

        if cfg.batch_size is None:
            grads = p.grad(state.x)
        else:
            idx = sample_batch_indices(k_batch, p.n, p.m, cfg.batch_size)
            grads = p.batch_grad(state.x, idx)
        calls = RULE.oracle_calls(p.n, p.m, cfg.batch_size)

        mask = self.sampler.sample(k_part)
        maskf = mask[:, None].astype(state.x.dtype)
        node_keys = jax.vmap(lambda i: jax.random.fold_in(k_comp, i))(
            jnp.arange(p.n))
        d_i = jax.vmap(C.compress)(node_keys, grads - state.c_i)
        d_i = maskf * d_i

        n_part = jnp.maximum(jnp.sum(mask), 1)
        g = jnp.mean(state.c_i, axis=0) + jnp.sum(d_i, axis=0) / n_part
        c_new = state.c_i + cfg.alpha * d_i
        x_new = state.x - cfg.gamma * g

        metrics = StepMetrics(
            loss=p.loss(state.x),
            grad_norm_sq=jnp.sum(p.full_grad(state.x) ** 2),
            bits_sent=RULE.round_bits(p.n, p.d, jnp.sum(mask),
                                      C.wire_bits(p.d)),
            grad_oracle_calls=calls,
            participants=jnp.sum(mask),
            x_norm=jnp.linalg.norm(state.x),
        )
        return FreconState(x=x_new, c_i=c_new, step=state.step + 1), metrics

    def run(self, key: Array, x0: Array, num_rounds: int):
        init_key, run_key = jax.random.split(key)
        state = self.init(init_key, x0)

        def body(st, i):
            st, met = self.step(jax.random.fold_in(run_key, i), st)
            return st, met

        return jax.lax.scan(body, state, jnp.arange(num_rounds))
