"""Theory-exact hyperparameters for the DASHA-PP family (Theorems 2-4, 7).

Every function returns the paper's admissible (a, b, gamma, ...) given the
problem constants.  Used by default in benchmarks/examples so runs are
"as suggested in theory" (paper §A), with only the stepsize optionally
finetuned over {2^i}.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Smoothness / noise constants of problem (1)."""

    L: float                       # Assumption 2 (f is L-smooth)
    L_hat: float                   # Assumption 3: sqrt(mean L_i^2)
    L_max: float = 0.0             # Assumption 4 (finite-sum), max_ij L_ij
    L_sigma: float = 0.0           # Assumption 6 (stochastic, mean-squared smooth)
    sigma: float = 0.0             # Assumption 5 variance bound
    n: int = 1
    m: int = 1                     # finite-sum size per node
    d: int = 1


@dataclasses.dataclass(frozen=True)
class Hyperparams:
    a: float                       # compressor momentum (line 11 of Alg.1)
    b: float                       # VR momentum
    gamma: float                   # stepsize
    p_page: Optional[float] = None
    batch_size: int = 1


def _one_pa_sq(p_a: float, p_aa: float) -> float:
    """1 - p_aa / p_a  (= paper's 𝟙_{p_a}^2)."""
    return 1.0 - p_aa / p_a


def dasha_pp_gradient(c: ProblemConstants, omega: float, p_a: float,
                      p_aa: float) -> Hyperparams:
    """Theorem 2 (DASHA-PP, gradient setting)."""
    a = p_a / (2 * omega + 1)
    b = p_a / (2 - p_a)
    rad = (48 * omega * (2 * omega + 1) / (c.n * p_a**2)
           + 16 / (c.n * p_a**2) * _one_pa_sq(p_a, p_aa))
    gamma = 1.0 / (c.L + math.sqrt(rad) * c.L_hat)
    return Hyperparams(a=a, b=b, gamma=gamma)


def dasha_pp_page(c: ProblemConstants, omega: float, p_a: float, p_aa: float,
                  batch_size: int, p_page: Optional[float] = None) -> Hyperparams:
    """Theorem 3 + Corollary 1 (DASHA-PP-PAGE, finite-sum setting)."""
    B = batch_size
    if p_page is None:
        p_page = B / (c.m + B)          # Corollary 1 balance
    a = p_a / (2 * omega + 1)
    b = p_page * p_a / (2 - p_a)
    t1 = (48 * omega * (2 * omega + 1) / (c.n * p_a**2)
          * (c.L_hat**2 + (1 - p_page) * c.L_max**2 / B))
    t2 = (16 / (c.n * p_a**2 * p_page)
          * (_one_pa_sq(p_a, p_aa) * c.L_hat**2
             + (1 - p_page) * c.L_max**2 / B))
    gamma = 1.0 / (c.L + math.sqrt(t1 + t2))
    return Hyperparams(a=a, b=b, gamma=gamma, p_page=p_page, batch_size=B)


def dasha_pp_finite_mvr(c: ProblemConstants, omega: float, p_a: float,
                        p_aa: float, batch_size: int) -> Hyperparams:
    """Theorem 7 (DASHA-PP-FINITE-MVR, finite-sum setting)."""
    B = batch_size
    pb = p_a * B / c.m
    a = p_a / (2 * omega + 1)
    b = pb / (2 - pb)
    t1 = (148 * omega * (2 * omega + 1) / (c.n * p_a**2)
          * (c.L_hat**2 + c.L_max**2 / B))
    t2 = (72 * c.m / (c.n * p_a**2 * B)
          * (_one_pa_sq(p_a, p_aa) * c.L_hat**2 + c.L_max**2 / B))
    gamma = 1.0 / (c.L + math.sqrt(t1 + t2))
    return Hyperparams(a=a, b=b, gamma=gamma, batch_size=B)


def dasha_pp_mvr(c: ProblemConstants, omega: float, p_a: float, p_aa: float,
                 batch_size: int, eps: Optional[float] = None) -> Hyperparams:
    """Theorem 4 + Corollary 3 (DASHA-PP-MVR, stochastic setting).

    ``b`` per Corollary 3 when eps given, else the Theorem-4 maximum
    ``p_a / (2 - p_a)``.
    """
    B = batch_size
    a = p_a / (2 * omega + 1)
    if eps is not None and c.sigma > 0:
        b = min(p_a / max(omega, 1e-12) * math.sqrt(c.n * eps * B) / c.sigma
                if omega > 0 else 1.0,
                p_a * c.n * eps * B / c.sigma**2,
                p_a / (2 - p_a))
        b = max(b, 1e-6)
    else:
        b = p_a / (2 - p_a)
    t1 = (48 * omega * (2 * omega + 1) / (c.n * p_a**2)
          * (c.L_hat**2 + (1 - b) ** 2 * c.L_sigma**2 / B))
    t2 = (12 / (c.n * p_a * b)
          * (_one_pa_sq(p_a, p_aa) * c.L_hat**2
             + (1 - b) ** 2 * c.L_sigma**2 / B))
    gamma = 1.0 / (c.L + math.sqrt(t1 + t2))
    return Hyperparams(a=a, b=b, gamma=gamma, batch_size=B)


def dasha_gradient(c: ProblemConstants, omega: float) -> Hyperparams:
    """DASHA (Alg. 6) theory params — Tyurin & Richtarik 2023: the p_a=1
    specialization of Theorem 2."""
    return dasha_pp_gradient(c, omega, p_a=1.0, p_aa=1.0)


def dasha_mvr(c: ProblemConstants, omega: float, batch_size: int) -> Hyperparams:
    """DASHA-MVR (Alg. 7) = DASHA-PP-MVR with p_a = p_aa = 1."""
    return dasha_pp_mvr(c, omega, p_a=1.0, p_aa=1.0, batch_size=batch_size)


def marina(c: ProblemConstants, omega: float) -> Hyperparams:
    """MARINA (Gorbunov et al. 2021), gradient setting:
    gamma <= (L + L_hat * sqrt((1-p)/p * omega / n))^{-1} with sync prob p."""
    p = 1.0 / (1.0 + omega)
    gamma = 1.0 / (c.L + c.L_hat * math.sqrt((1 - p) / p * omega / c.n))
    return Hyperparams(a=p, b=0.0, gamma=gamma)


def corollary2_randk_k(d: int, m: int, batch_size: int) -> int:
    """Corollary 2: RandK with K = Theta(B d / sqrt(m))."""
    return max(1, min(d, round(batch_size * d / math.sqrt(m))))


def corollary2_batch_bound(c: ProblemConstants, p_a: float, p_aa: float) -> int:
    """Corollary 2: B <= min{ (1/p_a) sqrt(m/n), L_max^2 / (1_pa^2 L_hat^2) }."""
    one_sq = _one_pa_sq(p_a, p_aa)
    b1 = math.sqrt(c.m / c.n) / p_a
    b2 = math.inf if one_sq == 0 else c.L_max**2 / (one_sq * c.L_hat**2)
    return max(1, int(min(b1, b2)))


# ----------------------------------------------------------------------
# Polyak-Lojasiewicz condition (paper Section F)
# ----------------------------------------------------------------------

def dasha_pp_pl(c: ProblemConstants, omega: float, p_a: float, p_aa: float,
                mu: float) -> "tuple[Hyperparams, float]":
    """Section F (gradient setting under the PL condition
    ||grad f(x)||^2 >= 2 mu (f(x) - f*)): same admissible (a, b, gamma)
    as Theorem 2; the Lyapunov gap then contracts linearly at
    ~(1 - Theta(gamma*mu)) per round — O(log(1/eps)/(gamma mu)) rounds.
    We return the conservative guaranteed factor 1 - gamma*mu/4 (the
    appendix-F constants are not in our copy of the text; the 1/4 slack
    absorbs the control-variate lag and is validated empirically as an
    upper bound on the observed contraction in
    tests/test_extensions.py::test_pl_linear_convergence).
    """
    hp = dasha_pp_gradient(c, omega, p_a, p_aa)
    rate = max(0.0, 1.0 - hp.gamma * mu / 4.0)
    return hp, rate


def pl_rounds_to_eps(c: ProblemConstants, omega: float, p_a: float,
                     p_aa: float, mu: float, eps: float,
                     delta0: float = 1.0) -> int:
    """O(log(delta0/eps)/(gamma mu)) communication rounds under PL."""
    hp, rate = dasha_pp_pl(c, omega, p_a, p_aa, mu)
    if rate >= 1.0:
        return 1 << 30
    return max(1, math.ceil(math.log(max(delta0 / eps, 1.0 + 1e-9))
                            / -math.log(rate)))
