"""Variant-rule layer: ONE source of truth for the ``k_i`` rules of
Algorithms 2-5 and everything a ``k_i`` rule owns.

Both engines (the ``vmap`` reference :mod:`repro.core.dasha_pp` and the
``shard_map`` production :mod:`repro.core.sharded`) consume the rules
from this registry instead of carrying private copies (DESIGN.md §8).
A :class:`VariantRule` owns:

(a) the ``k_i`` formula as a pure leaf-level function — shape
    polymorphic, so the reference engine applies it node-major ``(n, d)``
    and the sharded engine applies it to a flat local leaf ``(D,)``;
(b) which gradient oracles the step needs (full pair, same-sample
    minibatch pair, periodic full pass + shared coin, component
    scatter) — both as metadata and as ``reference_oracle`` which
    evaluates them against a :class:`~repro.core.problems.
    DistributedProblem` with the canonical randomness consumption;
(c) oracle-call and uplink-bit accounting;
(d) the matching fused-kernel dispatch (``dasha_update`` vs
    ``dasha_page_update`` vs tail-only; dense and blocks-only wire
    forms).

The MARINA / FRECON baselines are recast in the same interface
(:class:`BaselineRule`): they are not Algorithm-1 ``k_i`` rules, but
their oracle needs and accounting live here so every method the repo
compares shares one metadata/accounting source.

Randomness contract (what makes reference <-> sharded trajectory parity
possible, asserted in tests/test_sharded.py): every step splits its
round key as ``round_keys`` below — ``(k_part, k_oracle, k_comp)`` —
the participation mask comes from ``k_part`` via
:mod:`repro.core.participation`, the PAGE coin/batch keys from
``page_keys(k_oracle)``, and node ``i``'s compressor key for pytree
leaf ``li`` from ``leaf_node_key(k_comp, li, i)`` (the reference
engine's flat vector is leaf 0).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.problems import sample_batch_indices

Array = jax.Array


# ----------------------------------------------------------------------
# Randomness derivation (shared by both engines)
# ----------------------------------------------------------------------

def round_keys(key: Array, step: Optional[Array] = None
               ) -> Tuple[Array, Array, Array]:
    """The per-round key split: ``(k_part, k_oracle, k_comp)``.  The
    sharded engine passes ``step`` (its key is per-run); the reference
    engine folds the round index in before calling :meth:`DashaPP.step`
    and passes ``step=None``."""
    if step is not None:
        key = jax.random.fold_in(key, step)
    keys = jax.random.split(key, 3)
    return keys[0], keys[1], keys[2]


def page_keys(k_oracle: Array) -> Tuple[Array, Array]:
    """PAGE's oracle-key split: ``(k_coin, k_batch)``."""
    keys = jax.random.split(k_oracle)
    return keys[0], keys[1]


def page_coin(k_coin: Array, p_page: float) -> Array:
    """The shared Bernoulli switch of Alg. 3 (one coin for all nodes)."""
    return jax.random.bernoulli(k_coin, p_page)


def leaf_node_key(k_comp: Array, leaf_idx: int, node_idx) -> Array:
    """Node ``node_idx``'s compressor key for pytree leaf ``leaf_idx``
    (Assumption 7: independent across nodes).  The reference engine's
    flat parameter vector is leaf 0."""
    return jax.random.fold_in(jax.random.fold_in(k_comp, leaf_idx),
                              node_idx)


# ----------------------------------------------------------------------
# Pure k_i formulas (Alg. 1 line 9, one per sub-algorithm)
# ----------------------------------------------------------------------

def k_same_sample(gn: Array, go: Array, h: Array, *, b: float) -> Array:
    """Algs. 2/5 share one formula: ``k = gn - go - b (h - go)`` with
    ``gn/go`` the full (Alg. 2) vs same-sample minibatch (Alg. 5)
    gradients at ``x^{t+1}`` / ``x^t``.  Shape-polymorphic."""
    return gn - go - b * (h - go)


def k_page(gn: Array, go: Array, bn: Array, bo: Array, h: Array,
           coin: Array, *, b: float, p_page: float) -> Array:
    """Alg. 3: with probability ``p_page`` (shared ``coin``) the
    full-gradient branch ``gn - go - (b/p_page)(h - go)``, else the
    minibatch branch ``bn - bo``."""
    k_full = gn - go - (b / p_page) * (h - go)
    k_mini = bn - bo
    return jnp.where(jnp.asarray(coin).astype(bool), k_full, k_mini)


def k_finite_mvr_components(gn_sel: Array, go_sel: Array, h_sel: Array,
                            idx: Array, m: int, *, b: float) -> Array:
    """Alg. 4, single node: component gradients at the ``B`` selected
    indices -> the ``(m, d)`` component update ``k_ij`` (zero at
    unselected components).  The reference engine vmaps this over
    nodes; the sharded engine applies it per local leaf."""
    B = gn_sel.shape[0]
    k_sel = (m / B) * (gn_sel - go_sel - b * (h_sel - go_sel))
    zeros = jnp.zeros((m,) + gn_sel.shape[1:], gn_sel.dtype)
    return zeros.at[idx].set(k_sel)


def control_variate_tail(k: Array, h: Array, g_i: Array, *, a: float,
                         pa: float, part) -> Array:
    """Alg. 1 lines 10-11 given ``k``: the tracker step and the uplink
    payload.  ``part`` is the participation indicator, broadcastable to
    ``k`` (scalar for a flat leaf, ``(n, 1)`` node-major)."""
    h_new = h + part * (k / pa)
    payload = k / pa - (a / pa) * (g_i - h)
    return h_new, payload


# ----------------------------------------------------------------------
# BlockRandK wire helpers (TPU adaptation of RandK, DESIGN.md §3)
# ----------------------------------------------------------------------

def _pad_to(x: Array, mult: int) -> Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, (0, pad)) if pad else x


def block_plan(d: int, block_size: int, ratio: float
               ) -> Tuple[int, int, int]:
    """The (effective block size, #blocks, #selected blocks) of a
    ``d``-vector under compression ``ratio`` — the single place this
    arithmetic lives so engines, compressors, and accounting agree."""
    bs = min(block_size, d)
    nb = -(-d // bs)
    kb = max(1, math.ceil(ratio * nb))
    return bs, nb, kb


def block_randk_indices(key: Array, nb: int, k_blocks: int) -> Array:
    """The BlockRandK draw: ``k_blocks`` of ``nb`` blocks u.a.r. without
    replacement.  Single source of truth — the fused Pallas paths must
    consume randomness identically to the jnp path for trajectory
    parity."""
    return jax.random.permutation(key, nb)[:k_blocks]


def block_randk_select(key: Array, flat: Array, k_blocks: int,
                       block_size: int) -> Tuple[Array, Array]:
    """Choose ``k_blocks`` of the ``nb`` blocks u.a.r. without replacement.
    Returns (values (k_blocks, block_size) scaled by nb/k_blocks,
    block_idx (k_blocks,))."""
    padded = _pad_to(flat, block_size)
    nb = padded.shape[0] // block_size
    blocks = padded.reshape(nb, block_size)
    idx = block_randk_indices(key, nb, k_blocks)
    scale = nb / k_blocks
    return blocks[idx] * scale, idx


def block_scatter_add(base_flat: Array, vals: Array, block_idx: Array,
                      block_size: int) -> Array:
    """base += scatter(vals at block_idx); shapes per block_randk_select.
    ``vals``/``block_idx`` may carry a leading nodes dim."""
    padded = _pad_to(base_flat, block_size)
    nb = padded.shape[0] // block_size
    blocks = padded.reshape(nb, block_size)
    vals2 = vals.reshape(-1, block_size)
    idx2 = block_idx.reshape(-1)
    blocks = blocks.at[idx2].add(vals2)
    return blocks.reshape(-1)[: base_flat.shape[0]]


def block_randk_dense(key: Array, flat: Array, k_blocks: int,
                      block_size: int) -> Array:
    """Dense output of BlockRandK (used by the dense_psum + compressed
    combination, the :class:`~repro.core.compressors.BlockRandK`
    reference compressor, and tests)."""
    vals, idx = block_randk_select(key, flat, k_blocks, block_size)
    return block_scatter_add(jnp.zeros_like(flat), vals, idx, block_size)


# ----------------------------------------------------------------------
# Uplink accounting (aggregation-aware)
# ----------------------------------------------------------------------

FLOAT_BITS = 32.0
INDEX_BITS = 32.0

WIRE_FORMATS = ("block_randk", "topk", "dithering")


def message_bits(d: int, *, aggregation: str,
                 compression_ratio: Optional[float],
                 block_size: int, wire_format: str = "block_randk",
                 dithering_levels: int = 4) -> float:
    """Uplink bits one participating node pays to send one ``d``-leaf
    message.  Only ``sparse_allgather`` has a compressed wire format:
    ``dense_psum`` all-reduces *dense* vectors (the BlockRandK zeros
    still cross the wire) and ``compression_ratio=None`` is the
    uncompressed baseline.  Wire formats (``ShardedDashaConfig.
    wire_format``):

    * ``block_randk`` — kb blocks of (bs values + 1 index);
    * ``topk``        — ceil(ratio*d) coordinate (value, index) pairs;
    * ``dithering``   — dense but quantized: one ||x|| float plus
      sign+level bits per coordinate (the ratio is ignored — the
      saving is bits-per-coordinate, not sparsity).
    """
    if compression_ratio is None or aggregation != "sparse_allgather":
        return d * FLOAT_BITS
    if wire_format == "dithering":
        return FLOAT_BITS + d * (
            1 + math.ceil(math.log2(dithering_levels + 1)))
    if wire_format == "topk":
        k = max(1, math.ceil(compression_ratio * d))
        return k * (FLOAT_BITS + INDEX_BITS)
    bs, _, kb = block_plan(d, block_size, compression_ratio)
    return kb * (bs * FLOAT_BITS + INDEX_BITS)


def uplink_bits_per_node(d_total: int, *, aggregation: str,
                         compression_ratio: Optional[float],
                         block_size: int, p_a: float = 1.0,
                         wire_format: str = "block_randk",
                         dithering_levels: int = 4) -> float:
    """Expected uplink bits per node per round (Tables 1-2 metric):
    a node participates with probability ``p_a`` and then pays
    :func:`message_bits`."""
    return p_a * message_bits(d_total, aggregation=aggregation,
                              compression_ratio=compression_ratio,
                              block_size=block_size,
                              wire_format=wire_format,
                              dithering_levels=dithering_levels)


# ----------------------------------------------------------------------
# Oracle inputs (what a k_i rule consumes, per leaf or node-major)
# ----------------------------------------------------------------------

class OracleBatch(NamedTuple):
    """Evaluated gradient-oracle inputs for one step.  Which fields are
    set depends on the rule: gradient/mvr use ``(gn, go)`` (full vs
    same-sample minibatch pair), page adds ``(bn, bo, coin)``,
    finite_mvr carries the pre-scattered ``k`` (its dense elementwise
    shape is the scatter output, not an oracle pair)."""
    gn: Any = None
    go: Any = None
    bn: Any = None
    bo: Any = None
    coin: Any = None
    k: Any = None


# ----------------------------------------------------------------------
# The rules
# ----------------------------------------------------------------------

class VariantRule:
    """One Algorithm-2..5 sub-algorithm: metadata + pure math + oracle
    plan + fused-kernel dispatch.  Stateless; registered in
    :data:`VARIANTS`."""

    name: str = ""
    algorithm: str = ""
    oracle: str = ""                   # human-readable oracle needs
    needs_coin: bool = False           # shared Bernoulli switch (page)
    needs_minibatch: bool = False      # second (minibatch) gradient pair
    component_trackers: bool = False   # (n, m, d) h_ij state (finite_mvr)
    trainer_supported: bool = True     # runs in training/trainer.py

    # -- (a) the k_i formula ------------------------------------------
    def k(self, ox: OracleBatch, h: Array, *, b: float,
          p_page: float = 1.0) -> Array:
        raise NotImplementedError

    # -- (c) oracle accounting ----------------------------------------
    def oracle_calls(self, n: int, m: int, batch_size: Optional[int] = None,
                     coin=None) -> Array:
        raise NotImplementedError

    # -- (b) the oracle plan against a DistributedProblem -------------
    def reference_oracle(self, key, problem, cfg, x_new, x_old, state
                         ) -> Tuple[OracleBatch, Optional[Array], Array]:
        """Evaluate the oracles the rule needs, consuming randomness
        canonically.  Returns ``(ox, k_ij or None, oracle_calls)``."""
        raise NotImplementedError

    # -- (d) fused-kernel dispatch ------------------------------------
    def fused_batched(self, ox: OracleBatch, h, gi, mask, *, b, a, pa,
                      p_page: float = 1.0, interpret=None):
        """Node-major (n, d) fused update -> (k, h_new, payload)."""
        raise NotImplementedError

    def fused_flat(self, ox: OracleBatch, h, gi, part, *, b, a, pa,
                   p_page: float = 1.0, interpret=None):
        """Flat (D,) fused update -> (h_new, payload)."""
        raise NotImplementedError

    def fused_flat_blocks(self, ox: OracleBatch, h, gi, part, block_idx,
                          *, b, a, pa, scale, block_size,
                          p_page: float = 1.0, interpret=None):
        """Flat sparse-wire split -> (h_new, wire values at the selected
        blocks, pre-scaled)."""
        raise NotImplementedError


class GradientRule(VariantRule):
    name = "gradient"
    algorithm = "Alg. 2 (DASHA-PP)"
    oracle = "full local gradients at x^{t+1} and x^t"

    def k(self, ox, h, *, b, p_page=1.0):
        return k_same_sample(ox.gn, ox.go, h, b=b)

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        return jnp.asarray(2 * m * n)

    def reference_oracle(self, key, problem, cfg, x_new, x_old, state):
        del key, state
        ox = OracleBatch(gn=problem.grad(x_new), go=problem.grad(x_old))
        return ox, None, self.oracle_calls(problem.n, problem.m)

    def fused_batched(self, ox, h, gi, mask, *, b, a, pa, p_page=1.0,
                      interpret=None):
        from repro.kernels import ops
        return ops.dasha_update_batched_op(ox.gn, ox.go, h, gi, mask,
                                           b=b, a=a, pa=pa,
                                           interpret=interpret)

    def fused_flat(self, ox, h, gi, part, *, b, a, pa, p_page=1.0,
                   interpret=None):
        from repro.kernels import ops
        _, h_new, payload = ops.dasha_update_op(
            ox.gn, ox.go, h, gi, b=b, a=a, pa=pa, participates=part,
            interpret=interpret)
        return h_new, payload

    def fused_flat_blocks(self, ox, h, gi, part, block_idx, *, b, a, pa,
                          scale, block_size, p_page=1.0, interpret=None):
        from repro.kernels import ops
        h_new = ops.dasha_h_update_op(ox.gn, ox.go, h, b=b, pa=pa,
                                      participates=part,
                                      interpret=interpret)
        vals = ops.dasha_payload_blocks_op(
            ox.gn, ox.go, h, gi, block_idx, b=b, a=a, pa=pa, scale=scale,
            block_size=block_size, interpret=interpret)
        return h_new, vals


class MvrRule(GradientRule):
    name = "mvr"
    algorithm = "Alg. 5 (DASHA-PP-MVR)"
    oracle = "same-sample minibatch gradient pair at x^{t+1} and x^t"

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        return jnp.asarray(2 * batch_size * n)

    def reference_oracle(self, key, problem, cfg, x_new, x_old, state):
        del state
        idx = sample_batch_indices(key, problem.n, problem.m,
                                   cfg.batch_size, replace=True)
        ox = OracleBatch(gn=problem.batch_grad(x_new, idx),
                         go=problem.batch_grad(x_old, idx))
        return ox, None, self.oracle_calls(problem.n, problem.m,
                                           cfg.batch_size)


class PageRule(VariantRule):
    name = "page"
    algorithm = "Alg. 3 (DASHA-PP-PAGE)"
    oracle = ("periodic full pass (shared coin, prob. p_page) + "
              "same-sample minibatch pair")
    needs_coin = True
    needs_minibatch = True

    def k(self, ox, h, *, b, p_page):
        return k_page(ox.gn, ox.go, ox.bn, ox.bo, h, ox.coin,
                      b=b, p_page=p_page)

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        return jnp.where(coin, 2 * m * n, 2 * batch_size * n)

    def reference_oracle(self, key, problem, cfg, x_new, x_old, state):
        del state
        k_coin, k_batch = page_keys(key)
        coin = page_coin(k_coin, cfg.p_page)
        idx = sample_batch_indices(k_batch, problem.n, problem.m,
                                   cfg.batch_size, replace=cfg.replace)
        ox = OracleBatch(gn=problem.grad(x_new), go=problem.grad(x_old),
                         bn=problem.batch_grad(x_new, idx),
                         bo=problem.batch_grad(x_old, idx), coin=coin)
        return ox, None, self.oracle_calls(problem.n, problem.m,
                                           cfg.batch_size, coin)

    def fused_batched(self, ox, h, gi, mask, *, b, a, pa, p_page=1.0,
                      interpret=None):
        from repro.kernels import ops
        return ops.dasha_page_update_op(ox.gn, ox.go, ox.bn, ox.bo, h, gi,
                                        mask, ox.coin, b=b, a=a, pa=pa,
                                        p_page=p_page, interpret=interpret)

    def fused_flat(self, ox, h, gi, part, *, b, a, pa, p_page=1.0,
                   interpret=None):
        from repro.kernels import ops
        ins = [x[None] for x in (ox.gn, ox.go, ox.bn, ox.bo, h, gi)]
        _, h_new, payload = ops.dasha_page_update_op(
            *ins, jnp.reshape(part, (1,)), ox.coin, b=b, a=a, pa=pa,
            p_page=p_page, interpret=interpret)
        return h_new[0], payload[0]

    def fused_flat_blocks(self, ox, h, gi, part, block_idx, *, b, a, pa,
                          scale, block_size, p_page=1.0, interpret=None):
        from repro.kernels import ops
        h_new = ops.dasha_page_h_update_op(
            ox.gn, ox.go, ox.bn, ox.bo, h, ox.coin, b=b, pa=pa,
            p_page=p_page, participates=part, interpret=interpret)
        vals = ops.dasha_page_payload_blocks_op(
            ox.gn, ox.go, ox.bn, ox.bo, h, gi, block_idx, ox.coin,
            b=b, a=a, pa=pa, p_page=p_page, scale=scale,
            block_size=block_size, interpret=interpret)
        return h_new, vals


class FiniteMvrRule(VariantRule):
    name = "finite_mvr"
    algorithm = "Alg. 4 (DASHA-PP-FINITE-MVR)"
    oracle = ("component gradient pair at a without-replacement "
              "minibatch, scattered over (m,) trackers")
    component_trackers = True
    # Needs per-component trackers h_ij of shape (n, m, *param): the LM
    # trainer treats each node's (fixed) batch examples as the m
    # components and threads (n, B, *param) per-example gradients +
    # component_idx through the engine (training/trainer.py).
    trainer_supported = True

    def k(self, ox, h, *, b, p_page=1.0):
        return ox.k

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        return jnp.asarray(2 * batch_size * n)

    def reference_oracle(self, key, problem, cfg, x_new, x_old, state):
        B, m = cfg.batch_size, problem.m
        idx = sample_batch_indices(key, problem.n, m, B,
                                   replace=False)   # Alg. 4: w/o repl.
        gn = problem.component_grads(x_new, idx)     # (n, B, d)
        go = problem.component_grads(x_old, idx)
        h_sel = jnp.take_along_axis(state.h_ij, idx[..., None], axis=1)
        k_ij = jax.vmap(
            lambda a_, b_, c_, i_: k_finite_mvr_components(
                a_, b_, c_, i_, m, b=cfg.b))(gn, go, h_sel, idx)
        ox = OracleBatch(k=jnp.mean(k_ij, axis=1))
        return ox, k_ij, self.oracle_calls(problem.n, m, B)

    def fused_batched(self, ox, h, gi, mask, *, b, a, pa, p_page=1.0,
                      interpret=None):
        from repro.kernels import ops
        h_new, payload = ops.dasha_tail_op(ox.k, h, gi, mask, a=a, pa=pa,
                                           interpret=interpret)
        return ox.k, h_new, payload

    def fused_flat(self, ox, h, gi, part, *, b, a, pa, p_page=1.0,
                   interpret=None):
        from repro.kernels import ops
        h_new, payload = ops.dasha_tail_op(
            ox.k[None], h[None], gi[None], jnp.reshape(part, (1,)),
            a=a, pa=pa, interpret=interpret)
        return h_new[0], payload[0]

    def fused_flat_blocks(self, ox, h, gi, part, block_idx, *, b, a, pa,
                          scale, block_size, p_page=1.0, interpret=None):
        # k_i comes from the component scatter and is already dense, so
        # the payload has no never-materialize win: fuse the tail, then
        # gather the selected blocks (kernel gather, DESIGN.md §8).
        from repro.kernels import ops
        h_new, payload = self.fused_flat(ox, h, gi, part, b=b, a=a, pa=pa,
                                         interpret=interpret)
        padded = _pad_to(payload, block_size)
        blocks = padded.reshape(-1, block_size)
        vals = ops.block_gather_op(blocks, block_idx, scale=scale,
                                   interpret=interpret)
        return h_new, vals


# ----------------------------------------------------------------------
# Baselines recast in the same interface (metadata + accounting only)
# ----------------------------------------------------------------------

class BaselineRule:
    """MARINA / FRECON are not Algorithm-1 ``k_i`` rules, but they share
    the registry so method comparisons draw oracle needs and accounting
    from one place."""

    name: str = ""
    algorithm: str = ""
    oracle: str = ""
    variance_reduced: bool = False
    supports_pp: bool = False

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        raise NotImplementedError

    def round_bits(self, n, d, n_part, wire_bits, sync=None):
        raise NotImplementedError


class MarinaRule(BaselineRule):
    name = "marina"
    algorithm = "MARINA (Gorbunov et al., 2021)"
    oracle = ("local gradient pair; full uncompressed gradients from "
              "ALL nodes on sync rounds (no PP there)")
    variance_reduced = True       # compressor variance only
    supports_pp = False           # sync rounds require full participation

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        if batch_size is None:
            return jnp.asarray(2 * m * n)
        return jnp.where(coin, m * n + batch_size * n,
                         2 * batch_size * n)

    def round_bits(self, n, d, n_part, wire_bits, sync=None):
        return jnp.where(sync, n * FLOAT_BITS * d, n_part * wire_bits)


class FreconRule(BaselineRule):
    name = "frecon"
    algorithm = "FRECON (Zhao et al., 2021a)"
    oracle = "one (mini-batch) gradient per sampled client per round"
    variance_reduced = False      # no local stochastic-gradient VR
    supports_pp = True

    def oracle_calls(self, n, m, batch_size=None, coin=None):
        return jnp.asarray((m if batch_size is None else batch_size) * n)

    def round_bits(self, n, d, n_part, wire_bits, sync=None):
        return n_part * wire_bits


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

VARIANTS = {r.name: r for r in
            (GradientRule(), PageRule(), FiniteMvrRule(), MvrRule())}
BASELINES = {r.name: r for r in (MarinaRule(), FreconRule())}
RULES = {**VARIANTS, **BASELINES}


def get_rule(name: str) -> VariantRule:
    """The Algorithm-2..5 rule registered under ``name``."""
    try:
        return VARIANTS[name]
    except KeyError:
        raise ValueError(
            f"unknown variant {name!r}; choose from {sorted(VARIANTS)}"
        ) from None


def get_baseline(name: str) -> BaselineRule:
    try:
        return BASELINES[name]
    except KeyError:
        raise ValueError(
            f"unknown baseline {name!r}; choose from {sorted(BASELINES)}"
        ) from None
