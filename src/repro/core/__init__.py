"""repro.core — the paper's contribution: the DASHA-PP method family.

Layout:
    compressors.py    unbiased/biased communication compressors (Def. 1)
    participation.py  Assumption-8 participation samplers
    variants.py       the k_i rule registry (Algs. 2-5) both engines share
    problems.py       distributed problems (paper §A experiments)
    theory.py         theorem-exact hyperparameters
    dasha_pp.py       Algorithm 1 (+ Algs. 2-5) and DASHA baselines
    marina.py         MARINA baseline
    frecon.py         FRECON baseline
    sharded.py        SPMD production runtime (shard_map over the mesh)
    sync_mvr.py       DASHA-PP-SYNC-MVR (appendix G)
"""
from repro.core.compressors import (BlockRandK, Composed, Compressor,
                                    Identity, NaturalCompression, RandK,
                                    RandomDithering, TopK, make_compressor,
                                    randk_for_ratio)
from repro.core.dasha_pp import (DashaPP, DashaPPConfig, DashaPPState,
                                 StepMetrics, dasha, dasha_mvr, dasha_page,
                                 dasha_pp, dasha_pp_finite_mvr, dasha_pp_mvr,
                                 dasha_pp_page)
from repro.core.frecon import Frecon, FreconConfig
from repro.core.marina import Marina, MarinaConfig
from repro.core.participation import (FullParticipation, Independent,
                                      ParticipationSampler, SNice,
                                      make_sampler)
from repro.core.problems import (DistributedProblem, LogisticSigmoidProblem,
                                 NonconvexSoftmaxProblem, QuadraticProblem,
                                 make_synthetic_classification,
                                 sample_batch_indices)
from repro.core.sync_mvr import DashaPPSyncMVR, SyncMVRConfig, dasha_pp_sync_mvr
from repro.core import theory, variants, wire
from repro.core.variants import (BaselineRule, VariantRule, get_baseline,
                                 get_rule)

__all__ = [
    "Compressor", "Identity", "RandK", "BlockRandK", "TopK",
    "NaturalCompression",
    "RandomDithering", "Composed", "make_compressor", "randk_for_ratio",
    "ParticipationSampler", "SNice", "Independent", "FullParticipation",
    "make_sampler",
    "DistributedProblem", "LogisticSigmoidProblem", "NonconvexSoftmaxProblem",
    "QuadraticProblem", "make_synthetic_classification",
    "sample_batch_indices",
    "DashaPP", "DashaPPConfig", "DashaPPState", "StepMetrics",
    "dasha", "dasha_mvr", "dasha_page", "dasha_pp", "dasha_pp_page",
    "dasha_pp_finite_mvr", "dasha_pp_mvr",
    "Marina", "MarinaConfig", "Frecon", "FreconConfig",
    "DashaPPSyncMVR", "SyncMVRConfig", "dasha_pp_sync_mvr",
    "theory", "variants", "wire",
    "VariantRule", "BaselineRule", "get_rule", "get_baseline",
]
