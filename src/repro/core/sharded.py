"""SPMD production runtime for DASHA-PP on TPU meshes.

Mapping (DESIGN.md §3, §5): one *node* of the paper = one slice of the
``data`` mesh axes (``("data",)`` single-pod, ``("pod", "data")``
multi-pod).  The parameter server is an abstraction realized by
collectives over those axes.

Pieces:

* :func:`per_node_value_and_grads` — per-node gradients (no cross-node
  mean!) via ``vmap(value_and_grad)`` over an explicit node dimension of
  the batch; runs under GSPMD so the ``model`` axis (tensor/expert
  parallelism) needs no manual collectives.
* :class:`ShardedDasha` — the Algorithm-1 node/server update as a
  ``shard_map`` over the data axes.  Per-node control variates ``h_i,
  g_i`` are param-shaped arrays with a leading node dimension sharded
  over the data axes (each device stores only its own node's variates:
  no replication).
* Aggregation modes:
    - ``dense_psum``       — uncompressed baseline: ``psum`` of dense
      messages over the data axes (bytes ∝ d).
    - ``sparse_allgather`` — RandK/BlockRandK wire format: all-gather of
      ``(values, block indices)`` (bytes ∝ n·K ≪ n·d) + local
      scatter-add.  This is the paper's communication saving made
      visible to the roofline.
* **BlockRandK** (TPU adaptation, DESIGN.md §3): RandK at (128,)-block
  granularity — blocks partition coordinates, so choosing ``K/bs`` of
  ``D/bs`` blocks uniformly without replacement and scaling by ``D/K``
  is unbiased with exactly the Definition-1 bound ``omega = D/K - 1``
  (blocks are super-coordinates).  Avoids a full-length sort/gather per
  step and keeps lane-aligned memory access.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

Array = jax.Array
PyTree = Any


# ----------------------------------------------------------------------
# Per-node gradients
# ----------------------------------------------------------------------

def per_node_value_and_grads(loss_fn: Callable, params: PyTree,
                             batch: PyTree, *args) -> Tuple[Array, PyTree]:
    """``loss_fn(params, node_batch, *args) -> scalar``; ``batch`` leaves
    carry a leading node dimension.  Returns ``(losses (n,), grads)`` with
    grad leaves shaped ``(n, *param_shape)`` — the *unreduced* per-node
    gradients the DASHA-PP update consumes."""
    vg = jax.value_and_grad(loss_fn)
    in_axes = (None, 0) + tuple(None for _ in args)
    return jax.vmap(vg, in_axes=in_axes)(params, batch, *args)


# ----------------------------------------------------------------------
# Config / state
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedDashaConfig:
    gamma: float
    a: float                       # compressor momentum (Alg.1 line 11)
    b: float                       # VR momentum (Algs. 2/5 share one formula)
    p_a: float = 1.0
    sampler: str = "independent"   # independent | s_nice | full
    compression_ratio: Optional[float] = 0.01   # K/D; None => identity
    block_size: int = 128          # BlockRandK block (TPU lane width)
    aggregation: str = "sparse_allgather"       # or dense_psum
    data_axes: Tuple[str, ...] = ("data",)
    # Dispatch the fused Pallas update path (kernels/, DESIGN.md §6) in
    # every aggregation mode.  sparse_allgather additionally fuses
    # BlockRandK into the update: the line-11 payload is evaluated only
    # at the selected blocks, never dense in HBM.  On CPU the kernels
    # run in interpret mode automatically (kernels/ops.py).
    use_pallas: bool = False
    # Force interpret mode on/off; None = auto (interpret unless TPU).
    pallas_interpret: Optional[bool] = None

    @property
    def compressed(self) -> bool:
        return (self.compression_ratio is not None
                and self.aggregation == "sparse_allgather")


class ShardedDashaState(NamedTuple):
    g: PyTree      # server estimator, sharded like params
    g_i: PyTree    # per-node estimators, leading node dim over data axes
    h_i: PyTree    # per-node gradient trackers, same layout
    step: Array


def _num_nodes(mesh: Mesh, data_axes: Sequence[str]) -> int:
    return int(math.prod(mesh.shape[a] for a in data_axes))


def node_spec(param_spec: P, data_axes: Sequence[str]) -> P:
    """Spec for a per-node array: prepend the (tuple of) node axes and
    strip them from the param dims (a per-node array cannot FSDP over the
    axis that indexes nodes)."""
    lead = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)

    def strip(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return None if entry in data_axes else entry
        kept = tuple(a for a in entry if a not in data_axes)
        return kept if kept else None

    return P(lead, *(strip(e) for e in param_spec))


def estimator_spec(param_spec: P, data_axes: Sequence[str]) -> P:
    """Spec for the server estimator g: like params but never sharded over
    the node axes (every node must see the full (model-sharded) g)."""

    def strip(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return None if entry in data_axes else entry
        kept = tuple(a for a in entry if a not in data_axes)
        return kept if kept else None

    return P(*(strip(e) for e in param_spec))


# ----------------------------------------------------------------------
# BlockRandK helpers (operate on a flat local vector inside shard_map)
# ----------------------------------------------------------------------

def _pad_to(x: Array, mult: int) -> Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, (0, pad)) if pad else x


def block_randk_indices(key: Array, nb: int, k_blocks: int) -> Array:
    """The BlockRandK draw: ``k_blocks`` of ``nb`` blocks u.a.r. without
    replacement.  Single source of truth — the fused Pallas paths must
    consume randomness identically to the jnp path for trajectory
    parity."""
    return jax.random.permutation(key, nb)[:k_blocks]


def block_randk_select(key: Array, flat: Array, k_blocks: int,
                       block_size: int) -> Tuple[Array, Array]:
    """Choose ``k_blocks`` of the ``nb`` blocks u.a.r. without replacement.
    Returns (values (k_blocks, block_size) scaled by nb/k_blocks,
    block_idx (k_blocks,))."""
    padded = _pad_to(flat, block_size)
    nb = padded.shape[0] // block_size
    blocks = padded.reshape(nb, block_size)
    idx = block_randk_indices(key, nb, k_blocks)
    scale = nb / k_blocks
    return blocks[idx] * scale, idx


def block_scatter_add(base_flat: Array, vals: Array, block_idx: Array,
                      block_size: int) -> Array:
    """base += scatter(vals at block_idx); shapes per block_randk_select.
    ``vals``/``block_idx`` may carry a leading nodes dim."""
    padded = _pad_to(base_flat, block_size)
    nb = padded.shape[0] // block_size
    blocks = padded.reshape(nb, block_size)
    vals2 = vals.reshape(-1, block_size)
    idx2 = block_idx.reshape(-1)
    blocks = blocks.at[idx2].add(vals2)
    return blocks.reshape(-1)[: base_flat.shape[0]]


def block_randk_dense(key: Array, flat: Array, k_blocks: int,
                      block_size: int) -> Array:
    """Dense output of BlockRandK (used by the dense_psum + compressed
    combination and by tests as the oracle wire-format-free form)."""
    vals, idx = block_randk_select(key, flat, k_blocks, block_size)
    return block_scatter_add(jnp.zeros_like(flat), vals, idx, block_size)


# ----------------------------------------------------------------------
# The sharded DASHA-PP engine
# ----------------------------------------------------------------------

class ShardedDasha:
    """Algorithm 1 over a mesh.  Usage::

        engine = ShardedDasha(mesh, param_specs, cfg)
        state  = engine.init(grads_like)       # under jit, sharded
        params_new = engine.server_step(params, state)   # x - gamma g
        state = engine.node_update(gn, go, state, key)   # lines 7-19
    """

    def __init__(self, mesh: Mesh, param_specs: PyTree,
                 cfg: ShardedDashaConfig):
        self.mesh = mesh
        self.param_specs = param_specs
        self.cfg = cfg
        self.n_nodes = _num_nodes(mesh, cfg.data_axes)

    # -- state ----------------------------------------------------------
    def init(self, grads0: PyTree) -> ShardedDashaState:
        """Paper line 2 / Theorem 2: g_i^0 = h_i^0 = ∇f_i(x^0); the server
        holds g^0 = mean_i g_i^0.  ``grads0`` = per-node grads (n, *shape)."""
        g0 = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads0)
        return ShardedDashaState(
            g=g0, g_i=grads0, h_i=grads0,
            step=jnp.zeros((), jnp.int32))

    def init_zero(self, params: PyTree) -> ShardedDashaState:
        """Zero-initialized variant (g_i^0 = h_i^0 = 0) — admissible for
        MVR (Theorem 4 allows any h^0; adds a transient O(||∇f(x^0)||²/bT)
        term).  Cheaper when an extra init pass is undesirable."""
        zeros_node = jax.tree.map(
            lambda p: jnp.zeros((self.n_nodes,) + p.shape, p.dtype), params)
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return ShardedDashaState(g=zeros, g_i=zeros_node, h_i=zeros_node,
                                 step=jnp.zeros((), jnp.int32))

    # -- server ----------------------------------------------------------
    def server_step(self, params: PyTree, state: ShardedDashaState) -> PyTree:
        """Line 5: x^{t+1} = x^t - gamma * g^t (g is replicated over data)."""
        return jax.tree.map(
            lambda p, g: (p - self.cfg.gamma * g.astype(p.dtype)),
            params, state.g)

    # -- participation ----------------------------------------------------
    def _participates(self, key: Array, node_idx: Array) -> Array:
        cfg = self.cfg
        if cfg.sampler == "full" or cfg.p_a >= 1.0:
            return jnp.ones((), bool)
        if cfg.sampler == "independent":
            return jax.random.bernoulli(jax.random.fold_in(key, node_idx),
                                        cfg.p_a)
        if cfg.sampler == "s_nice":
            s = max(1, round(cfg.p_a * self.n_nodes))
            perm = jax.random.permutation(key, self.n_nodes)
            return perm[node_idx] < s
        raise ValueError(f"unknown sampler {self.cfg.sampler!r}")

    # -- node + aggregation ------------------------------------------------
    def node_update(self, grads_new: PyTree, grads_old: PyTree,
                    state: ShardedDashaState, key: Array
                    ) -> ShardedDashaState:
        """Lines 7-19 of Algorithm 1 as a shard_map over the data axes.

        ``grads_new/old`` leaves: (n_nodes, *param_shape) — per-node
        (stochastic) gradients at x^{t+1} and x^t with the same sample
        (Alg. 5 / Alg. 2 share the k_i formula ``gn - go - b (h - go)``).
        """
        cfg = self.cfg
        data_axes = cfg.data_axes
        lead = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)

        node_specs = jax.tree.map(lambda s: node_spec(s, data_axes),
                                  self.param_specs,
                                  is_leaf=lambda x: isinstance(x, P))
        est_specs = jax.tree.map(lambda s: estimator_spec(s, data_axes),
                                 self.param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
        in_specs = (node_specs, node_specs, node_specs, node_specs,
                    est_specs, P(), P())
        out_specs = (node_specs, node_specs, est_specs)

        def update(gn, go, h_i, g_i, g, key, step):
            # Inside shard_map: leaves of gn/go/h_i/g_i are (1, *local);
            # g leaves are (*local) replicated over data axes.
            node_idx = jax.lax.axis_index(data_axes) if len(data_axes) > 1 \
                else jax.lax.axis_index(data_axes[0])
            step_key = jax.random.fold_in(key, step)
            part = self._participates(step_key, node_idx)
            partf = part.astype(jnp.float32)
            pa = cfg.p_a

            leaves_gn, treedef = jax.tree.flatten(gn)
            leaves_go = jax.tree.leaves(go)
            leaves_h = jax.tree.leaves(h_i)
            leaves_gi = jax.tree.leaves(g_i)
            leaves_g = jax.tree.leaves(g)

            new_h, new_gi, new_g = [], [], []
            for li, (tn, to, th, tgi, tg) in enumerate(zip(
                    leaves_gn, leaves_go, leaves_h, leaves_gi, leaves_g)):
                fn = tn[0].reshape(-1).astype(jnp.float32)
                fo = to[0].reshape(-1).astype(jnp.float32)
                fh = th[0].reshape(-1).astype(jnp.float32)
                fgi = tgi[0].reshape(-1).astype(jnp.float32)
                fg = tg.reshape(-1).astype(jnp.float32)

                lkey = jax.random.fold_in(
                    jax.random.fold_in(step_key, 7919 + li), node_idx)
                interp = cfg.pallas_interpret

                def dense_update():
                    """Lines 9-11 over the full local vector: fused
                    kernel or the five-pass jnp chain."""
                    if cfg.use_pallas:
                        from repro.kernels.ops import dasha_update_op
                        _, hn, pay = dasha_update_op(
                            fn, fo, fh, fgi, b=cfg.b, a=cfg.a, pa=pa,
                            participates=partf, interpret=interp)
                        return hn, pay
                    # Alg.2/5: k = gn - go - b (h - go)
                    k_vec = fn - fo - cfg.b * (fh - fo)
                    # line 10: h += k/pa if participating
                    hn = fh + partf * (k_vec / pa)
                    # line 11 payload: k/pa - (a/pa)(g_i - h_old)
                    pay = k_vec / pa - (cfg.a / pa) * (fgi - fh)
                    return hn, pay

                if cfg.compression_ratio is None:
                    fh_new, payload = dense_update()
                    m_i = partf * payload
                    total = jax.lax.psum(m_i, data_axes)
                    delta = total / self.n_nodes
                    fgi_new = fgi + m_i
                elif cfg.aggregation == "dense_psum":
                    bs = min(cfg.block_size, fn.shape[0])
                    nb = -(-fn.shape[0] // bs)
                    kb = max(1, math.ceil(cfg.compression_ratio * nb))
                    # Fused update (dense_update); the compress step is
                    # already dense here, so BlockRandK has no traffic
                    # to save and stays jnp in both paths.
                    fh_new, payload = dense_update()
                    m_i = partf * block_randk_dense(lkey, payload, kb, bs)
                    total = jax.lax.psum(m_i, data_axes)
                    delta = total / self.n_nodes
                    fgi_new = fgi + m_i
                else:  # sparse_allgather — the communication saving
                    bs = min(cfg.block_size, fn.shape[0])
                    nb = -(-fn.shape[0] // bs)
                    kb = max(1, math.ceil(cfg.compression_ratio * nb))
                    if cfg.use_pallas:
                        # Fused update+compress (DESIGN.md §6): the h
                        # tracker gets its own dense pass (k stays
                        # in-register) and the line-11 payload is
                        # evaluated ONLY at the kb selected blocks —
                        # the dense payload never exists in HBM.
                        from repro.kernels.ops import (
                            dasha_h_update_op, dasha_payload_blocks_op)
                        bidx = block_randk_indices(lkey, nb, kb)
                        fh_new = dasha_h_update_op(
                            fn, fo, fh, b=cfg.b, pa=pa,
                            participates=partf, interpret=interp)
                        vals = dasha_payload_blocks_op(
                            fn, fo, fh, fgi, bidx, b=cfg.b, a=cfg.a,
                            pa=pa, scale=nb / kb, block_size=bs,
                            interpret=interp)
                    else:
                        fh_new, payload = dense_update()
                        vals, bidx = block_randk_select(lkey, payload,
                                                        kb, bs)
                    vals = partf * vals
                    # wire: (n·kb·bs values + n·kb indices) over data axes
                    all_vals = jax.lax.all_gather(vals, data_axes,
                                                  tiled=False)
                    all_idx = jax.lax.all_gather(bidx, data_axes,
                                                 tiled=False)
                    delta = block_scatter_add(
                        jnp.zeros_like(fg),
                        all_vals.reshape(-1, bs), all_idx.reshape(-1),
                        bs) / self.n_nodes
                    fgi_new = block_scatter_add(fgi, vals, bidx, bs)

                fg_new = fg + delta
                new_h.append(fh_new.astype(th.dtype).reshape(th.shape))
                new_gi.append(fgi_new.astype(tgi.dtype).reshape(tgi.shape))
                new_g.append(fg_new.astype(tg.dtype).reshape(tg.shape))

            return (jax.tree.unflatten(treedef, new_h),
                    jax.tree.unflatten(treedef, new_gi),
                    jax.tree.unflatten(treedef, new_g))

        h_new, gi_new, g_new = compat.shard_map(
            update, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
        )(grads_new, grads_old, state.h_i, state.g_i, state.g, key,
          state.step)

        return ShardedDashaState(g=g_new, g_i=gi_new, h_i=h_new,
                                 step=state.step + 1)

    # -- wire accounting ---------------------------------------------------
    def uplink_bits_per_round(self, d_total: int) -> float:
        """Expected uplink bits per node per round (Tables 1-2 metric)."""
        cfg = self.cfg
        if cfg.compression_ratio is None:
            return cfg.p_a * d_total * 32.0
        nb = -(-d_total // cfg.block_size)
        kb = max(1, math.ceil(cfg.compression_ratio * nb))
        return cfg.p_a * kb * (cfg.block_size * 32.0 + 32.0)
