"""SPMD production runtime for DASHA-PP on TPU meshes.

Mapping (DESIGN.md §3, §5): one *node* of the paper = one slice of the
``data`` mesh axes (``("data",)`` single-pod, ``("pod", "data")``
multi-pod).  The parameter server is an abstraction realized by
collectives over those axes.

Pieces:

* :func:`per_node_value_and_grads` — per-node gradients (no cross-node
  mean!) via ``vmap(value_and_grad)`` over an explicit node dimension of
  the batch; runs under GSPMD so the ``model`` axis (tensor/expert
  parallelism) needs no manual collectives.
* :class:`ShardedDasha` — the Algorithm-1 node/server update as a
  ``shard_map`` over the data axes.  Per-node control variates ``h_i,
  g_i`` are param-shaped arrays with a leading node dimension sharded
  over the data axes (each device stores only its own node's variates:
  no replication).
* **All four k_i rules** (Algs. 2-5) via ``ShardedDashaConfig.variant``,
  consumed from the :mod:`repro.core.variants` registry — the same
  objects the reference engine uses, so the two engines' trajectories
  coincide for matched keys (DESIGN.md §8; asserted by
  tests/test_sharded.py).  ``gradient``/``mvr`` take one gradient pair,
  ``page`` adds a minibatch pair + the shared coin (derived in here
  from the round key), ``finite_mvr`` takes component gradients + the
  selected indices and carries ``h_ij`` component trackers in the
  state.
* Aggregation modes:
    - ``dense_psum``       — uncompressed baseline: ``psum`` of dense
      messages over the data axes (bytes ∝ d).
    - ``sparse_allgather`` — RandK/BlockRandK wire format: all-gather of
      ``(values, block indices)`` (bytes ∝ n·K ≪ n·d) + local
      scatter-add.  This is the paper's communication saving made
      visible to the roofline.
* **BlockRandK** (TPU adaptation, DESIGN.md §3): RandK at (128,)-block
  granularity — blocks partition coordinates, so choosing ``K/bs`` of
  ``D/bs`` blocks uniformly without replacement and scaling by ``D/K``
  is unbiased with exactly the Definition-1 bound ``omega = D/K - 1``
  (blocks are super-coordinates).  The draw/scatter helpers live in
  :mod:`repro.core.variants` (re-exported here for compatibility).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import participation, variants
# Re-exported: the BlockRandK wire helpers moved to the rule layer
# (core/variants.py); existing imports from this module keep working.
from repro.core.variants import (block_plan, block_randk_dense,
                                 block_randk_indices, block_randk_select,
                                 block_scatter_add)

Array = jax.Array
PyTree = Any


# ----------------------------------------------------------------------
# Per-node gradients
# ----------------------------------------------------------------------

def per_node_value_and_grads(loss_fn: Callable, params: PyTree,
                             batch: PyTree, *args) -> Tuple[Array, PyTree]:
    """``loss_fn(params, node_batch, *args) -> scalar``; ``batch`` leaves
    carry a leading node dimension.  Returns ``(losses (n,), grads)`` with
    grad leaves shaped ``(n, *param_shape)`` — the *unreduced* per-node
    gradients the DASHA-PP update consumes."""
    vg = jax.value_and_grad(loss_fn)
    in_axes = (None, 0) + tuple(None for _ in args)
    return jax.vmap(vg, in_axes=in_axes)(params, batch, *args)


# ----------------------------------------------------------------------
# Config / state
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedDashaConfig:
    gamma: float
    a: float                       # compressor momentum (Alg.1 line 11)
    b: float                       # VR momentum
    p_a: float = 1.0
    sampler: str = "independent"   # independent | s_nice | full
    compression_ratio: Optional[float] = 0.01   # K/D; None => identity
    block_size: int = 128          # BlockRandK block (TPU lane width)
    aggregation: str = "sparse_allgather"       # or dense_psum
    data_axes: Tuple[str, ...] = ("data",)
    # Which k_i rule (Algs. 2-5) the node update runs; see
    # core/variants.py.  "mvr" (same-sample pair) and "gradient" (full
    # pair) share one leaf formula — they differ in what gradients the
    # caller feeds and in accounting; "page" additionally needs the
    # minibatch pair (node_update(..., mini_new=, mini_old=)) and
    # "finite_mvr" component gradients + indices and h_ij state.
    variant: str = "mvr"
    p_page: float = 1.0            # page only: full-pass probability
    # Wire format of the sparse_allgather aggregation (DESIGN.md §8):
    #   block_randk — kb of nb (block_size,)-blocks, unbiased (default);
    #   topk        — ceil(ratio * d_local) largest coordinates (biased
    #                 baseline; coordinate-level (value, index) wire);
    #   dithering   — QSGD random dithering: dense but quantized to
    #                 ``dithering_levels`` levels (+ norm); the ratio is
    #                 ignored for the wire size but must stay non-None
    #                 to enable the compressed path.
    wire_format: str = "block_randk"
    dithering_levels: int = 4
    # Dispatch the fused Pallas update path (kernels/, DESIGN.md §6) in
    # every aggregation mode.  sparse_allgather additionally fuses
    # BlockRandK into the update: the line-11 payload is evaluated only
    # at the selected blocks, never dense in HBM.  On CPU the kernels
    # run in interpret mode automatically (kernels/ops.py).
    use_pallas: bool = False
    # Force interpret mode on/off; None = auto (interpret unless TPU).
    pallas_interpret: Optional[bool] = None

    def __post_init__(self):
        variants.get_rule(self.variant)   # raises on unknown names
        if self.wire_format not in variants.WIRE_FORMATS:
            raise ValueError(
                f"unknown wire_format {self.wire_format!r}; choose from "
                f"{sorted(variants.WIRE_FORMATS)}")
        if self.wire_format != "block_randk":
            if self.aggregation != "sparse_allgather":
                raise ValueError(
                    f"wire_format {self.wire_format!r} requires the "
                    "sparse_allgather aggregation (dense_psum moves "
                    "dense vectors regardless)")
            if self.compression_ratio is None:
                raise ValueError(
                    f"wire_format {self.wire_format!r} requires a "
                    "non-None compression_ratio — ratio None is the "
                    "dense uncompressed baseline and would silently "
                    "bypass the requested wire format")

    @property
    def compressed(self) -> bool:
        return (self.compression_ratio is not None
                and self.aggregation == "sparse_allgather")


class ShardedDashaState(NamedTuple):
    g: PyTree      # server estimator, sharded like params
    g_i: PyTree    # per-node estimators, leading node dim over data axes
    h_i: PyTree    # per-node gradient trackers, same layout
    step: Array
    # finite_mvr only: per-node per-component trackers, leaves
    # (n, m, *param_shape) sharded like g_i with an extra (m,) dim.
    h_ij: Optional[PyTree] = None


class NodeUpdateMetrics(NamedTuple):
    """Per-round wire accounting, measured inside the update (the
    reference engine's StepMetrics counterpart)."""
    participants: Array   # |S^t|, the realized participant count
    bits_sent: Array      # total uplink bits this round (all nodes)


class ShardedDispatch(NamedTuple):
    """Everything one gang-scheduled round of client work produces
    BEFORE the server applies it — the sharded counterpart of
    :class:`repro.core.dasha_pp.DispatchOutputs` (DESIGN.md §10).

    The sync :meth:`ShardedDasha.node_update` commits it immediately;
    the cohort scheduler (:mod:`repro.fl.cohorts`) buffers it by
    virtual arrival time and commits it with a staleness weight.  All
    leaves are float32 (the update's internal precision), so a
    deferred commit loses nothing to an intermediate cast."""
    h_new: PyTree          # (n, *shape) tracker rows after the update
    g_i_inc: PyTree        # (n, *shape) masked uplink increments m_i
    g_delta: PyTree        # (*shape,)  server-estimator increment
    h_ij_new: Optional[PyTree]   # (n, m, *shape) component trackers
    part: Array            # (n,) float32 realized participation mask


def _num_nodes(mesh: Mesh, data_axes: Sequence[str]) -> int:
    return int(math.prod(mesh.shape[a] for a in data_axes))


def node_spec(param_spec: P, data_axes: Sequence[str]) -> P:
    """Spec for a per-node array: prepend the (tuple of) node axes and
    strip them from the param dims (a per-node array cannot FSDP over the
    axis that indexes nodes)."""
    lead = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)

    def strip(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return None if entry in data_axes else entry
        kept = tuple(a for a in entry if a not in data_axes)
        return kept if kept else None

    return P(lead, *(strip(e) for e in param_spec))


def component_spec(param_spec: P, data_axes: Sequence[str]) -> P:
    """Spec for a per-node, per-component array (n, B|m, *param_shape):
    like :func:`node_spec` with an unsharded component dim inserted."""
    ns = node_spec(param_spec, data_axes)
    return P(ns[0], None, *tuple(ns)[1:])


def estimator_spec(param_spec: P, data_axes: Sequence[str]) -> P:
    """Spec for the server estimator g: like params but never sharded over
    the node axes (every node must see the full (model-sharded) g)."""

    def strip(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return None if entry in data_axes else entry
        kept = tuple(a for a in entry if a not in data_axes)
        return kept if kept else None

    return P(*(strip(e) for e in param_spec))


# ----------------------------------------------------------------------
# The sharded DASHA-PP engine
# ----------------------------------------------------------------------

class ShardedDasha:
    """Algorithm 1 over a mesh.  Usage::

        engine = ShardedDasha(mesh, param_specs, cfg)
        state  = engine.init(grads_like)       # under jit, sharded
        params_new = engine.server_step(params, state)   # x - gamma g
        state, wire = engine.node_update(gn, go, state, key)  # lines 7-19

    Variant-specific extra inputs to :meth:`node_update`:

    * ``page``: ``mini_new=/mini_old=`` — the same-sample minibatch
      gradient pair (``gn/go`` are the full-pass pair; the shared coin
      is derived in here from the round key).
    * ``finite_mvr``: ``gn/go`` are component gradients
      ``(n, B, *shape)`` and ``component_idx`` the ``(n, B)`` selected
      indices; ``state.h_ij`` must be initialized (``init(...,
      h_ij0=...)``).
    """

    def __init__(self, mesh: Mesh, param_specs: PyTree,
                 cfg: ShardedDashaConfig):
        self.mesh = mesh
        self.param_specs = param_specs
        self.cfg = cfg
        self.rule = variants.get_rule(cfg.variant)
        self.n_nodes = _num_nodes(mesh, cfg.data_axes)

    # -- state ----------------------------------------------------------
    def init(self, grads0: PyTree,
             h_ij0: Optional[PyTree] = None) -> ShardedDashaState:
        """Paper line 2 / Theorem 2: g_i^0 = h_i^0 = ∇f_i(x^0); the server
        holds g^0 = mean_i g_i^0.  ``grads0`` = per-node grads (n, *shape).
        ``finite_mvr`` additionally takes the component trackers
        ``h_ij0`` with leaves (n, m, *shape)."""
        if self.rule.component_trackers and h_ij0 is None:
            raise ValueError(
                f"variant {self.cfg.variant!r} needs component trackers: "
                "pass h_ij0 with leaves (n, m, *param_shape)")
        g0 = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads0)
        return ShardedDashaState(
            g=g0, g_i=grads0, h_i=grads0,
            step=jnp.zeros((), jnp.int32), h_ij=h_ij0)

    def init_zero(self, params: PyTree,
                  num_components: Optional[int] = None
                  ) -> ShardedDashaState:
        """Zero-initialized variant (g_i^0 = h_i^0 = 0) — admissible for
        MVR (Theorem 4 allows any h^0; adds a transient O(||∇f(x^0)||²/bT)
        term).  Cheaper when an extra init pass is undesirable.
        ``finite_mvr`` additionally zero-inits the (n, m, *shape)
        component trackers; pass ``num_components`` = m."""
        zeros_node = jax.tree.map(
            lambda p: jnp.zeros((self.n_nodes,) + p.shape, p.dtype), params)
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        h_ij = None
        if self.rule.component_trackers:
            if num_components is None:
                raise ValueError(
                    f"variant {self.cfg.variant!r} needs num_components "
                    "(= m) to size the h_ij trackers")
            h_ij = jax.tree.map(
                lambda p: jnp.zeros(
                    (self.n_nodes, num_components) + p.shape, p.dtype),
                params)
        return ShardedDashaState(g=zeros, g_i=zeros_node, h_i=zeros_node,
                                 step=jnp.zeros((), jnp.int32), h_ij=h_ij)

    # -- server ----------------------------------------------------------
    def server_step(self, params: PyTree, state: ShardedDashaState) -> PyTree:
        """Line 5: x^{t+1} = x^t - gamma * g^t (g is replicated over data)."""
        return jax.tree.map(
            lambda p, g: (p - self.cfg.gamma * g.astype(p.dtype)),
            params, state.g)

    # -- wire size of one node's message -----------------------------------
    def _leaf_model_shards(self, spec: P) -> int:
        """Number of distinct shards one node's copy of a leaf is split
        into over the non-data mesh axes (replicated leaves: 1)."""
        axes = set()
        for entry in spec:
            if entry is None:
                continue
            for a in ((entry,) if isinstance(entry, str) else entry):
                if a not in self.cfg.data_axes:
                    axes.add(a)
        return int(math.prod(self.mesh.shape[a] for a in axes))

    def _per_node_message_bits(self, h_i: PyTree) -> float:
        """Uplink bits one participating node pays per round: compression
        is applied per local shard, so each leaf contributes
        (#model shards) x message_bits(local size).  Computed statically
        from the specs — counting inside the shard_map would tally
        model-replicated leaves once per model shard."""
        cfg, total = self.cfg, 0.0
        spec_leaves = jax.tree.leaves(self.param_specs,
                                      is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(jax.tree.leaves(h_i), spec_leaves):
            d_leaf = int(math.prod(leaf.shape[1:]))
            shards = self._leaf_model_shards(spec)
            total += shards * variants.message_bits(
                max(1, d_leaf // shards), aggregation=cfg.aggregation,
                compression_ratio=cfg.compression_ratio,
                block_size=cfg.block_size,
                wire_format=cfg.wire_format,
                dithering_levels=cfg.dithering_levels)
        return total

    # -- participation ----------------------------------------------------
    def _participates(self, key: Array, node_idx: Array) -> Array:
        """Node-local view of the participation mask — delegates to the
        shared draw in core/participation.py so the mask coincides with
        the reference samplers for a matched key."""
        return participation.participates(self.cfg.sampler, key, node_idx,
                                          self.n_nodes, self.cfg.p_a)

    # -- host-side view of the round's participation draw ------------------
    def participation_mask(self, key: Array, step) -> Array:
        """The (n,) participation mask :meth:`dispatch` would draw
        internally for ``(key, step)`` — the same
        ``round_keys``/``participates`` derivation, vmapped over nodes,
        so a host-side scheduler can intersect it with its own
        idle/availability state and pass the result back as
        ``participation_mask=`` without perturbing the randomness
        contract (sync limit: external mask == internal draw)."""
        k_part, _, _ = variants.round_keys(key, jnp.asarray(step))
        return jax.vmap(
            lambda i: participation.participates(
                self.cfg.sampler, k_part, i, self.n_nodes, self.cfg.p_a)
        )(jnp.arange(self.n_nodes))

    # -- node + aggregation ------------------------------------------------
    def dispatch(self, grads_new: PyTree, grads_old: PyTree,
                 state: ShardedDashaState, key: Array, *,
                 mini_new: Optional[PyTree] = None,
                 mini_old: Optional[PyTree] = None,
                 component_idx: Optional[Array] = None,
                 participation_mask: Optional[Array] = None,
                 ) -> Tuple[ShardedDispatch, NodeUpdateMetrics]:
        """Lines 7-11 of Algorithm 1 as a shard_map over the data axes:
        all client-side work of one round WITHOUT applying it to the
        server estimators (the sharded analog of
        :meth:`repro.core.dasha_pp.DashaPP.dispatch`).

        ``grads_new/old`` leaves: (n_nodes, *param_shape) per-node
        gradients at x^{t+1} and x^t — full pair (``gradient``),
        same-sample minibatch pair (``mvr``), full pair + ``mini_new/
        mini_old`` minibatch pair (``page``), or component gradients
        (n, B, *shape) + ``component_idx`` (``finite_mvr``).

        ``participation_mask`` overrides the internal sampler draw (the
        cohort scheduler passes ``sampled & idle & available``); ``None``
        draws from ``(key, state.step)`` exactly as before.

        Returns ``(ShardedDispatch, NodeUpdateMetrics)``.
        """
        cfg, rule = self.cfg, self.rule
        if rule.needs_minibatch and (mini_new is None or mini_old is None):
            raise ValueError(f"variant {cfg.variant!r} needs the "
                             "mini_new=/mini_old= minibatch gradient pair")
        if rule.component_trackers:
            if component_idx is None:
                raise ValueError(f"variant {cfg.variant!r} needs "
                                 "component_idx (n, B)")
            if state.h_ij is None:
                raise ValueError("state.h_ij is None — initialize with "
                                 "init(grads0, h_ij0=...)")
        data_axes = cfg.data_axes
        lead = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
        pa = cfg.p_a

        node_specs = jax.tree.map(lambda s: node_spec(s, data_axes),
                                  self.param_specs,
                                  is_leaf=lambda x: isinstance(x, P))
        est_specs = jax.tree.map(lambda s: estimator_spec(s, data_axes),
                                 self.param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
        comp_specs = jax.tree.map(lambda s: component_spec(s, data_axes),
                                  self.param_specs,
                                  is_leaf=lambda x: isinstance(x, P))

        grad_specs = comp_specs if rule.component_trackers else node_specs
        has_mask = participation_mask is not None

        operands = [grads_new, grads_old, state.h_i, state.g_i, state.g,
                    key, state.step]
        in_specs = [grad_specs, grad_specs, node_specs, node_specs,
                    est_specs, P(), P()]
        if rule.needs_minibatch:
            operands += [mini_new, mini_old]
            in_specs += [node_specs, node_specs]
        if rule.component_trackers:
            operands += [component_idx, state.h_ij]
            in_specs += [P(lead, None), comp_specs]
        if has_mask:
            operands += [participation_mask]
            in_specs += [P(lead)]

        out_specs = [node_specs, node_specs, est_specs]
        if rule.component_trackers:
            out_specs += [comp_specs]
        out_specs += [P(lead), P()]      # part mask, participants

        def update(gn, go, h_i, g_i, g, key, step, *extra):
            # Inside shard_map: leaves of gn/go/h_i/g_i are (1, *local);
            # g leaves are (*local) replicated over data axes.
            node_idx = jax.lax.axis_index(data_axes) if len(data_axes) > 1 \
                else jax.lax.axis_index(data_axes[0])
            # Shared per-round key derivation (DESIGN.md §8): identical
            # to the reference engine's, so masks/coins/compressor draws
            # coincide for matched keys.
            k_part, k_oracle, k_comp = variants.round_keys(key, step)
            if has_mask:
                part = extra[-1][0]      # local (1,) slice of the mask
            else:
                part = self._participates(k_part, node_idx)
            partf = part.astype(jnp.float32)
            coin = None
            if rule.needs_coin:
                coin = variants.page_coin(
                    variants.page_keys(k_oracle)[0],
                    cfg.p_page).astype(jnp.float32)
            b_new = b_old = idx = h_ij = None
            pos = 0
            if rule.needs_minibatch:
                b_new, b_old = extra[0], extra[1]
                pos = 2
            if rule.component_trackers:
                idx, h_ij = extra[pos], extra[pos + 1]

            leaves_gn, _ = jax.tree.flatten(gn)
            _, treedef = jax.tree.flatten(h_i)
            leaves_go = jax.tree.leaves(go)
            leaves_h = jax.tree.leaves(h_i)
            leaves_gi = jax.tree.leaves(g_i)
            leaves_g = jax.tree.leaves(g)
            leaves_bn = jax.tree.leaves(b_new) if b_new is not None else None
            leaves_bo = jax.tree.leaves(b_old) if b_old is not None else None
            leaves_hij = jax.tree.leaves(h_ij) if h_ij is not None else None

            interp = cfg.pallas_interpret
            hp = dict(b=cfg.b, a=cfg.a, pa=pa, p_page=cfg.p_page)
            new_h, new_gi, new_g, new_hij = [], [], [], []
            for li, (tn, to, th, tgi, tg) in enumerate(zip(
                    leaves_gn, leaves_go, leaves_h, leaves_gi, leaves_g)):
                fh = th[0].reshape(-1).astype(jnp.float32)
                fgi = tgi[0].reshape(-1).astype(jnp.float32)
                fg = tg.reshape(-1).astype(jnp.float32)
                d_loc = fh.shape[0]

                # ---- line 9 inputs: the rule's oracle leaf view ------
                if rule.component_trackers:
                    # tn/to: (1, B, *loc); h_ij leaf: (1, m, *loc).
                    m_comp = leaves_hij[li].shape[1]
                    B = tn.shape[1]
                    fij = leaves_hij[li][0].reshape(
                        m_comp, -1).astype(jnp.float32)
                    fn2 = tn[0].reshape(B, -1).astype(jnp.float32)
                    fo2 = to[0].reshape(B, -1).astype(jnp.float32)
                    iloc = idx[0]                        # (B,)
                    k_ij = variants.k_finite_mvr_components(
                        fn2, fo2, fij[iloc], iloc, m_comp, b=cfg.b)
                    fij_new = fij + partf * (k_ij / pa)
                    ox = variants.OracleBatch(k=jnp.mean(k_ij, axis=0))
                elif rule.needs_minibatch:
                    ox = variants.OracleBatch(
                        gn=tn[0].reshape(-1).astype(jnp.float32),
                        go=to[0].reshape(-1).astype(jnp.float32),
                        bn=leaves_bn[li][0].reshape(-1).astype(jnp.float32),
                        bo=leaves_bo[li][0].reshape(-1).astype(jnp.float32),
                        coin=coin)
                else:
                    ox = variants.OracleBatch(
                        gn=tn[0].reshape(-1).astype(jnp.float32),
                        go=to[0].reshape(-1).astype(jnp.float32))

                lkey = variants.leaf_node_key(k_comp, li, node_idx)

                def dense_update(ox=ox, fh=fh, fgi=fgi):
                    """Lines 9-11 over the full local vector (fused
                    Pallas or jnp) -> (h_new, dense payload).  Every
                    wire below consumes this EXCEPT the BlockRandK
                    sparse path, whose fused form evaluates the payload
                    only at the selected blocks."""
                    if cfg.use_pallas:
                        return rule.fused_flat(ox, fh, fgi, partf,
                                               interpret=interp, **hp)
                    k = rule.k(ox, fh, b=cfg.b, p_page=cfg.p_page)
                    return variants.control_variate_tail(
                        k, fh, fgi, a=cfg.a, pa=pa, part=partf)

                # ---- lines 10-11 + compress + aggregate --------------
                # Every branch yields the node's g_i INCREMENT (the
                # masked compressed message m_i, dense-scattered) and
                # the server-estimator increment delta = mean_i m_i —
                # commit() applies them (weighted); the sync
                # node_update applies them immediately with weight 1.
                if cfg.compression_ratio is None:
                    fh_new, payload = dense_update()
                    m_i = partf * payload
                    total = jax.lax.psum(m_i, data_axes)
                    delta = total / self.n_nodes
                    gi_inc = m_i
                elif cfg.aggregation == "dense_psum":
                    bs, nb, kb = block_plan(d_loc, cfg.block_size,
                                            cfg.compression_ratio)
                    # The compress step is already dense here, so
                    # BlockRandK has no traffic to save and stays jnp
                    # in both paths.
                    fh_new, payload = dense_update()
                    m_i = partf * block_randk_dense(lkey, payload, kb, bs)
                    total = jax.lax.psum(m_i, data_axes)
                    delta = total / self.n_nodes
                    gi_inc = m_i
                elif cfg.wire_format == "topk":
                    # Coordinate-level TopK wire: ceil(ratio * d_local)
                    # largest-|payload| coordinates as (value, index)
                    # pairs.  Biased baseline — needs the dense payload,
                    # so the fused path stops at the update (no
                    # never-materialize win to fuse into).
                    from repro.core.compressors import TopK
                    kk = max(1, min(d_loc, math.ceil(
                        cfg.compression_ratio * d_loc)))
                    fh_new, payload = dense_update()
                    vals, cidx = TopK(k=kk).compress_sparse(lkey, payload)
                    vals = partf * vals
                    all_vals = jax.lax.all_gather(vals, data_axes,
                                                  tiled=False)
                    all_idx = jax.lax.all_gather(cidx, data_axes,
                                                 tiled=False)
                    delta = jnp.zeros_like(fg).at[
                        all_idx.reshape(-1)].add(
                        all_vals.reshape(-1)) / self.n_nodes
                    gi_inc = jnp.zeros_like(fgi).at[cidx].add(vals)
                elif cfg.wire_format == "dithering":
                    # QSGD wire: dense message, quantized coordinates.
                    # The all-gather carries what the server would
                    # decode from (norm, sign, level) packets.
                    from repro.core.compressors import RandomDithering
                    q = RandomDithering(s=cfg.dithering_levels)
                    fh_new, payload = dense_update()
                    m_i = partf * q.compress(lkey, payload)
                    all_m = jax.lax.all_gather(m_i, data_axes,
                                               tiled=False)
                    delta = jnp.sum(all_m.reshape(-1, d_loc),
                                    axis=0) / self.n_nodes
                    gi_inc = m_i
                else:  # sparse_allgather, BlockRandK — the paper's wire
                    bs, nb, kb = block_plan(d_loc, cfg.block_size,
                                            cfg.compression_ratio)
                    if cfg.use_pallas:
                        # Fused update+compress (DESIGN.md §6): the h
                        # tracker gets its own dense pass (k stays
                        # in-register) and the line-11 payload is
                        # evaluated ONLY at the kb selected blocks —
                        # the dense payload never exists in HBM
                        # (finite_mvr: tail+gather, its k is dense).
                        bidx = block_randk_indices(lkey, nb, kb)
                        fh_new, vals = rule.fused_flat_blocks(
                            ox, fh, fgi, partf, bidx, scale=nb / kb,
                            block_size=bs, interpret=interp, **hp)
                    else:
                        fh_new, payload = dense_update()   # jnp here
                        vals, bidx = block_randk_select(lkey, payload,
                                                        kb, bs)
                    vals = partf * vals
                    # wire: (n·kb·bs values + n·kb indices) over data axes
                    all_vals = jax.lax.all_gather(vals, data_axes,
                                                  tiled=False)
                    all_idx = jax.lax.all_gather(bidx, data_axes,
                                                 tiled=False)
                    delta = block_scatter_add(
                        jnp.zeros_like(fg),
                        all_vals.reshape(-1, bs), all_idx.reshape(-1),
                        bs) / self.n_nodes
                    gi_inc = block_scatter_add(jnp.zeros_like(fgi),
                                               vals, bidx, bs)

                new_h.append(fh_new.reshape(th.shape))
                new_gi.append(gi_inc.reshape(tgi.shape))
                new_g.append(delta.reshape(tg.shape))
                if rule.component_trackers:
                    hl = leaves_hij[li]
                    new_hij.append(fij_new.reshape(hl.shape))

            participants = jax.lax.psum(partf, data_axes)
            outs = [jax.tree.unflatten(treedef, new_h),
                    jax.tree.unflatten(treedef, new_gi),
                    jax.tree.unflatten(treedef, new_g)]
            if rule.component_trackers:
                outs.append(jax.tree.unflatten(treedef, new_hij))
            return tuple(outs) + (partf.reshape(1), participants)

        results = compat.shard_map(
            update, mesh=self.mesh, in_specs=tuple(in_specs),
            out_specs=tuple(out_specs),
        )(*operands)

        if rule.component_trackers:
            h_new, gi_inc, g_delta, h_ij_new, part, parts = results
        else:
            h_new, gi_inc, g_delta, part, parts = results
            h_ij_new = None
        disp = ShardedDispatch(h_new=h_new, g_i_inc=gi_inc,
                               g_delta=g_delta, h_ij_new=h_ij_new,
                               part=part)
        bits = parts * self._per_node_message_bits(state.h_i)
        return disp, NodeUpdateMetrics(participants=parts,
                                       bits_sent=bits)

    # -- the server-side apply ---------------------------------------------
    def commit(self, state: ShardedDashaState, disp: ShardedDispatch,
               weight=1.0) -> ShardedDashaState:
        """Lines 12/19 of Algorithm 1 for one dispatched round: apply a
        :class:`ShardedDispatch` to the estimators.  ``weight`` is the
        staleness weight ``w(s)`` of the async commit (DESIGN.md §9/§10)
        — it scales the compressed increments to BOTH ``g_i`` and ``g``
        (preserving ``g = mean_i g_i``), while the node trackers
        ``h_i``/``h_ij`` are *set* unweighted for participating rows
        (they are the clients' local state, already stepped).  Leaves
        ``state.step`` untouched — the caller owns the round counter."""
        w = jnp.asarray(weight, jnp.float32)

        def add_w(x, d):
            return (x.astype(jnp.float32) + w * d).astype(x.dtype)

        def set_rows(x, new):
            m = disp.part.reshape((-1,) + (1,) * (x.ndim - 1)) > 0
            return jnp.where(m, new.astype(jnp.float32),
                             x.astype(jnp.float32)).astype(x.dtype)

        g = jax.tree.map(add_w, state.g, disp.g_delta)
        g_i = jax.tree.map(add_w, state.g_i, disp.g_i_inc)
        h_i = jax.tree.map(set_rows, state.h_i, disp.h_new)
        h_ij = state.h_ij
        if disp.h_ij_new is not None:
            h_ij = jax.tree.map(set_rows, state.h_ij, disp.h_ij_new)
        return state._replace(g=g, g_i=g_i, h_i=h_i, h_ij=h_ij)

    def node_update(self, grads_new: PyTree, grads_old: PyTree,
                    state: ShardedDashaState, key: Array, *,
                    mini_new: Optional[PyTree] = None,
                    mini_old: Optional[PyTree] = None,
                    component_idx: Optional[Array] = None,
                    ) -> Tuple[ShardedDashaState, NodeUpdateMetrics]:
        """Lines 7-19 of Algorithm 1: :meth:`dispatch` + immediate
        :meth:`commit` with weight 1 — the synchronous round, exactly
        as before the split (the async cohort runtime is a buffered
        re-composition of the same two halves, DESIGN.md §10)."""
        disp, metrics = self.dispatch(
            grads_new, grads_old, state, key, mini_new=mini_new,
            mini_old=mini_old, component_idx=component_idx)
        new_state = self.commit(state, disp, weight=1.0)
        return new_state._replace(step=state.step + 1), metrics

    # -- wire accounting ---------------------------------------------------
    def uplink_bits_per_round(self, d_total: int) -> float:
        """Expected uplink bits per node per round (Tables 1-2 metric),
        aggregation-aware: only ``sparse_allgather`` has a sparse wire;
        ``dense_psum`` moves dense messages regardless of the
        compression ratio (core/variants.py accounting)."""
        cfg = self.cfg
        return variants.uplink_bits_per_node(
            d_total, aggregation=cfg.aggregation,
            compression_ratio=cfg.compression_ratio,
            block_size=cfg.block_size, p_a=cfg.p_a,
            wire_format=cfg.wire_format,
            dithering_levels=cfg.dithering_levels)
