"""The single source of wire-format truth (bit accounting).

Every bit the repo reports — compressor ``wire_bits``, the fleet's
uplink payloads, the serving benchmarks — must trace back here: the
communication-complexity tables are the paper's headline claim, and
PR 6/7 both grew local ``32 * nnz``-style math that drifted from the
core model until reconciled.  The ``bit-accounting`` checker
(``repro.analysis``) enforces the discipline mechanically: literal
bit-width arithmetic outside ``core/`` is a finding.

Widths are floats because the complexity curves are analytic counts
(Tables 1-2), not byte-aligned encodings.
"""
from __future__ import annotations

import math

FLOAT_BITS = 32.0
"""Bits per transmitted float value (fp32 wire format)."""

GROUP_HEADER_BITS = 32.0
"""Per aggregated round-group: the dispatch-round id the tree fleet
stamps on each uplink group."""


def index_bits(d: int) -> float:
    """Bits per transmitted coordinate index: ``ceil(log2 d)``."""
    return float(max(1, math.ceil(math.log2(max(d, 2)))))


def payload_bits(nnz: int, d: int,
                 value_bits: float = FLOAT_BITS) -> float:
    """Lossless sparse-or-dense wire size of one aggregated vector:
    whichever of (value, index) pairs or the dense vector is smaller."""
    return float(min(nnz * (value_bits + index_bits(d)),
                     d * value_bits))
