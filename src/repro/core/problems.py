"""Distributed problems for the paper-scale experiments (Section A).

A :class:`DistributedProblem` holds per-node data and exposes the three
oracle interfaces the DASHA-PP variants need:

* ``grad(x) -> (n, d)``                       full local gradients,
* ``component_grads(x, idx) -> (n, B, d)``    finite-sum component grads,
* ``stochastic_grad_pair(key, x1, x0, B)``    same-sample grads at two
  points (Assumption 6 mean-squared smoothness usage in MVR variants).

Two concrete problems mirror the paper's experiments:

* :class:`LogisticSigmoidProblem` — eq. (11): 1/m Σ (1 - sigmoid(y a^T x))^2,
  a smooth **nonconvex** binary-classification loss.
* :class:`NonconvexSoftmaxProblem` — eq. (12): two-class softmax CE with a
  nonconvex regularizer λ Σ x_k^2 / (1 + x_k^2).

Datasets are synthetic sparse "libsvm-like" features split across n nodes
(the container is offline; the paper's claims we validate are *relative
rate* claims, invariant to the dataset; see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_synthetic_classification(key: Array, n_nodes: int, m_per_node: int,
                                  d: int, heterogeneity: float = 1.0,
                                  density: float = 0.2) -> Tuple[Array, Array]:
    """Sparse features A: (n, m, d), labels y in {-1, +1}: (n, m).

    ``heterogeneity`` scales per-node shifts of the generating hyperplane,
    controlling how different the f_i are (the paper targets the generic
    heterogeneous regime).
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    feats = jax.random.normal(k1, (n_nodes, m_per_node, d))
    mask = jax.random.bernoulli(k2, density, (n_nodes, m_per_node, d))
    feats = feats * mask / jnp.sqrt(density)
    w_true = jax.random.normal(k3, (d,))
    w_shift = heterogeneity * jax.random.normal(k4, (n_nodes, d)) / jnp.sqrt(d)
    logits = jnp.einsum("nmd,nd->nm", feats, w_true[None, :] + w_shift)
    flips = jax.random.bernoulli(k5, 0.05, (n_nodes, m_per_node))
    y = jnp.where(flips, -jnp.sign(logits), jnp.sign(logits))
    y = jnp.where(y == 0, 1.0, y)
    return feats, y


class DistributedProblem:
    """n-node finite-sum problem; all oracles are jit/vmap friendly."""

    n: int
    m: int
    d: int

    def loss(self, x: Array) -> Array:
        raise NotImplementedError

    def node_loss(self, x: Array) -> Array:
        """-> (n,) local losses."""
        raise NotImplementedError

    def grad(self, x: Array) -> Array:
        """-> (n, d) full local gradients."""
        raise NotImplementedError

    def full_grad(self, x: Array) -> Array:
        return jnp.mean(self.grad(x), axis=0)

    def component_grads(self, x: Array, idx: Array) -> Array:
        """idx: (n, B) component indices -> (n, B, d)."""
        raise NotImplementedError

    def batch_grad(self, x: Array, idx: Array) -> Array:
        return jnp.mean(self.component_grads(x, idx), axis=1)

    # ---- constants for theory.py ------------------------------------
    def smoothness(self) -> "tuple[float, float, float, float]":
        """(L, L_hat, L_max, L_sigma) estimates from the data."""
        raise NotImplementedError


@dataclasses.dataclass
class LogisticSigmoidProblem(DistributedProblem):
    """Paper eq. (11): f_ij(x) = (1 - 1/(1+exp(y a^T x)))^2 = sigmoid(-y a^T x)^2."""

    feats: Array  # (n, m, d)
    labels: Array  # (n, m)

    def __post_init__(self):
        self.n, self.m, self.d = self.feats.shape

    def _component_loss(self, x: Array) -> Array:
        z = jnp.einsum("nmd,d->nm", self.feats, x) * self.labels
        return jax.nn.sigmoid(-z) ** 2

    def loss(self, x: Array) -> Array:
        return jnp.mean(self._component_loss(x))

    def node_loss(self, x: Array) -> Array:
        return jnp.mean(self._component_loss(x), axis=1)

    def _component_grad_all(self, x: Array) -> Array:
        """-> (n, m, d) gradients of every component."""
        z = jnp.einsum("nmd,d->nm", self.feats, x) * self.labels
        s = jax.nn.sigmoid(-z)
        coef = -2.0 * s**2 * (1.0 - s) * self.labels   # d/dz sigmoid(-z)^2 * y
        return coef[..., None] * self.feats

    def grad(self, x: Array) -> Array:
        return jnp.mean(self._component_grad_all(x), axis=1)

    def component_grads(self, x: Array, idx: Array) -> Array:
        g_all = self._component_grad_all(x)  # (n, m, d)
        return jnp.take_along_axis(g_all, idx[..., None], axis=1)

    def smoothness(self):
        # |(sigmoid(-z)^2)''| <= ~0.3; row smoothness <= 0.3 ||a||^2.
        row_sq = jnp.sum(self.feats**2, axis=-1)          # (n, m)
        L_ij = 0.31 * row_sq
        L_i = jnp.mean(L_ij, axis=1)
        L = float(jnp.mean(L_i))
        L_hat = float(jnp.sqrt(jnp.mean(L_i**2)))
        L_max = float(jnp.max(L_ij))
        return L, L_hat, L_max, L_max


@dataclasses.dataclass
class NonconvexSoftmaxProblem(DistributedProblem):
    """Paper eq. (12) reduced to a single weight vector per class pair:
    binary softmax CE + nonconvex regularizer lam * sum x^2/(1+x^2)."""

    feats: Array   # (n, m, d)
    labels: Array  # (n, m) in {-1, +1}
    lam: float = 1e-3

    def __post_init__(self):
        self.n, self.m, self.d = self.feats.shape

    def _component_loss(self, x: Array) -> Array:
        z = jnp.einsum("nmd,d->nm", self.feats, x) * self.labels
        ce = jnp.log1p(jnp.exp(-z))
        reg = self.lam * jnp.sum(x**2 / (1.0 + x**2))
        return ce + reg

    def loss(self, x: Array) -> Array:
        return jnp.mean(self._component_loss(x))

    def node_loss(self, x: Array) -> Array:
        return jnp.mean(self._component_loss(x), axis=1)

    def _component_grad_all(self, x: Array) -> Array:
        z = jnp.einsum("nmd,d->nm", self.feats, x) * self.labels
        coef = -jax.nn.sigmoid(-z) * self.labels
        g_data = coef[..., None] * self.feats
        g_reg = self.lam * 2.0 * x / (1.0 + x**2) ** 2
        return g_data + g_reg[None, None, :]

    def grad(self, x: Array) -> Array:
        return jnp.mean(self._component_grad_all(x), axis=1)

    def component_grads(self, x: Array, idx: Array) -> Array:
        g_all = self._component_grad_all(x)
        return jnp.take_along_axis(g_all, idx[..., None], axis=1)

    def smoothness(self):
        row_sq = jnp.sum(self.feats**2, axis=-1)
        L_ij = 0.25 * row_sq + 2.0 * self.lam
        L_i = jnp.mean(L_ij, axis=1)
        L = float(jnp.mean(L_i))
        L_hat = float(jnp.sqrt(jnp.mean(L_i**2)))
        L_max = float(jnp.max(L_ij))
        return L, L_hat, L_max, L_max


@dataclasses.dataclass
class QuadraticProblem(DistributedProblem):
    """f_i(x) = 0.5 x^T A_i x - b_i^T x with PSD A_i — a sanity/test problem
    with analytically known constants and minimizer."""

    A: Array  # (n, d, d)
    b: Array  # (n, d)

    def __post_init__(self):
        self.n, self.d = self.b.shape
        self.m = 1

    @classmethod
    def random(cls, key: Array, n: int, d: int, cond: float = 10.0):
        k1, k2 = jax.random.split(key)
        mats = jax.random.normal(k1, (n, d, d)) / jnp.sqrt(d)
        A = jnp.einsum("nij,nkj->nik", mats, mats) + jnp.eye(d) / cond
        b = jax.random.normal(k2, (n, d))
        return cls(A=A, b=b)

    def loss(self, x: Array) -> Array:
        return jnp.mean(self.node_loss(x))

    def node_loss(self, x: Array) -> Array:
        quad = 0.5 * jnp.einsum("d,nde,e->n", x, self.A, x)
        return quad - self.b @ x

    def grad(self, x: Array) -> Array:
        return jnp.einsum("nde,e->nd", self.A, x) - self.b

    def component_grads(self, x: Array, idx: Array) -> Array:
        return self.grad(x)[:, None, :] * jnp.ones_like(idx[..., None])

    def minimizer(self) -> Array:
        return jnp.linalg.solve(jnp.mean(self.A, 0), jnp.mean(self.b, 0))

    def smoothness(self):
        eigs = jnp.linalg.eigvalsh(self.A)
        L_i = eigs[:, -1]
        L = float(jnp.linalg.eigvalsh(jnp.mean(self.A, 0))[-1])
        L_hat = float(jnp.sqrt(jnp.mean(L_i**2)))
        L_max = float(jnp.max(L_i))
        return L, L_hat, L_max, L_max


def sample_batch_indices(key: Array, n: int, m: int, B: int,
                         replace: bool = True) -> Array:
    """(n, B) per-node component indices."""
    keys = jax.random.split(key, n)
    if replace:
        return jax.vmap(lambda k: jax.random.randint(k, (B,), 0, m))(keys)
    return jax.vmap(lambda k: jax.random.permutation(k, m)[:B])(keys)
