"""Unbiased (and biased, for baselines) communication compressors.

Implements Definition 1 of the paper: a stochastic mapping
``C: R^d -> R^d`` with ``E[C(x)] = x`` and
``E[||C(x) - x||^2] <= omega * ||x||^2``.

Every compressor is a pure function of ``(key, x)`` so that Assumption 7
(independence across nodes) is realized by folding the node index into
the PRNG key.  Compressors operate on **flat 1-D vectors**; pytrees are
handled by :mod:`repro.core.flatten`.

Each compressor exposes:

* ``omega(d)``            – the variance parameter of Definition 1,
* ``compress(key, x)``    – dense d-vector -> dense d-vector (zeros kept),
* ``compress_sparse(key, x)`` – -> (values, indices) when a sparse wire
  format exists (RandK/TopK); used by the sharded runtime to send
  ``O(K)`` instead of ``O(d)`` bytes,
* ``wire_bits(d)``        – bits transmitted per message, used by the
  communication-complexity benchmarks (Tables 1-2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import wire

Array = jax.Array

# the wire model lives in repro.core.wire; these are the module-local
# spellings the compressor formulas use
_FLOAT_BITS = int(wire.FLOAT_BITS)
_index_bits = wire.index_bits


class Compressor:
    """Base interface (see module docstring)."""

    name: str = "base"

    def omega(self, d: int) -> float:
        raise NotImplementedError

    def compress(self, key: Array, x: Array) -> Array:
        raise NotImplementedError

    def wire_bits(self, d: int) -> float:
        raise NotImplementedError

    # Sparse wire format is optional.
    def compress_sparse(self, key: Array, x: Array) -> Tuple[Array, Array]:
        raise NotImplementedError(f"{self.name} has no sparse wire format")

    def __call__(self, key: Array, x: Array) -> Array:
        return self.compress(key, x)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """No compression: C(x) = x, omega = 0."""

    name: str = "identity"

    def omega(self, d: int) -> float:
        return 0.0

    def compress(self, key: Array, x: Array) -> Array:
        del key
        return x

    def wire_bits(self, d: int) -> float:
        return d * _FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Definition 5: keep K uniformly-random coordinates (w/o replacement),
    scaled by d/K.  ``C in U(d/K - 1)`` (Theorem 6)."""

    k: int
    name: str = "randk"

    def omega(self, d: int) -> float:
        return d / self.k - 1.0

    def _indices(self, key: Array, d: int) -> Array:
        # Without replacement.  For K << d a permutation is wasteful but
        # d here is a per-shard flat size (<= a few M) and permutation is
        # O(d) memory — acceptable and exactly uniform.
        return jax.random.permutation(key, d)[: self.k]

    def compress(self, key: Array, x: Array) -> Array:
        d = x.shape[-1]
        k = min(self.k, d)
        if k == d:
            return x
        idx = self._indices(key, d)
        scale = d / k
        out = jnp.zeros_like(x)
        return out.at[idx].set(x[idx] * scale)

    def compress_sparse(self, key: Array, x: Array) -> Tuple[Array, Array]:
        d = x.shape[-1]
        k = min(self.k, d)
        idx = self._indices(key, d)
        return x[idx] * (d / k), idx

    def wire_bits(self, d: int) -> float:
        k = min(self.k, d)
        return k * (_FLOAT_BITS + _index_bits(d))


@dataclasses.dataclass(frozen=True)
class BlockRandK(Compressor):
    """RandK at block granularity (the TPU wire format, DESIGN.md §3):
    choose ``kb`` of the ``nb`` (block_size,)-blocks u.a.r. without
    replacement and scale by ``nb/kb``.  Blocks partition coordinates,
    so this is an ordinary RandK on super-coordinates: unbiased with
    exactly ``omega = nb/kb - 1``.

    This is the *dense-output reference form* of the sharded engine's
    wire: it reuses the engine's draw (``variants.block_randk_dense``),
    so with matched keys (``variants.leaf_node_key``) the reference
    DashaPP engine reproduces ShardedDasha messages bit-for-bit — the
    basis of the trajectory-parity tests."""

    ratio: float
    block_size: int = 128
    name: str = "block_randk"

    def _plan(self, d: int):
        from repro.core.variants import block_plan
        return block_plan(d, self.block_size, self.ratio)

    def omega(self, d: int) -> float:
        _, nb, kb = self._plan(d)
        return nb / kb - 1.0

    def compress(self, key: Array, x: Array) -> Array:
        from repro.core.variants import block_randk_dense
        bs, _, kb = self._plan(x.shape[-1])
        return block_randk_dense(key, x, kb, bs)

    def compress_sparse(self, key: Array, x: Array) -> Tuple[Array, Array]:
        from repro.core.variants import block_randk_select
        bs, _, kb = self._plan(x.shape[-1])
        return block_randk_select(key, x, kb, bs)

    def wire_bits(self, d: int) -> float:
        from repro.core.variants import message_bits
        return message_bits(d, aggregation="sparse_allgather",
                            compression_ratio=self.ratio,
                            block_size=self.block_size)


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Greedy Top-K by magnitude.  *Biased* (contractive) — included as a
    baseline only; not admissible for DASHA-PP's unbiasedness analysis.
    Satisfies ||C(x)-x||^2 <= (1 - k/d)||x||^2."""

    k: int
    name: str = "topk"

    def omega(self, d: int) -> float:  # contraction factor, not Def.1 omega
        return 1.0 - self.k / d

    def compress(self, key: Array, x: Array) -> Array:
        del key
        d = x.shape[-1]
        k = min(self.k, d)
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        out = jnp.zeros_like(x)
        return out.at[idx].set(x[idx])

    def compress_sparse(self, key: Array, x: Array) -> Tuple[Array, Array]:
        del key
        d = x.shape[-1]
        k = min(self.k, d)
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        return x[idx], idx

    def wire_bits(self, d: int) -> float:
        k = min(self.k, d)
        return k * (_FLOAT_BITS + _index_bits(d))


@dataclasses.dataclass(frozen=True)
class NaturalCompression(Compressor):
    """Natural compression (Horvath et al., 2019a): stochastic rounding of
    the mantissa to a power of two.  ``omega = 1/8``; sends exponent+sign
    (~9 bits/coord)."""

    name: str = "natural"

    def omega(self, d: int) -> float:
        return 0.125

    def compress(self, key: Array, x: Array) -> Array:
        ax = jnp.abs(x)
        safe = jnp.where(ax > 0, ax, 1.0)
        e = jnp.floor(jnp.log2(safe))
        lo = jnp.exp2(e)
        # p(up) chosen for unbiasedness: x = p*2^{e+1} + (1-p)*2^e
        p_up = (safe - lo) / lo
        u = jax.random.uniform(key, x.shape)
        mag = jnp.where(u < p_up, 2.0 * lo, lo)
        out = jnp.sign(x) * mag
        return jnp.where(ax > 0, out, 0.0).astype(x.dtype)

    def wire_bits(self, d: int) -> float:
        return d * 9.0


@dataclasses.dataclass(frozen=True)
class RandomDithering(Compressor):
    """QSGD-style random dithering with ``s`` levels (Alistarh et al. 2017).

    C(x) = ||x||_2 * sign(x) * xi(x, s) with xi the stochastically rounded
    level.  omega <= min(d/s^2, sqrt(d)/s)."""

    s: int = 4
    name: str = "dithering"

    def omega(self, d: int) -> float:
        return min(d / self.s**2, jnp.sqrt(d).item() / self.s)

    def compress(self, key: Array, x: Array) -> Array:
        norm = jnp.linalg.norm(x)
        safe_norm = jnp.where(norm > 0, norm, 1.0)
        level = jnp.abs(x) / safe_norm * self.s
        floor = jnp.floor(level)
        p_up = level - floor
        u = jax.random.uniform(key, x.shape)
        q = floor + (u < p_up)
        out = norm * jnp.sign(x) * q / self.s
        return jnp.where(norm > 0, out, 0.0).astype(x.dtype)

    def wire_bits(self, d: int) -> float:
        import math

        return _FLOAT_BITS + d * (1 + math.ceil(math.log2(self.s + 1)))


@dataclasses.dataclass(frozen=True)
class Composed(Compressor):
    """C2 ∘ C1 with independent randomness.

    If C1 in U(w1) and C2 in U(w2) then C2∘C1 in U(w1 + w2 + w1*w2).
    Used beyond-paper: RandK + Natural to cut value bytes 32->9."""

    inner: Compressor
    outer: Compressor
    name: str = "composed"

    def omega(self, d: int) -> float:
        w1, w2 = self.inner.omega(d), self.outer.omega(d)
        return w1 + w2 + w1 * w2

    def compress(self, key: Array, x: Array) -> Array:
        k1, k2 = jax.random.split(key)
        return self.outer.compress(k2, self.inner.compress(k1, x))

    def compress_sparse(self, key: Array, x: Array) -> Tuple[Array, Array]:
        k1, k2 = jax.random.split(key)
        vals, idx = self.inner.compress_sparse(k1, x)
        return self.outer.compress(k2, vals), idx

    def wire_bits(self, d: int) -> float:
        if isinstance(self.inner, (RandK, TopK)):
            k = min(self.inner.k, d)
            return k * _index_bits(d) + self.outer.wire_bits(k)
        return self.outer.wire_bits(d)


@dataclasses.dataclass(frozen=True)
class PartialParticipationCompressor(Compressor):
    """The C^{p_a} construction of paper Section 5, eq. after (6):

        C^{p_a}(x) = (1/p_a) C(x)  w.p. p_a,   0  w.p. 1 - p_a.

    If C in U(w) then C^{p_a} in U((w+1)/p_a - 1) (paper footnote 3).
    Only valid for the *gradient setting* DASHA (single control variate)."""

    inner: Compressor
    p_a: float
    name: str = "pp_wrapper"

    def omega(self, d: int) -> float:
        return (self.inner.omega(d) + 1.0) / self.p_a - 1.0

    def compress(self, key: Array, x: Array) -> Array:
        k1, k2 = jax.random.split(key)
        participate = jax.random.bernoulli(k1, self.p_a)
        return jnp.where(participate, self.inner.compress(k2, x) / self.p_a, 0.0)

    def wire_bits(self, d: int) -> float:
        return self.p_a * self.inner.wire_bits(d)


def randk_for_ratio(d: int, ratio: float) -> RandK:
    """RandK with K = ceil(ratio * d), clipped to [1, d]."""
    import math

    return RandK(k=max(1, min(d, math.ceil(ratio * d))))


_REGISTRY = {
    "identity": lambda d, **kw: Identity(),
    "randk": lambda d, **kw: RandK(k=kw.get("k", max(1, d // 100))),
    "block_randk": lambda d, **kw: BlockRandK(
        ratio=kw.get("ratio", 0.01), block_size=kw.get("block_size", 128)),
    "topk": lambda d, **kw: TopK(k=kw.get("k", max(1, d // 100))),
    "natural": lambda d, **kw: NaturalCompression(),
    "dithering": lambda d, **kw: RandomDithering(s=kw.get("s", 4)),
}


def make_compressor(name: str, d: int, **kwargs) -> Compressor:
    if name not in _REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](d, **kwargs)
