"""DASHA-PP-SYNC-MVR (paper Appendix G).

Purpose (paper §6.3): plain DASHA-PP-MVR needs an initial batch
``B_init = Theta(sqrt(p_a) B / b)`` that is suboptimal w.r.t. omega in
some regimes — "a side effect of mixing the variance reduction of
stochastic gradients and compression".  The SYNC variant removes the
dependence by *probabilistic resynchronization*: with a (small)
probability ``p_sync`` a round additionally lets the participating
nodes push their current tracker ``h_i`` to the server uncompressed
(1/p_a-scaled), snapping ``g_i -> h_i`` — the compressed-estimator
error resets without ever requiring all nodes at once (unlike MARINA's
full-sync rounds).

The appendix pseudocode is followed at the level of its update
structure (the source text of Algorithm G is truncated in our copy of
the paper; the resync rule here preserves unbiasedness through Lemma 1
exactly like line 10-12 of Algorithm 1 — see tests).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor
from repro.core.dasha_pp import (DashaPP, DashaPPConfig, DashaPPState,
                                 StepMetrics)
from repro.core.participation import ParticipationSampler
from repro.core.problems import DistributedProblem

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SyncMVRConfig(DashaPPConfig):
    p_sync: float = 0.1


class DashaPPSyncMVR(DashaPP):
    """DASHA-PP-MVR + probabilistic uncompressed resync of g_i to h_i."""

    def __init__(self, problem: DistributedProblem, compressor: Compressor,
                 sampler: ParticipationSampler, config: SyncMVRConfig):
        super().__init__(problem, compressor, sampler, config)

    def step(self, key: Array, state: DashaPPState
             ) -> Tuple[DashaPPState, StepMetrics]:
        k_main, k_coin, k_part2 = jax.random.split(key, 3)
        new_state, metrics = super().step(k_main, state)

        # resync round (prob p_sync): participating nodes send h_i - g_i
        # uncompressed; the server debiases by 1/p_a (Lemma 1 pattern).
        coin = jax.random.bernoulli(k_coin, self.cfg.p_sync)
        mask = self.sampler.sample(k_part2)
        maskf = (mask[:, None].astype(state.x.dtype)
                 * coin.astype(state.x.dtype))
        pa = self.sampler.p_a
        resync_msg = maskf * (new_state.h_i - new_state.g_i)
        g_i_sync = new_state.g_i + resync_msg
        g_sync = new_state.g + jnp.mean(resync_msg / pa, axis=0)

        extra_bits = (jnp.sum(mask) * 32.0 * self.problem.d
                      * coin.astype(jnp.float32))
        metrics = metrics._replace(bits_sent=metrics.bits_sent + extra_bits)
        return DashaPPState(x=new_state.x, g=g_sync, g_i=g_i_sync,
                            h_i=new_state.h_i, h_ij=new_state.h_ij,
                            step=new_state.step), metrics


def dasha_pp_sync_mvr(problem, compressor, sampler, *, gamma, a, b,
                      batch_size, p_sync=0.1) -> DashaPPSyncMVR:
    return DashaPPSyncMVR(
        problem, compressor, sampler,
        SyncMVRConfig("mvr", gamma=gamma, a=a, b=b, batch_size=batch_size,
                      p_sync=p_sync))
