"""Partial-participation samplers (paper Section 2.2, Assumption 8).

A sampler draws, per communication round, a boolean participation mask of
shape ``(n,)`` over nodes with

    Prob(i participates)            = p_a      for all i,
    Prob(i and j both participate)  = p_aa     for all i != j,
    p_aa <= p_a**2,

independently across rounds.  The two standard strategies of the paper:

* **s-nice**: the server picks ``s`` nodes uniformly without replacement.
  ``p_a = s/n``, ``p_aa = s(s-1)/(n(n-1))``.
* **independent**: each node participates independently with prob p_a.
  ``p_aa = p_a**2``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


# ----------------------------------------------------------------------
# Shared draw functions
# ----------------------------------------------------------------------
# The mask math lives in these standalone functions so the vmap
# reference engine (vector form below) and the shard_map production
# engine (leaf form, :func:`participates`) consume participation
# randomness IDENTICALLY for a given key — a prerequisite for the
# reference<->sharded trajectory-parity tests (DESIGN.md §8).

def independent_mask(key: Array, n: int, p: float) -> Array:
    """(n,) bool mask; node ``i`` draws from ``fold_in(key, i)`` so a
    single node can reproduce its own coordinate without a gather."""
    return jax.vmap(
        lambda i: jax.random.bernoulli(jax.random.fold_in(key, i), p)
    )(jnp.arange(n))


def snice_mask(key: Array, n: int, s: int) -> Array:
    """(n,) bool mask with exactly ``s`` participants (shared perm)."""
    return jax.random.permutation(key, n) < s


def snice_size(p_a: float, n: int) -> int:
    """The ``s`` an s-nice sampler of rate ``p_a`` uses on ``n`` nodes."""
    return max(1, round(p_a * n))


def participates(sampler: str, key: Array, node_idx, n: int,
                 p_a: float) -> Array:
    """Leaf-level participation indicator: node ``node_idx``'s
    coordinate of the mask the matching sampler draws from ``key``
    (exact equality asserted by tests/test_variants.py)."""
    if sampler == "full" or p_a >= 1.0:
        return jnp.ones((), bool)
    if sampler == "independent":
        return jax.random.bernoulli(jax.random.fold_in(key, node_idx),
                                    p_a)
    if sampler == "s_nice":
        s = snice_size(p_a, n)
        return jax.random.permutation(key, n)[node_idx] < s
    raise ValueError(f"unknown sampler {sampler!r}")


class ParticipationSampler:
    n: int

    @property
    def p_a(self) -> float:
        raise NotImplementedError

    @property
    def p_aa(self) -> float:
        raise NotImplementedError

    def sample(self, key: Array) -> Array:
        """-> bool mask of shape (n,)."""
        raise NotImplementedError

    @property
    def one_pa(self) -> float:
        """The paper's 𝟙_{p_a} := sqrt(1 - p_aa / p_a) in [0, 1]."""
        return float(jnp.sqrt(1.0 - self.p_aa / self.p_a))


@dataclasses.dataclass(frozen=True)
class SNice(ParticipationSampler):
    """Uniformly choose exactly ``s`` of ``n`` nodes without replacement."""

    n: int
    s: int

    def __post_init__(self):
        if not (1 <= self.s <= self.n):
            raise ValueError(f"need 1 <= s <= n, got s={self.s}, n={self.n}")

    @property
    def p_a(self) -> float:
        return self.s / self.n

    @property
    def p_aa(self) -> float:
        if self.n == 1:
            return 1.0
        return self.s * (self.s - 1) / (self.n * (self.n - 1))

    def sample(self, key: Array) -> Array:
        return snice_mask(key, self.n, self.s)


@dataclasses.dataclass(frozen=True)
class Independent(ParticipationSampler):
    """Each node participates independently with probability p."""

    n: int
    p: float

    def __post_init__(self):
        if not (0.0 < self.p <= 1.0):
            raise ValueError(f"need 0 < p <= 1, got {self.p}")

    @property
    def p_a(self) -> float:
        return self.p

    @property
    def p_aa(self) -> float:
        return self.p * self.p

    def sample(self, key: Array) -> Array:
        return independent_mask(key, self.n, self.p)


@dataclasses.dataclass(frozen=True)
class FullParticipation(ParticipationSampler):
    """p_a = p_aa = 1: every node every round (the DASHA setting)."""

    n: int

    @property
    def p_a(self) -> float:
        return 1.0

    @property
    def p_aa(self) -> float:
        return 1.0

    def sample(self, key: Array) -> Array:
        del key
        return jnp.ones((self.n,), dtype=bool)


@dataclasses.dataclass(frozen=True)
class EdgeSNice(ParticipationSampler):
    """Per-edge s-nice over a contiguous edge partition (DESIGN.md §12).

    The fleet runtime partitions clients into contiguous per-edge
    chunks (:func:`repro.fl.client_store.edge_partition`); each round,
    every edge independently picks exactly ``s`` of its clients
    uniformly without replacement, so cohorts are balanced across edge
    aggregators by construction and the round's gather touches every
    chunk equally.  Host-side sampler: the mask is a numpy array drawn
    from per-edge numpy Generators seeded by a single jax draw from
    ``key`` — one device round-trip per round regardless of the number
    of edges, deterministic in ``key`` alone.

    Rates: ``p_a`` is exactly ``s / chunk_size`` when chunks are equal
    (the :func:`edge_partition` split differs by at most one client;
    the reported ``p_a`` is the fleet mean ``E*s/n``).  ``p_aa`` is
    reported as the *maximum* pairwise rate over client pairs —
    ``(s / min_chunk)**2`` — which is the conservative choice for the
    paper's step-size bounds since ``1_{p_a}`` shrinks as ``p_aa``
    grows toward ``p_a``.
    """

    bounds: tuple
    s: int

    def __post_init__(self):
        b = tuple(int(x) for x in self.bounds)
        object.__setattr__(self, "bounds", b)
        if len(b) < 2 or b[0] != 0 or any(y <= x for x, y in
                                          zip(b, b[1:])):
            raise ValueError(f"bounds must be ascending from 0: {b}")
        smallest = min(y - x for x, y in zip(b, b[1:]))
        if not (1 <= self.s <= smallest):
            raise ValueError(f"need 1 <= s <= min edge size "
                             f"({smallest}), got s={self.s}")

    @property
    def n(self) -> int:  # type: ignore[override]
        return self.bounds[-1]

    @property
    def num_edges(self) -> int:
        return len(self.bounds) - 1

    @property
    def p_a(self) -> float:
        return self.num_edges * self.s / self.n

    @property
    def p_aa(self) -> float:
        smallest = min(y - x for x, y in zip(self.bounds, self.bounds[1:]))
        if smallest == 1:
            return 1.0
        return (self.s / smallest) ** 2

    def sample(self, key: Array):
        import numpy as np
        seeds = np.asarray(jax.random.randint(
            key, (self.num_edges,), 0, jnp.iinfo(jnp.int32).max))
        mask = np.zeros(self.n, dtype=bool)
        for e in range(self.num_edges):
            lo, hi = self.bounds[e], self.bounds[e + 1]
            rng = np.random.default_rng(int(seeds[e]))
            picks = rng.choice(hi - lo, size=self.s, replace=False)
            mask[lo + picks] = True
        return mask


def make_sampler(name: str, n: int, **kwargs) -> ParticipationSampler:
    if name == "s_nice":
        return SNice(n=n, s=kwargs["s"])
    if name == "independent":
        return Independent(n=n, p=kwargs["p"])
    if name == "full":
        return FullParticipation(n=n)
    if name == "edge_s_nice":
        return EdgeSNice(bounds=tuple(kwargs["bounds"]), s=kwargs["s"])
    raise ValueError(f"unknown sampler {name!r}")
