"""DASHA-PP (paper Algorithm 1) and its sub-algorithms (Algs. 2-5).

One generic engine implements Algorithm 1; the four ``k_i`` rules plug
in from the :mod:`repro.core.variants` registry (the single source of
truth shared with the sharded production engine, DESIGN.md §8):

* ``gradient``    — Alg. 2 (DASHA-PP)
* ``page``        — Alg. 3 (DASHA-PP-PAGE, finite-sum)
* ``finite_mvr``  — Alg. 4 (DASHA-PP-FINITE-MVR, finite-sum)
* ``mvr``         — Alg. 5 (DASHA-PP-MVR, stochastic)

Baselines DASHA / DASHA-MVR (Algs. 6-7) are the exact ``p_a = 1``
specialization and are exposed as constructors.

The reference implementation here simulates all ``n`` nodes in-process
with ``vmap`` (paper §A does the same with multiprocessing); the
SPMD/sharded production version lives in :mod:`repro.core.sharded`.

Every step is jit-compatible; all randomness flows from an explicit key.
Per Assumption 7, node compressors are independent: node ``i`` uses
``fold_in(round_key, i)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import variants
from repro.core.compressors import Compressor
from repro.core.participation import (FullParticipation, ParticipationSampler)
from repro.core.problems import DistributedProblem, sample_batch_indices

Array = jax.Array


class DashaPPState(NamedTuple):
    x: Array            # (d,)   model point x^t
    g: Array            # (d,)   server estimator g^t
    g_i: Array          # (n, d) node estimators
    h_i: Array          # (n, d) node gradient trackers
    h_ij: Optional[Array]  # (n, m, d) component trackers (finite_mvr) or None
    step: Array         # ()


class StepMetrics(NamedTuple):
    loss: Array
    grad_norm_sq: Array        # ||∇f(x^t)||^2, the paper's plotted quantity
    bits_sent: Array           # total uplink bits this round (all nodes)
    grad_oracle_calls: Array   # (stochastic) gradient evaluations this round
    participants: Array
    x_norm: Array              # ||x^t|| — detects escape to flat tails


class DispatchOutputs(NamedTuple):
    """Everything one round of client work produces BEFORE the server
    applies it (Alg. 1 lines 4-11).  The sync :meth:`DashaPP.step`
    commits all of it immediately; the async runtime
    (:mod:`repro.fl.server`) defers each node's row to its virtual
    arrival time — both consume this same dispatch, which is what makes
    the async sync-limit parity a structural property rather than a
    reimplementation (DESIGN.md §9)."""
    x_new: Array          # (d,)   x^{t+1}
    mask: Array           # (n,)   participation indicator
    m_i: Array            # (n, d) compressed uplink messages (masked)
    h_new: Array          # (n, d) tracker step (rows of non-participants
    #        equal the old h_i — line 10's mask is already applied)
    h_ij_delta: Optional[Array]   # (n, m, d) component-tracker increment
    oracle_calls: Array


@dataclasses.dataclass(frozen=True)
class DashaPPConfig:
    variant: str                      # gradient | page | finite_mvr | mvr
    gamma: float
    a: float                          # compressor momentum
    b: float                          # VR momentum
    p_page: float = 1.0               # page only
    batch_size: int = 1               # page / finite_mvr / mvr
    replace: bool = True              # batch sampling w/ replacement (Alg.3)
    # Fuse lines 9-11 into one batched Pallas launch per round
    # (kernels/dasha_update.py; interpret-mode on CPU).  Mirrors
    # ShardedDashaConfig.use_pallas; numerics match the jnp chain to
    # float32 rounding (tests/test_dasha_pp.py parity sweep).
    use_pallas: bool = False

    def __post_init__(self):
        variants.get_rule(self.variant)   # raises on unknown names


class DashaPP:
    """Engine for Algorithm 1.  Construct, then ``state = init(key, x0)``
    and ``state, metrics = step(key, state)`` (both jit-able)."""

    def __init__(self, problem: DistributedProblem, compressor: Compressor,
                 sampler: ParticipationSampler, config: DashaPPConfig):
        if sampler.n != problem.n:
            raise ValueError("sampler.n != problem.n")
        self.problem = problem
        self.compressor = compressor
        self.sampler = sampler
        self.cfg = config

    # ------------------------------------------------------------------
    def init(self, key: Array, x0: Array,
             b_init: Optional[int] = None) -> DashaPPState:
        """Line 2: g_i^0 = h_i^0 = ∇f_i(x^0) (gradient/finite settings) or a
        B_init-sample estimate (Corollary 3, stochastic setting)."""
        p = self.problem
        if self.cfg.variant == "mvr" and b_init is not None:
            idx = sample_batch_indices(key, p.n, p.m, b_init, replace=True)
            h0 = p.batch_grad(x0, idx)
        else:
            h0 = p.grad(x0)
        h_ij = None
        if self.cfg.variant == "finite_mvr":
            # (n, m, d) component trackers: h_ij^0 = ∇f_ij(x^0)
            all_idx = jnp.broadcast_to(jnp.arange(p.m)[None, :], (p.n, p.m))
            h_ij = p.component_grads(x0, all_idx)
        return DashaPPState(
            x=x0, g=jnp.mean(h0, axis=0), g_i=h0, h_i=h0, h_ij=h_ij,
            step=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------------
    def dispatch(self, key: Array, state: DashaPPState,
                 mask: Optional[Array] = None) -> DispatchOutputs:
        """Alg. 1 lines 4-11: the model broadcast and all client-side
        work of one round, WITHOUT applying it to the server estimators.
        ``mask`` overrides the sampler draw (the async runtime passes
        ``sampled & idle``); ``None`` draws from ``self.sampler`` with
        the canonical ``k_part`` — exactly what :meth:`step` commits."""
        p, cfg, C = self.problem, self.cfg, self.compressor
        rule = variants.get_rule(cfg.variant)
        pa = self.sampler.p_a
        k_part, k_oracle, k_comp = variants.round_keys(key)

        # Lines 4-5: x^{t+1} = x^t - gamma * g^t; broadcast.
        x_new = state.x - cfg.gamma * state.g

        # Lines 7-8: participation mask.
        if mask is None:
            mask = self.sampler.sample(k_part)         # (n,) bool
        maskf = mask[:, None].astype(state.x.dtype)

        # Line 9 oracles: the rule evaluates what it needs (full pair /
        # same-sample pair / PAGE coin+pair / component scatter) with
        # the canonical randomness consumption — shared between the
        # fused and jnp paths, so their trajectories coincide.
        ox, k_ij, calls = rule.reference_oracle(k_oracle, p, cfg, x_new,
                                                state.x, state)
        if cfg.use_pallas:
            # Lines 9-11 fused (one batched Pallas launch for all n
            # simulated nodes, DESIGN.md §6).  Kernels compute in
            # float32; restore the state dtype so the lax.scan carry in
            # run() keeps a fixed type (x64/bf16 problems).
            dt = state.h_i.dtype
            k_i, h_new, payload = (
                x.astype(dt) for x in rule.fused_batched(
                    ox, state.h_i, state.g_i, mask, b=cfg.b, a=cfg.a,
                    pa=pa, p_page=cfg.p_page))
        else:
            # Line 9: k_i^{t+1} per variant (computed for every node; only
            # participating nodes *use* it — masking note, DESIGN.md §3).
            k_i = rule.k(ox, state.h_i, b=cfg.b, p_page=cfg.p_page)
            # Lines 10-11: tracker step + uplink payload.
            h_new, payload = variants.control_variate_tail(
                k_i, state.h_i, state.g_i, a=cfg.a, pa=pa, part=maskf)

        h_ij_delta = None
        if rule.component_trackers:
            h_ij_delta = maskf[:, :, None] * (k_ij / pa)

        # Line 11: m_i = C_i(payload).  Node i's key is the leaf-0 key of
        # the shared derivation (Assumption 7; matches the sharded
        # engine's per-leaf keys for trajectory parity).
        node_keys = jax.vmap(
            lambda i: variants.leaf_node_key(k_comp, 0, i))(
            jnp.arange(p.n))
        m_i = jax.vmap(C.compress)(node_keys, payload)
        m_i = maskf * m_i

        return DispatchOutputs(x_new=x_new, mask=mask, m_i=m_i,
                               h_new=h_new, h_ij_delta=h_ij_delta,
                               oracle_calls=calls)

    # ------------------------------------------------------------------
    def step(self, key: Array, state: DashaPPState
             ) -> Tuple[DashaPPState, StepMetrics]:
        p, C = self.problem, self.compressor
        out = self.dispatch(key, state)

        # Lines 12, 19: the synchronous commit — every dispatched row
        # lands in the same round it was produced.
        g_i_new = state.g_i + out.m_i
        g_new = state.g + jnp.mean(out.m_i, axis=0)
        h_ij_new = None
        if out.h_ij_delta is not None:
            h_ij_new = state.h_ij + out.h_ij_delta

        n_part = jnp.sum(out.mask)
        metrics = StepMetrics(
            loss=p.loss(state.x),
            grad_norm_sq=jnp.sum(p.full_grad(state.x) ** 2),
            bits_sent=n_part * C.wire_bits(p.d),
            grad_oracle_calls=out.oracle_calls,
            participants=n_part,
            x_norm=jnp.linalg.norm(state.x),
        )
        new_state = DashaPPState(x=out.x_new, g=g_new, g_i=g_i_new,
                                 h_i=out.h_new, h_ij=h_ij_new,
                                 step=state.step + 1)
        return new_state, metrics

    # ------------------------------------------------------------------
    def run(self, key: Array, x0: Array, num_rounds: int,
            b_init: Optional[int] = None) -> Tuple[DashaPPState, StepMetrics]:
        """jit-compiled lax.scan over ``num_rounds`` rounds; returns the final
        state and stacked per-round metrics."""
        init_key, run_key = jax.random.split(key)
        state = self.init(init_key, x0, b_init=b_init)

        def body(carry, i):
            st = carry
            st, met = self.step(jax.random.fold_in(run_key, i), st)
            return st, met

        return jax.lax.scan(body, state, jnp.arange(num_rounds))


# ----------------------------------------------------------------------
# Named constructors (the paper's method zoo)
# ----------------------------------------------------------------------

def dasha_pp(problem, compressor, sampler, *, gamma, a, b,
             use_pallas=False) -> DashaPP:
    """DASHA-PP, gradient setting (Alg. 1 + Alg. 2, Theorem 2)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("gradient", gamma=gamma, a=a, b=b,
                                 use_pallas=use_pallas))


def dasha_pp_page(problem, compressor, sampler, *, gamma, a, b, p_page,
                  batch_size, use_pallas=False) -> DashaPP:
    """DASHA-PP-PAGE (Alg. 1 + Alg. 3, Theorem 3)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("page", gamma=gamma, a=a, b=b,
                                 p_page=p_page, batch_size=batch_size,
                                 use_pallas=use_pallas))


def dasha_pp_finite_mvr(problem, compressor, sampler, *, gamma, a, b,
                        batch_size, use_pallas=False) -> DashaPP:
    """DASHA-PP-FINITE-MVR (Alg. 1 + Alg. 4, Theorem 7)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("finite_mvr", gamma=gamma, a=a, b=b,
                                 batch_size=batch_size,
                                 use_pallas=use_pallas))


def dasha_pp_mvr(problem, compressor, sampler, *, gamma, a, b,
                 batch_size, use_pallas=False) -> DashaPP:
    """DASHA-PP-MVR (Alg. 1 + Alg. 5, Theorem 4)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("mvr", gamma=gamma, a=a, b=b,
                                 batch_size=batch_size,
                                 use_pallas=use_pallas))


def dasha(problem, compressor, *, gamma, a) -> DashaPP:
    """DASHA (Alg. 6) == DASHA-PP with p_a = 1 and b = 1 (so h_i^{t+1}
    tracks ∇f_i(x^{t+1}) exactly and line 11 reduces to Alg. 6 line 7)."""
    sampler = FullParticipation(n=problem.n)
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("gradient", gamma=gamma, a=a, b=1.0))


def dasha_mvr(problem, compressor, *, gamma, a, b, batch_size) -> DashaPP:
    """DASHA-MVR (Alg. 7) == DASHA-PP-MVR with p_a = 1."""
    sampler = FullParticipation(n=problem.n)
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("mvr", gamma=gamma, a=a, b=b,
                                 batch_size=batch_size))


def dasha_page(problem, compressor, *, gamma, a, b, p_page, batch_size) -> DashaPP:
    """DASHA-PAGE == DASHA-PP-PAGE with p_a = 1."""
    sampler = FullParticipation(n=problem.n)
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("page", gamma=gamma, a=a, b=b,
                                 p_page=p_page, batch_size=batch_size))
