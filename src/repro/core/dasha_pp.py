"""DASHA-PP (paper Algorithm 1) and its sub-algorithms (Algs. 2-5).

One generic engine implements Algorithm 1; the four ``k_i`` rules plug in:

* ``gradient``    — Alg. 2 (DASHA-PP)
* ``page``        — Alg. 3 (DASHA-PP-PAGE, finite-sum)
* ``finite_mvr``  — Alg. 4 (DASHA-PP-FINITE-MVR, finite-sum)
* ``mvr``         — Alg. 5 (DASHA-PP-MVR, stochastic)

Baselines DASHA / DASHA-MVR (Algs. 6-7) are the exact ``p_a = 1``
specialization and are exposed as constructors.

The reference implementation here simulates all ``n`` nodes in-process
with ``vmap`` (paper §A does the same with multiprocessing); the
SPMD/sharded production version lives in :mod:`repro.core.sharded`.

Every step is jit-compatible; all randomness flows from an explicit key.
Per Assumption 7, node compressors are independent: node ``i`` uses
``fold_in(round_key, i)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor
from repro.core.participation import (FullParticipation, ParticipationSampler)
from repro.core.problems import DistributedProblem, sample_batch_indices

Array = jax.Array


class DashaPPState(NamedTuple):
    x: Array            # (d,)   model point x^t
    g: Array            # (d,)   server estimator g^t
    g_i: Array          # (n, d) node estimators
    h_i: Array          # (n, d) node gradient trackers
    h_ij: Optional[Array]  # (n, m, d) component trackers (finite_mvr) or None
    step: Array         # ()


class StepMetrics(NamedTuple):
    loss: Array
    grad_norm_sq: Array        # ||∇f(x^t)||^2, the paper's plotted quantity
    bits_sent: Array           # total uplink bits this round (all nodes)
    grad_oracle_calls: Array   # (stochastic) gradient evaluations this round
    participants: Array
    x_norm: Array              # ||x^t|| — detects escape to flat tails


@dataclasses.dataclass(frozen=True)
class DashaPPConfig:
    variant: str                      # gradient | page | finite_mvr | mvr
    gamma: float
    a: float                          # compressor momentum
    b: float                          # VR momentum
    p_page: float = 1.0               # page only
    batch_size: int = 1               # page / finite_mvr / mvr
    replace: bool = True              # batch sampling w/ replacement (Alg.3)
    # Fuse lines 9-11 into one batched Pallas launch per round
    # (kernels/dasha_update.py; interpret-mode on CPU).  Mirrors
    # ShardedDashaConfig.use_pallas; numerics match the jnp chain to
    # float32 rounding (tests/test_dasha_pp.py parity sweep).
    use_pallas: bool = False

    def __post_init__(self):
        if self.variant not in ("gradient", "page", "finite_mvr", "mvr"):
            raise ValueError(f"unknown variant {self.variant!r}")


class DashaPP:
    """Engine for Algorithm 1.  Construct, then ``state = init(key, x0)``
    and ``state, metrics = step(key, state)`` (both jit-able)."""

    def __init__(self, problem: DistributedProblem, compressor: Compressor,
                 sampler: ParticipationSampler, config: DashaPPConfig):
        if sampler.n != problem.n:
            raise ValueError("sampler.n != problem.n")
        self.problem = problem
        self.compressor = compressor
        self.sampler = sampler
        self.cfg = config

    # ------------------------------------------------------------------
    def init(self, key: Array, x0: Array,
             b_init: Optional[int] = None) -> DashaPPState:
        """Line 2: g_i^0 = h_i^0 = ∇f_i(x^0) (gradient/finite settings) or a
        B_init-sample estimate (Corollary 3, stochastic setting)."""
        p = self.problem
        if self.cfg.variant == "mvr" and b_init is not None:
            idx = sample_batch_indices(key, p.n, p.m, b_init, replace=True)
            h0 = p.batch_grad(x0, idx)
        else:
            h0 = p.grad(x0)
        h_ij = None
        if self.cfg.variant == "finite_mvr":
            # (n, m, d) component trackers: h_ij^0 = ∇f_ij(x^0)
            all_idx = jnp.broadcast_to(jnp.arange(p.m)[None, :], (p.n, p.m))
            h_ij = p.component_grads(x0, all_idx)
        return DashaPPState(
            x=x0, g=jnp.mean(h0, axis=0), g_i=h0, h_i=h0, h_ij=h_ij,
            step=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------------
    def _k_gradient(self, key, x_new, x_old, state):
        p, b = self.problem, self.cfg.b
        gn, go = p.grad(x_new), p.grad(x_old)
        k = gn - go - b * (state.h_i - go)
        calls = jnp.asarray(2 * p.m * p.n)  # full local grads at two points
        return k, None, calls

    def _k_page(self, key, x_new, x_old, state):
        p, cfg = self.problem, self.cfg
        k_coin, k_batch = jax.random.split(key)
        # One global coin (paper: "with probability p_page on all
        # participating nodes" — the switch is shared).
        coin = jax.random.bernoulli(k_coin, cfg.p_page)
        idx = sample_batch_indices(k_batch, p.n, p.m, cfg.batch_size,
                                   replace=cfg.replace)
        gn, go = p.grad(x_new), p.grad(x_old)
        k_full = gn - go - (cfg.b / cfg.p_page) * (state.h_i - go)
        bn = p.batch_grad(x_new, idx)
        bo = p.batch_grad(x_old, idx)
        k_mini = bn - bo
        k = jnp.where(coin, k_full, k_mini)
        calls = jnp.where(coin, 2 * p.m * p.n, 2 * cfg.batch_size * p.n)
        return k, None, calls

    def _k_finite_mvr(self, key, x_new, x_old, state):
        p, cfg = self.problem, self.cfg
        B, m = cfg.batch_size, p.m
        idx = sample_batch_indices(key, p.n, m, B, replace=False)  # Alg.4: w/o repl.
        gn = p.component_grads(x_new, idx)            # (n, B, d)
        go = p.component_grads(x_old, idx)
        h_sel = jnp.take_along_axis(state.h_ij, idx[..., None], axis=1)
        k_sel = (m / B) * (gn - go - cfg.b * (h_sel - go))   # (n, B, d)
        # Scatter back to (n, m, d); untouched components are zero.
        k_ij = jnp.zeros_like(state.h_ij)
        k_ij = jax.vmap(lambda kz, ii, kv: kz.at[ii].set(kv))(k_ij, idx, k_sel)
        k = jnp.mean(k_ij, axis=1)                    # (n, d)
        calls = jnp.asarray(2 * B * p.n)
        return k, k_ij, calls

    def _k_mvr(self, key, x_new, x_old, state):
        p, cfg = self.problem, self.cfg
        B = cfg.batch_size
        idx = sample_batch_indices(key, p.n, p.m, B, replace=True)
        bn = p.batch_grad(x_new, idx)   # same sample at both points (Alg.5)
        bo = p.batch_grad(x_old, idx)
        k = bn - bo - cfg.b * (state.h_i - bo)
        calls = jnp.asarray(2 * B * p.n)
        return k, None, calls

    # ------------------------------------------------------------------
    def _fused_update(self, key: Array, x_new: Array, x_old: Array,
                      state: DashaPPState, mask: Array):
        """Lines 9-11 via the fused batched Pallas kernels (DESIGN.md §6):
        one launch computes (k_i, h_new, payload) for all ``n`` simulated
        nodes, replacing the five-pass elementwise jnp chain.  Randomness
        is consumed exactly as in the unfused ``_k_*`` path, so the two
        trajectories coincide."""
        from repro.kernels import ops
        p, cfg = self.problem, self.cfg
        pa = self.sampler.p_a
        kw = dict(b=cfg.b, a=cfg.a, pa=pa)
        # Kernels compute in float32; restore the state dtype so the
        # lax.scan carry in run() keeps a fixed type (x64/bf16 problems).
        dt = state.h_i.dtype
        _cast = lambda *xs: tuple(x.astype(dt) for x in xs)
        if cfg.variant == "gradient":
            gn, go = p.grad(x_new), p.grad(x_old)
            k_i, h_new, payload = _cast(*ops.dasha_update_batched_op(
                gn, go, state.h_i, state.g_i, mask, **kw))
            return k_i, None, h_new, payload, jnp.asarray(2 * p.m * p.n)
        if cfg.variant == "mvr":
            idx = sample_batch_indices(key, p.n, p.m, cfg.batch_size,
                                       replace=True)
            bn, bo = p.batch_grad(x_new, idx), p.batch_grad(x_old, idx)
            k_i, h_new, payload = _cast(*ops.dasha_update_batched_op(
                bn, bo, state.h_i, state.g_i, mask, **kw))
            return (k_i, None, h_new, payload,
                    jnp.asarray(2 * cfg.batch_size * p.n))
        if cfg.variant == "page":
            k_coin, k_batch = jax.random.split(key)
            coin = jax.random.bernoulli(k_coin, cfg.p_page)
            idx = sample_batch_indices(k_batch, p.n, p.m, cfg.batch_size,
                                       replace=cfg.replace)
            gn, go = p.grad(x_new), p.grad(x_old)
            bn, bo = p.batch_grad(x_new, idx), p.batch_grad(x_old, idx)
            k_i, h_new, payload = _cast(*ops.dasha_page_update_op(
                gn, go, bn, bo, state.h_i, state.g_i, mask, coin,
                p_page=cfg.p_page, **kw))
            calls = jnp.where(coin, 2 * p.m * p.n,
                              2 * cfg.batch_size * p.n)
            return k_i, None, h_new, payload, calls
        # finite_mvr: k_i comes from the (n, m, d) component scatter —
        # no dense elementwise shape to fuse — so only the tail fuses.
        k_i, k_ij, calls = self._k_finite_mvr(key, x_new, x_old, state)
        h_new, payload = _cast(*ops.dasha_tail_op(k_i, state.h_i,
                                                  state.g_i, mask,
                                                  a=cfg.a, pa=pa))
        return k_i, k_ij, h_new, payload, calls

    # ------------------------------------------------------------------
    def step(self, key: Array, state: DashaPPState
             ) -> Tuple[DashaPPState, StepMetrics]:
        p, cfg, C = self.problem, self.cfg, self.compressor
        pa = self.sampler.p_a
        k_part, k_oracle, k_comp = jax.random.split(key, 3)

        # Lines 4-5: x^{t+1} = x^t - gamma * g^t; broadcast.
        x_new = state.x - cfg.gamma * state.g

        # Lines 7-8: participation mask.
        mask = self.sampler.sample(k_part)             # (n,) bool
        maskf = mask[:, None].astype(state.x.dtype)

        if cfg.use_pallas:
            # Lines 9-11 fused (one Pallas launch for all n nodes).
            k_i, k_ij, h_new, payload, calls = self._fused_update(
                k_oracle, x_new, state.x, state, mask)
        else:
            # Line 9: k_i^{t+1} per variant (computed for every node; only
            # participating nodes *use* it — masking note, DESIGN.md §3).
            k_fn = getattr(self, f"_k_{cfg.variant}")
            k_i, k_ij, calls = k_fn(k_oracle, x_new, state.x, state)
            # Line 10: h_i^{t+1} = h_i^t + k_i/p_a (participating only).
            h_new = state.h_i + maskf * (k_i / pa)
            # Line 11 payload: k_i/p_a - (a/p_a)(g_i - h_i^t).
            payload = k_i / pa - (cfg.a / pa) * (state.g_i - state.h_i)

        h_ij_new = None
        if cfg.variant == "finite_mvr":
            h_ij_new = state.h_ij + maskf[:, :, None] * (k_ij / pa)

        # Line 11: m_i = C_i(payload).
        node_keys = jax.vmap(lambda i: jax.random.fold_in(k_comp, i))(
            jnp.arange(p.n))
        m_i = jax.vmap(C.compress)(node_keys, payload)
        m_i = maskf * m_i

        # Lines 12, 19.
        g_i_new = state.g_i + m_i
        g_new = state.g + jnp.mean(m_i, axis=0)

        n_part = jnp.sum(mask)
        metrics = StepMetrics(
            loss=p.loss(state.x),
            grad_norm_sq=jnp.sum(p.full_grad(state.x) ** 2),
            bits_sent=n_part * C.wire_bits(p.d),
            grad_oracle_calls=calls,
            participants=n_part,
            x_norm=jnp.linalg.norm(state.x),
        )
        new_state = DashaPPState(x=x_new, g=g_new, g_i=g_i_new, h_i=h_new,
                                 h_ij=h_ij_new, step=state.step + 1)
        return new_state, metrics

    # ------------------------------------------------------------------
    def run(self, key: Array, x0: Array, num_rounds: int,
            b_init: Optional[int] = None) -> Tuple[DashaPPState, StepMetrics]:
        """jit-compiled lax.scan over ``num_rounds`` rounds; returns the final
        state and stacked per-round metrics."""
        init_key, run_key = jax.random.split(key)
        state = self.init(init_key, x0, b_init=b_init)

        def body(carry, i):
            st = carry
            st, met = self.step(jax.random.fold_in(run_key, i), st)
            return st, met

        return jax.lax.scan(body, state, jnp.arange(num_rounds))


# ----------------------------------------------------------------------
# Named constructors (the paper's method zoo)
# ----------------------------------------------------------------------

def dasha_pp(problem, compressor, sampler, *, gamma, a, b,
             use_pallas=False) -> DashaPP:
    """DASHA-PP, gradient setting (Alg. 1 + Alg. 2, Theorem 2)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("gradient", gamma=gamma, a=a, b=b,
                                 use_pallas=use_pallas))


def dasha_pp_page(problem, compressor, sampler, *, gamma, a, b, p_page,
                  batch_size, use_pallas=False) -> DashaPP:
    """DASHA-PP-PAGE (Alg. 1 + Alg. 3, Theorem 3)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("page", gamma=gamma, a=a, b=b,
                                 p_page=p_page, batch_size=batch_size,
                                 use_pallas=use_pallas))


def dasha_pp_finite_mvr(problem, compressor, sampler, *, gamma, a, b,
                        batch_size, use_pallas=False) -> DashaPP:
    """DASHA-PP-FINITE-MVR (Alg. 1 + Alg. 4, Theorem 7)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("finite_mvr", gamma=gamma, a=a, b=b,
                                 batch_size=batch_size,
                                 use_pallas=use_pallas))


def dasha_pp_mvr(problem, compressor, sampler, *, gamma, a, b,
                 batch_size, use_pallas=False) -> DashaPP:
    """DASHA-PP-MVR (Alg. 1 + Alg. 5, Theorem 4)."""
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("mvr", gamma=gamma, a=a, b=b,
                                 batch_size=batch_size,
                                 use_pallas=use_pallas))


def dasha(problem, compressor, *, gamma, a) -> DashaPP:
    """DASHA (Alg. 6) == DASHA-PP with p_a = 1 and b = 1 (so h_i^{t+1}
    tracks ∇f_i(x^{t+1}) exactly and line 11 reduces to Alg. 6 line 7)."""
    sampler = FullParticipation(n=problem.n)
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("gradient", gamma=gamma, a=a, b=1.0))


def dasha_mvr(problem, compressor, *, gamma, a, b, batch_size) -> DashaPP:
    """DASHA-MVR (Alg. 7) == DASHA-PP-MVR with p_a = 1."""
    sampler = FullParticipation(n=problem.n)
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("mvr", gamma=gamma, a=a, b=b,
                                 batch_size=batch_size))


def dasha_page(problem, compressor, *, gamma, a, b, p_page, batch_size) -> DashaPP:
    """DASHA-PAGE == DASHA-PP-PAGE with p_a = 1."""
    sampler = FullParticipation(n=problem.n)
    return DashaPP(problem, compressor, sampler,
                   DashaPPConfig("page", gamma=gamma, a=a, b=b,
                                 p_page=p_page, batch_size=batch_size))
