"""Lightweight metrics logging: stdout + JSONL sink.

Since PR 8 this is a thin shim over the observability layer
(``repro.obs.metrics``): the jsonl file goes through
:class:`~repro.obs.metrics.JsonlSink` and every numeric field is
mirrored into the metrics registry as a ``<name>.<field>`` gauge, so a
``--metrics-out`` snapshot sees whatever was logged.  The public API
and the on-disk jsonl / stdout formats are unchanged.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, Optional

from repro.obs.metrics import JsonlSink, Registry, get_registry


class MetricsLogger:
    def __init__(self, out_dir: Optional[str] = None, name: str = "train",
                 print_every: int = 1,
                 registry: Optional[Registry] = None):
        self.out_dir = out_dir
        self.name = name
        self.print_every = print_every
        self._t0 = time.time()
        self._sink: Optional[JsonlSink] = None
        self._registry = registry
        if out_dir:
            self._sink = JsonlSink(os.path.join(out_dir, f"{name}.jsonl"))

    def log(self, step: int, **metrics: Any) -> None:
        rec: Dict[str, Any] = {"step": step,
                               "wall_s": round(time.time() - self._t0, 3)}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        reg = self._registry if self._registry is not None else get_registry()
        reg.gauge(f"{self.name}.step").set(float(step))
        for k, v in rec.items():
            if k != "step" and isinstance(v, float):
                reg.gauge(f"{self.name}.{k}").set(v)
        if self._sink is not None:
            self._sink.write(rec)
        if step % self.print_every == 0:
            kv = " ".join(f"{k}={v:.5g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in rec.items() if k != "step")
            print(f"[step {step:>6d}] {kv}", flush=True)

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
