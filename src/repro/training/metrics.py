"""Lightweight metrics logging: stdout + CSV/JSONL sinks."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class MetricsLogger:
    def __init__(self, out_dir: Optional[str] = None, name: str = "train",
                 print_every: int = 1):
        self.out_dir = out_dir
        self.print_every = print_every
        self._file = None
        self._t0 = time.time()
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            self._file = open(os.path.join(out_dir, f"{name}.jsonl"), "a")

    def log(self, step: int, **metrics: Any) -> None:
        rec: Dict[str, Any] = {"step": step,
                               "wall_s": round(time.time() - self._t0, 3)}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        if self._file:
            self._file.write(json.dumps(rec) + "\n")
            self._file.flush()
        if step % self.print_every == 0:
            kv = " ".join(f"{k}={v:.5g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in rec.items() if k != "step")
            print(f"[step {step:>6d}] {kv}", flush=True)

    def close(self) -> None:
        if self._file:
            self._file.close()
