"""Server-side optimizers.

The paper's server step is plain ``x^{t+1} = x^t - gamma * g^t`` (Alg. 1
line 5) — that is the *faithful* mode and the default.

Beyond-paper: the server may treat ``g^t`` (the variance-reduced,
compression-debiased estimator) as the gradient fed to any first-order
optimizer.  We provide AdamW — convergence theory no longer applies
verbatim, but the estimator is still unbiased-in-the-limit and this is
what a production deployment would run.  Recorded separately in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class SGDState(NamedTuple):
    count: Array


class AdamWState(NamedTuple):
    count: Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class ServerOptimizer:
    """optax-like (init, update) pair; ``update`` maps the DASHA estimator
    g to a parameter delta."""
    name: str
    lr: float
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    warmup: int = 0

    def init(self, params: PyTree):
        if self.name == "sgd":
            return SGDState(count=jnp.zeros((), jnp.int32))
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(count=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.zeros_like, zeros))

    def _schedule(self, count: Array) -> Array:
        if self.warmup <= 0:
            return jnp.asarray(self.lr, jnp.float32)
        w = jnp.minimum(1.0, (count + 1) / self.warmup)
        return self.lr * w

    def update(self, g: PyTree, state, params: PyTree
               ) -> Tuple[PyTree, Any]:
        lr = self._schedule(state.count)
        if self.name == "sgd":
            delta = jax.tree.map(
                lambda gi, p: -lr * gi.astype(jnp.float32)
                - lr * self.weight_decay * p.astype(jnp.float32),
                g, params)
            return delta, SGDState(count=state.count + 1)
        if self.name != "adamw":
            raise ValueError(self.name)
        c = state.count + 1
        mu = jax.tree.map(lambda m, gi: self.b1 * m
                          + (1 - self.b1) * gi.astype(jnp.float32),
                          state.mu, g)
        nu = jax.tree.map(lambda v, gi: self.b2 * v
                          + (1 - self.b2) * jnp.square(gi.astype(jnp.float32)),
                          state.nu, g)
        bc1 = 1 - self.b1 ** c.astype(jnp.float32)
        bc2 = 1 - self.b2 ** c.astype(jnp.float32)
        delta = jax.tree.map(
            lambda m, v, p: -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
                                   + self.weight_decay * p.astype(jnp.float32)),
            mu, nu, params)
        return delta, AdamWState(count=c, mu=mu, nu=nu)


def paper_server(gamma: float) -> ServerOptimizer:
    return ServerOptimizer(name="sgd", lr=gamma)


def adamw_server(lr: float, weight_decay: float = 0.01,
                 warmup: int = 100) -> ServerOptimizer:
    return ServerOptimizer(name="adamw", lr=lr, weight_decay=weight_decay,
                           warmup=warmup)
