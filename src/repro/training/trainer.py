"""The production train step: Model x ShardedDasha x ServerOptimizer.

Step order is Algorithm 1, faithfully:

    1. x^{t+1} = x^t + server_update(g^t)        (paper: -gamma g^t)
    2. per-node stochastic grads at x^{t+1} AND x^t — what is evaluated
       depends on the variant (core/variants.py):
         * ``mvr``      — the same minibatch at both points (Alg. 5 pair)
         * ``gradient`` — the (fixed-batch) local gradient pair; the
           old-point gradient is deterministic, so it is CACHED from the
           previous round instead of re-evaluated (one vjp per step
           instead of two — exactness requires node batches fixed
           across rounds, the Alg. 2 full-gradient setting)
         * ``page``     — the shared Alg. 3 coin picks EITHER a full
           pass over the whole node batch OR a minibatch pass over the
           first ``page_mini_batch`` examples (two batch-shape paths in
           one step; ``lax.cond`` executes only the taken branch, so
           full-pass compute is paid only with probability p_page)
         * ``finite_mvr`` — each node's FIXED batch examples are the m
           finite-sum components: per round, ``component_batch`` of
           them are sampled without replacement (the engine's canonical
           ``k_oracle``), per-example gradients (n, B, *param) are
           evaluated at both points, and the engine carries the
           (n, m, *param) component trackers ``h_ij`` in its state
           (``TrainerConfig.num_components`` sizes them)
    3. node update: h_i, g_i, compressed messages m_i, aggregation -> g^{t+1}

The whole step is one jit-able function; the dry-run lowers it with
ShapeDtypeStructs for every (arch x input-shape x mesh) combination.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import variants
from repro.core.problems import sample_batch_indices
from repro.core.sharded import (ShardedDasha, ShardedDashaConfig,
                                ShardedDashaState, ShardedDispatch,
                                component_spec, estimator_spec, node_spec,
                                per_node_value_and_grads)
from repro.data.sharding import batch_specs
from repro.models.common import param_specs_like
from repro.models.model import Model
from repro.training.optim import ServerOptimizer

Array = jax.Array
PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    dasha: ShardedDashaState
    opt: Any
    step: Array
    # gradient-variant eval reuse: (losses (n,), per-node grads) at the
    # CURRENT params — next round's old-point pair.  () when disabled.
    cache: Any = ()


def _tree_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


class TrainMetrics(NamedTuple):
    loss: Array
    loss_old: Array
    grad_norm: Array      # ||g^{t+1}|| of the server estimator
    step: Array
    bits_sent: Array      # uplink bits this round, all nodes (engine-measured)
    participants: Array   # |S^t| this round


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    dasha: ShardedDashaConfig
    server: ServerOptimizer
    zero_init_variates: bool = True   # init_zero vs grads-at-x0 init
    fsdp: bool = True                 # shard params over the data axis too
    # page variant: per-node examples of the minibatch branch (the full
    # branch uses the whole node batch).
    page_mini_batch: int = 1
    # gradient variant: cache the old-point per-node gradients from the
    # previous round (None = auto: on iff variant == "gradient").
    # EXACT only when each node's batch is FIXED across rounds — which
    # is what the Alg. 2 deterministic-gradient setting means (the k_i
    # pair must be two evaluations of the same f_i).  Feed a constant
    # batch per node (launch/train.py does) or set this to False when
    # streaming data through the gradient variant anyway.
    cache_old_grads: Optional[bool] = None
    # finite_mvr variant (also a fixed-batch finite-sum setting):
    # m = examples per node in every batch (sizes the h_ij trackers)
    # and B = components sampled per round (without replacement).
    num_components: Optional[int] = None
    component_batch: int = 1


class Trainer:
    def __init__(self, model: Model, mesh: Mesh, cfg: TrainerConfig):
        rule = variants.get_rule(cfg.dasha.variant)
        if not rule.trainer_supported:
            raise ValueError(
                f"variant {cfg.dasha.variant!r} ({rule.algorithm}) is "
                "not supported by the LM trainer (DESIGN.md §8)")
        if rule.component_trackers:
            if cfg.num_components is None:
                raise ValueError(
                    "finite_mvr needs TrainerConfig.num_components "
                    "(= examples per node in every batch) to size the "
                    "h_ij component trackers")
            if not (1 <= cfg.component_batch <= cfg.num_components):
                raise ValueError(
                    f"need 1 <= component_batch <= num_components, got "
                    f"{cfg.component_batch} / {cfg.num_components}")
        self.model = model
        self.mesh = mesh
        self.cfg = cfg
        self.rule = rule
        self.cache_old = (cfg.cache_old_grads
                          if cfg.cache_old_grads is not None
                          else cfg.dasha.variant == "gradient")
        params_shape = jax.eval_shape(model.init_params, jax.random.key(0))
        self.param_specs = param_specs_like(
            params_shape, mesh, fsdp_axis="data" if cfg.fsdp else None)
        self.engine = ShardedDasha(mesh, self.param_specs, cfg.dasha)

    # ---- specs (for dry-run in_shardings) ------------------------------
    def state_specs(self) -> TrainState:
        ps = self.param_specs
        axes = self.cfg.dasha.data_axes
        lead = axes[0] if len(axes) == 1 else tuple(axes)
        nspec = jax.tree.map(
            lambda s: node_spec(s, axes), ps,
            is_leaf=lambda x: isinstance(x, P))
        espec = jax.tree.map(
            lambda s: estimator_spec(s, axes), ps,
            is_leaf=lambda x: isinstance(x, P))
        hij_spec = None
        if self.rule.component_trackers:
            hij_spec = jax.tree.map(
                lambda s: component_spec(s, axes), ps,
                is_leaf=lambda x: isinstance(x, P))
        params_shape = jax.eval_shape(self.model.init_params,
                                      jax.random.key(0))
        opt_state_shape = jax.eval_shape(self.cfg.server.init, params_shape)
        opt_spec = jax.tree.map(lambda _: P(), opt_state_shape)
        # mu/nu of adamw mirror params
        if hasattr(opt_state_shape, "mu"):
            opt_spec = type(opt_state_shape)(count=P(), mu=ps, nu=ps)
        cache_spec = (P(lead), nspec) if self.cache_old else ()
        return TrainState(
            params=ps,
            dasha=ShardedDashaState(g=espec, g_i=nspec, h_i=nspec,
                                    step=P(), h_ij=hij_spec),
            opt=opt_spec,
            step=P(),
            cache=cache_spec)

    def state_shapes(self, batch_shapes: PyTree) -> TrainState:
        del batch_shapes
        return jax.eval_shape(self._init_abstract, jax.random.key(0))

    def _init_abstract(self, key: Array) -> TrainState:
        params = self.model.init_params(key)
        dasha = self.engine.init_zero(
            params, num_components=self.cfg.num_components)
        opt = self.cfg.server.init(params)
        cache = ()
        if self.cache_old:
            n = self.engine.n_nodes
            cache = (jnp.zeros((n,), jnp.float32),
                     jax.tree.map(
                         lambda p: jnp.zeros((n,) + p.shape, p.dtype),
                         params))
        return TrainState(params=params, dasha=dasha, opt=opt,
                          step=jnp.zeros((), jnp.int32), cache=cache)

    # ---- init -----------------------------------------------------------
    def init(self, key: Array) -> TrainState:
        specs = self.state_specs()
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(self._init_abstract,
                       out_shardings=shardings)(key)

    # ---- the step --------------------------------------------------------
    def _advance_and_grads(self, state: TrainState, batch: PyTree,
                           key: Array):
        """Phases (1)-(2) of the step — the server update of the params
        with g^t plus the variant's per-node gradient oracles — shared
        verbatim between the sync :meth:`train_step` and the async
        :meth:`dispatch_step` (DESIGN.md §10)."""
        model, eng, cfg = self.model, self.engine, self.cfg

        # (1) server step with g^t
        delta, opt_new = cfg.server.update(state.dasha.g, state.opt,
                                           state.params)
        params_new = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            state.params, delta)

        # (2) the variant's per-node gradient oracles
        def node_loss(p, node_batch):
            return model.loss(p, node_batch)

        node_kwargs: Dict[str, Any] = {}
        cache_new = state.cache
        if self.rule.needs_minibatch:        # page: two batch-shape paths
            mini = jax.tree.map(lambda x: x[:, :cfg.page_mini_batch], batch)
            # Same coin derivation as the engine consumes inside
            # node_update (core/variants.py round-key contract), so the
            # branch we evaluate is the branch the kernel selects.
            _, k_oracle, _ = variants.round_keys(key, state.dasha.step)
            coin = variants.page_coin(variants.page_keys(k_oracle)[0],
                                      cfg.dasha.p_page)

            def full_pass(_):
                ln, gn = per_node_value_and_grads(node_loss, params_new,
                                                  batch)
                lo, go = per_node_value_and_grads(node_loss, state.params,
                                                  batch)
                z = jax.tree.map(jnp.zeros_like, gn)
                return ln, lo, gn, go, z, z

            def mini_pass(_):
                ln, bn = per_node_value_and_grads(node_loss, params_new,
                                                  mini)
                lo, bo = per_node_value_and_grads(node_loss, state.params,
                                                  mini)
                z = jax.tree.map(jnp.zeros_like, bn)
                return ln, lo, z, z, bn, bo

            # Only the taken branch runs: the full pass is paid with
            # probability p_page (the unused pair enters the kernel as
            # zeros and is discarded by the coin select).
            (losses_new, losses_old, g_new, g_old, b_new,
             b_old) = jax.lax.cond(coin, full_pass, mini_pass, None)
            node_kwargs = dict(mini_new=b_new, mini_old=b_old)
        elif self.rule.component_trackers:   # finite_mvr: per-example pair
            n, m_comp, B = (eng.n_nodes, cfg.num_components,
                            cfg.component_batch)
            # Alg. 4 randomness: the engine's canonical k_oracle draws
            # the without-replacement component indices (same derivation
            # node_update consumes for its own bookkeeping).
            _, k_oracle, _ = variants.round_keys(key, state.dasha.step)
            idx = sample_batch_indices(k_oracle, n, m_comp, B,
                                       replace=False)
            sel = jax.tree.map(
                lambda x: jnp.take_along_axis(
                    x, idx.reshape(idx.shape + (1,) * (x.ndim - 2)),
                    axis=1),
                batch)

            def comp_loss(p, example):
                # one example, re-batched to size 1 for the model loss
                return model.loss(
                    p, jax.tree.map(lambda v: v[None], example))

            vg = jax.vmap(jax.vmap(jax.value_and_grad(comp_loss),
                                   in_axes=(None, 0)),
                          in_axes=(None, 0))
            losses_new_c, g_new = vg(params_new, sel)   # (n, B, *param)
            losses_old_c, g_old = vg(state.params, sel)
            losses_new = jnp.mean(losses_new_c, axis=1)
            losses_old = jnp.mean(losses_old_c, axis=1)
            node_kwargs = dict(component_idx=idx)
        elif self.cache_old:                 # gradient: reuse old grads
            losses_new, g_new = per_node_value_and_grads(
                node_loss, params_new, batch)

            def fresh(_):
                return per_node_value_and_grads(node_loss, state.params,
                                                batch)

            losses_old, g_old = jax.lax.cond(
                state.step == 0, fresh, lambda _: state.cache, None)
            cache_new = (losses_new, g_new)
        else:                                # mvr: same-sample pair
            losses_new, g_new = per_node_value_and_grads(
                node_loss, params_new, batch)
            losses_old, g_old = per_node_value_and_grads(
                node_loss, state.params, batch)

        return (params_new, opt_new, cache_new, losses_new, losses_old,
                g_new, g_old, node_kwargs)

    def train_step(self, state: TrainState, batch: PyTree, key: Array
                   ) -> Tuple[TrainState, TrainMetrics]:
        (params_new, opt_new, cache_new, losses_new, losses_old,
         g_new, g_old, node_kwargs) = self._advance_and_grads(
            state, batch, key)

        # (3) DASHA-PP node/aggregation update
        # repro: ignore[prng-reuse] -- deliberate: the engine derives
        # its own (k_part, k_oracle, k_comp) streams from the round key
        # via variants.round_keys, domain-separated from the oracle
        # draws _advance_and_grads consumed
        dasha_new, wire = self.engine.node_update(
            g_new, g_old, state.dasha, key, **node_kwargs)

        gn = _tree_norm(dasha_new.g)
        metrics = TrainMetrics(loss=jnp.mean(losses_new),
                               loss_old=jnp.mean(losses_old),
                               grad_norm=gn,
                               step=state.step,
                               bits_sent=wire.bits_sent,
                               participants=wire.participants)
        return TrainState(params=params_new, dasha=dasha_new, opt=opt_new,
                          step=state.step + 1, cache=cache_new), metrics

    def dispatch_step(self, state: TrainState, batch: PyTree, key: Array,
                      participation_mask: Array
                      ) -> Tuple[TrainState, ShardedDispatch, TrainMetrics]:
        """One gang-scheduled round (DESIGN.md §10): the server update
        of the params with the CURRENT g plus the cohort's client-side
        work (:meth:`ShardedDasha.dispatch` over the mesh), WITHOUT
        applying the cohort's contribution — the scheduler buffers the
        returned :class:`ShardedDispatch` by virtual arrival time and
        commits it later through :meth:`commit_step`.

        ``participation_mask`` is the (n,) cohort the scheduler settled
        on (``sampled & idle & available``); the engine's round counter
        advances here so the key stream stays aligned with the sync
        path.  ``metrics.grad_norm`` reports ‖g^t‖ — the estimator this
        dispatch consumed (commits change g between rounds)."""
        (params_new, opt_new, cache_new, losses_new, losses_old,
         g_new, g_old, node_kwargs) = self._advance_and_grads(
            state, batch, key)

        # repro: ignore[prng-reuse] -- deliberate: same round_keys
        # domain separation as node_update above; the dispatch's
        # internal draw must match the scheduler's mask preview
        disp, wire = self.engine.dispatch(
            g_new, g_old, state.dasha, key,
            participation_mask=participation_mask, **node_kwargs)

        metrics = TrainMetrics(loss=jnp.mean(losses_new),
                               loss_old=jnp.mean(losses_old),
                               grad_norm=_tree_norm(state.dasha.g),
                               step=state.step,
                               bits_sent=wire.bits_sent,
                               participants=wire.participants)
        dasha_new = state.dasha._replace(step=state.dasha.step + 1)
        new_state = TrainState(params=params_new, dasha=dasha_new,
                               opt=opt_new, step=state.step + 1,
                               cache=cache_new)
        return new_state, disp, metrics

    def commit_step(self, state: TrainState, disp: ShardedDispatch,
                    weight: Array) -> TrainState:
        """Apply one buffered cohort with staleness weight ``w(s)``
        (:meth:`ShardedDasha.commit`)."""
        return state._replace(
            dasha=self.engine.commit(state.dasha, disp, weight))

    def jit_train_step(self, batch_example: PyTree):
        """jit with explicit shardings (used by train loop and dry-run)."""
        specs = self.state_specs()
        bspecs = batch_specs(batch_example, self.cfg.dasha.data_axes)
        to_shard = lambda tree: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(
            self.train_step,
            in_shardings=(to_shard(specs), to_shard(bspecs), None),
            out_shardings=(to_shard(specs), None),
            donate_argnums=(0,),
        )

    # ---- the async (gang-scheduled) halves -------------------------------
    def dispatch_specs(self) -> ShardedDispatch:
        """PartitionSpecs of one cohort's :class:`ShardedDispatch`."""
        ps = self.param_specs
        axes = self.cfg.dasha.data_axes
        lead = axes[0] if len(axes) == 1 else tuple(axes)
        nspec = jax.tree.map(lambda s: node_spec(s, axes), ps,
                             is_leaf=lambda x: isinstance(x, P))
        espec = jax.tree.map(lambda s: estimator_spec(s, axes), ps,
                             is_leaf=lambda x: isinstance(x, P))
        hij_spec = None
        if self.rule.component_trackers:
            hij_spec = jax.tree.map(lambda s: component_spec(s, axes), ps,
                                    is_leaf=lambda x: isinstance(x, P))
        return ShardedDispatch(h_new=nspec, g_i_inc=nspec, g_delta=espec,
                               h_ij_new=hij_spec, part=P(lead))

    def jit_dispatch_step(self, batch_example: PyTree):
        """jit of :meth:`dispatch_step` with explicit shardings; the
        (n,) participation mask rides the data axes."""
        specs = self.state_specs()
        bspecs = batch_specs(batch_example, self.cfg.dasha.data_axes)
        axes = self.cfg.dasha.data_axes
        lead = axes[0] if len(axes) == 1 else tuple(axes)
        to_shard = lambda tree: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(
            self.dispatch_step,
            in_shardings=(to_shard(specs), to_shard(bspecs), None,
                          NamedSharding(self.mesh, P(lead))),
            out_shardings=(to_shard(specs), to_shard(self.dispatch_specs()),
                           None),
        )

    def jit_commit_step(self):
        """jit of :meth:`commit_step`; the weight is a traced scalar so
        one compilation serves every staleness level."""
        return jax.jit(self.commit_step, donate_argnums=(0,))
