"""The production train step: Model x ShardedDasha x ServerOptimizer.

Step order is Algorithm 1, faithfully:

    1. x^{t+1} = x^t + server_update(g^t)        (paper: -gamma g^t)
    2. per-node stochastic grads at x^{t+1} AND x^t with the *same*
       minibatch (Alg. 5 MVR pair; DESIGN.md §3)
    3. node update: h_i, g_i, compressed messages m_i, aggregation -> g^{t+1}

The whole step is one jit-able function; the dry-run lowers it with
ShapeDtypeStructs for every (arch x input-shape x mesh) combination.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.sharded import (ShardedDasha, ShardedDashaConfig,
                                ShardedDashaState, estimator_spec, node_spec,
                                per_node_value_and_grads)
from repro.data.sharding import batch_specs
from repro.models.common import param_specs_like
from repro.models.model import Model
from repro.training.optim import ServerOptimizer

Array = jax.Array
PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    dasha: ShardedDashaState
    opt: Any
    step: Array


class TrainMetrics(NamedTuple):
    loss: Array
    loss_old: Array
    grad_norm: Array      # ||g^{t+1}|| of the server estimator
    step: Array


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    dasha: ShardedDashaConfig
    server: ServerOptimizer
    zero_init_variates: bool = True   # init_zero vs grads-at-x0 init
    fsdp: bool = True                 # shard params over the data axis too


class Trainer:
    def __init__(self, model: Model, mesh: Mesh, cfg: TrainerConfig):
        self.model = model
        self.mesh = mesh
        self.cfg = cfg
        params_shape = jax.eval_shape(model.init_params, jax.random.key(0))
        self.param_specs = param_specs_like(
            params_shape, mesh, fsdp_axis="data" if cfg.fsdp else None)
        self.engine = ShardedDasha(mesh, self.param_specs, cfg.dasha)

    # ---- specs (for dry-run in_shardings) ------------------------------
    def state_specs(self) -> TrainState:
        ps = self.param_specs
        axes = self.cfg.dasha.data_axes
        nspec = jax.tree.map(
            lambda s: node_spec(s, axes), ps,
            is_leaf=lambda x: isinstance(x, P))
        espec = jax.tree.map(
            lambda s: estimator_spec(s, axes), ps,
            is_leaf=lambda x: isinstance(x, P))
        params_shape = jax.eval_shape(self.model.init_params,
                                      jax.random.key(0))
        opt_state_shape = jax.eval_shape(self.cfg.server.init, params_shape)
        opt_spec = jax.tree.map(lambda _: P(), opt_state_shape)
        # mu/nu of adamw mirror params
        if hasattr(opt_state_shape, "mu"):
            opt_spec = type(opt_state_shape)(count=P(), mu=ps, nu=ps)
        return TrainState(
            params=ps,
            dasha=ShardedDashaState(g=espec, g_i=nspec, h_i=nspec, step=P()),
            opt=opt_spec,
            step=P())

    def state_shapes(self, batch_shapes: PyTree) -> TrainState:
        del batch_shapes
        return jax.eval_shape(self._init_abstract, jax.random.key(0))

    def _init_abstract(self, key: Array) -> TrainState:
        params = self.model.init_params(key)
        dasha = self.engine.init_zero(params)
        opt = self.cfg.server.init(params)
        return TrainState(params=params, dasha=dasha, opt=opt,
                          step=jnp.zeros((), jnp.int32))

    # ---- init -----------------------------------------------------------
    def init(self, key: Array) -> TrainState:
        specs = self.state_specs()
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(self._init_abstract,
                       out_shardings=shardings)(key)

    # ---- the step --------------------------------------------------------
    def train_step(self, state: TrainState, batch: PyTree, key: Array
                   ) -> Tuple[TrainState, TrainMetrics]:
        model, eng, cfg = self.model, self.engine, self.cfg

        # (1) server step with g^t
        delta, opt_new = cfg.server.update(state.dasha.g, state.opt,
                                           state.params)
        params_new = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            state.params, delta)

        # (2) same-sample per-node gradient pair (Alg. 5)
        def node_loss(p, node_batch):
            return model.loss(p, node_batch)

        losses_new, g_new = per_node_value_and_grads(node_loss, params_new,
                                                     batch)
        losses_old, g_old = per_node_value_and_grads(node_loss, state.params,
                                                     batch)

        # (3) DASHA-PP node/aggregation update
        dasha_new = eng.node_update(g_new, g_old, state.dasha, key)

        gn = jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(dasha_new.g)))
        metrics = TrainMetrics(loss=jnp.mean(losses_new),
                               loss_old=jnp.mean(losses_old),
                               grad_norm=gn,
                               step=state.step)
        return TrainState(params=params_new, dasha=dasha_new, opt=opt_new,
                          step=state.step + 1), metrics

    def jit_train_step(self, batch_example: PyTree):
        """jit with explicit shardings (used by train loop and dry-run)."""
        specs = self.state_specs()
        bspecs = batch_specs(batch_example, self.cfg.dasha.data_axes)
        to_shard = lambda tree: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(
            self.train_step,
            in_shardings=(to_shard(specs), to_shard(bspecs), None),
            out_shardings=(to_shard(specs), None),
            donate_argnums=(0,),
        )
