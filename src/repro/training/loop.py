"""Training-loop driver: batches -> jit step -> metrics/checkpoints."""
from __future__ import annotations

from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.data.sharding import place_batch
from repro.training.checkpoints import save_checkpoint
from repro.training.metrics import MetricsLogger
from repro.training.trainer import Trainer, TrainState


def train(trainer: Trainer, state: TrainState,
          batches: Iterator[dict], num_steps: int,
          logger: Optional[MetricsLogger] = None,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 0,
          log_every: int = 10,
          seed: int = 0) -> TrainState:
    logger = logger or MetricsLogger(print_every=log_every)
    first = next(batches)
    step_fn = trainer.jit_train_step(first)
    mesh = trainer.mesh
    data_axes = trainer.cfg.dasha.data_axes

    batch = first
    for i in range(num_steps):
        placed = place_batch(batch, mesh, data_axes)
        key = jax.random.key(seed + i)
        state, metrics = step_fn(state, placed, key)
        if i % log_every == 0 or i == num_steps - 1:
            logger.log(i, loss=metrics.loss, grad_norm=metrics.grad_norm,
                       bits_sent=metrics.bits_sent,
                       participants=metrics.participants)
        if checkpoint_dir and checkpoint_every and (i + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, state, i + 1)
        if i < num_steps - 1:
            batch = next(batches)
    return state
