"""Training-loop driver: batches -> jit step -> metrics/checkpoints.

Resume contract: both the per-round randomness and the checkpoint
numbering derive from the GLOBAL step carried in ``state.step``, not
the loop-local iteration index — a run resumed from a restored
``TrainState`` continues the key stream where it left off instead of
replaying round 0's randomness, and its checkpoints never overwrite
the earlier run's files (tests/test_training_resume.py).
"""
from __future__ import annotations

from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.data.sharding import place_batch
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.training.checkpoints import save_checkpoint
from repro.training.metrics import MetricsLogger
from repro.training.trainer import Trainer, TrainState


def round_train_key(seed: int, global_step: int) -> jax.Array:
    """The canonical per-round key of the LM training loops — shared by
    the sync loop below and the gang-scheduled cohort scheduler
    (repro/fl/cohorts.py), so the two runtimes consume identical
    randomness for a given global step (the trainer-scale sync-limit
    parity contract, DESIGN.md §10)."""
    return jax.random.key(seed + global_step)


def train(trainer: Trainer, state: TrainState,
          batches: Iterator[dict], num_steps: int,
          logger: Optional[MetricsLogger] = None,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 0,
          log_every: int = 10,
          seed: int = 0) -> TrainState:
    logger = logger or MetricsLogger(print_every=log_every)
    first = next(batches)
    step_fn = trainer.jit_train_step(first)
    mesh = trainer.mesh
    data_axes = trainer.cfg.dasha.data_axes
    start = int(jax.device_get(state.step))

    batch = first
    # per-step device scalars, summed once at the end: publishing the
    # wire ledger must not force a host sync every step
    bits_seen = []
    parts_seen = []
    metrics = None
    for i in range(num_steps):
        gstep = start + i
        placed = place_batch(batch, mesh, data_axes)
        key = round_train_key(seed, gstep)
        with obs_trace.span("train.step", track="train", step=gstep):
            state, metrics = step_fn(state, placed, key)
        bits_seen.append(metrics.bits_sent)
        parts_seen.append(metrics.participants)
        if i % log_every == 0 or i == num_steps - 1:
            logger.log(gstep, loss=metrics.loss, grad_norm=metrics.grad_norm,
                       bits_sent=metrics.bits_sent,
                       participants=metrics.participants)
        if checkpoint_dir and checkpoint_every \
                and (gstep + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, state, gstep + 1)
        if i < num_steps - 1:
            batch = next(batches)
    if metrics is not None:
        reg = obs_metrics.get_registry()
        reg.gauge("train.bits_sent").set(
            float(np.sum(jax.device_get(bits_seen), dtype=np.float64)))
        # one oracle call per participating node per round
        reg.gauge("train.oracle_calls").set(
            float(np.sum(jax.device_get(parts_seen), dtype=np.float64)))
        reg.gauge("train.steps").set(float(num_steps))
        reg.gauge("train.loss").set(float(jax.device_get(metrics.loss)))
    return state
