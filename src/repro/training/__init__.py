"""repro.training substrate."""
