"""Minimal dependency-free checkpointing: pytrees -> .npz + structure
manifest.  Handles NamedTuples/dicts/tuples and restores onto the mesh
with the trainer's shardings."""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save_checkpoint(path: str, state: PyTree, step: int) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, paths, _ = _flatten_with_paths(state)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    np.savez(fname, **arrays)
    manifest = {"step": step, "paths": paths, "num_leaves": len(leaves)}
    with open(os.path.join(path, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(path, "latest"), "w") as f:
        f.write(str(step))
    return fname


def latest_step(path: str) -> Optional[int]:
    marker = os.path.join(path, "latest")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        return int(f.read().strip())


def restore_checkpoint(path: str, state_like: PyTree,
                       step: Optional[int] = None) -> PyTree:
    """``state_like`` supplies structure + shardings (its leaves may be
    concrete arrays or ShapeDtypeStructs with shardings)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(state_like)
    if len(leaves) != len(data.files):
        raise ValueError(
            f"checkpoint has {len(data.files)} leaves, state expects "
            f"{len(leaves)}")
    new_leaves = []
    for i, like in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        sharding = getattr(like, "sharding", None)
        x = jnp.asarray(arr, dtype=like.dtype)
        if sharding is not None:
            x = jax.device_put(x, sharding)
        new_leaves.append(x)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
