"""Hierarchical edge-aggregator fleet for async DASHA-PP (DESIGN.md §12).

The flat :class:`~repro.fl.server.AsyncDashaServer` delivers every
client's compressed increment straight to the root — fine for tens of
clients, not for the ROADMAP's million-client fleet.  This runtime
interposes a configurable aggregation *tree*: clients report to edge
aggregators (tier 0), edges pre-reduce and forward to tier 1, …, the
top tier reports to the root server.  Per tier:

* **Pre-reduction**: an aggregator merges the buffered contributions
  into per-dispatch-round partial sums (float64 accumulation of the
  float32 client messages), so the root applies one weighted group per
  (message, dispatch round) instead of one per client.  Grouping by
  dispatch round is what lets the root keep the flat server's
  staleness semantics exactly: a group dispatched at round ``r`` and
  committed at round ``t`` is weighted by ``w(t - r)`` from the same
  :mod:`repro.fl.staleness` policy registry, and the per-hop stamps it
  carries telescope to ``t - r`` (:func:`repro.fl.staleness.
  compose_hops`; tests/test_tree_invariants.py).
* **FedBuff-style buffering**: ``buffer_size=K`` flushes after exactly
  ``K`` buffered items; ``None`` is the barrier tier — it flushes when
  its subtree is quiet (no live contribution below it still in
  flight).  The root has the same knob over *messages*.
* **Compressed-uplink accounting**: every tier message is priced on
  the wire sparse-or-dense — ``min(nnz·(value_bits + ceil(log2 d)),
  d·value_bits)`` per round-group plus a round header — so compression
  that survives pre-reduction (union of RandK supports below d) keeps
  paying upstream, and the per-hop totals sum into the existing
  ``bits_cum`` metric.
* **Out-of-core client state**: the per-client trackers live in a
  :class:`~repro.fl.client_store.ClientStore` chunked by edge (numpy
  or memmap), so a round touches cohort rows only and ``n`` scales to
  1e6+ without an (n, d) resident array.

State-write placement: an edge *owns* its clients' tracker shards and
writes ``h_i`` (and ``h_ij``) when it flushes the contribution upstream;
the root writes ``g_i``/``g`` at commit (the ack broadcast).  A
contribution discarded for staleness *at its own edge* is therefore
discarded whole, exactly like the flat server; one discarded higher up
keeps its (already correct) local tracker write but contributes nothing
to ``g``/``g_i``.  With the depth-0 tree (``tiers=()``) clients feed
the root directly and all writes happen at commit — the flat semantics.

Mid-flight dropout: a dropped client's non-arrival is *detected at its
edge* at the would-be arrival time (barrier tiers hold the flush until
then), its contribution is excluded everywhere, and the client re-enters
through a REJOIN event ``rejoin_s`` after detection.

Sync-limit parity contract (tests/test_fleet.py): a depth-1 tree with
zero jitter, barrier buffers everywhere and no availability process
reproduces the synchronous :meth:`DashaPP.run` trajectory allclose for
all four variants, pallas on/off — the fleet is an anchored
generalization of the reference engine, through the same
:meth:`DashaPP.dispatch`.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import (Any, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import variants, wire
from repro.core.compressors import Compressor
from repro.core.dasha_pp import DashaPP, DashaPPConfig, DashaPPState
from repro.core.participation import ParticipationSampler
from repro.fl.client_store import ClientStore, edge_partition
from repro.fl.events import (ARRIVAL, DROP, REJOIN, TIER_ARRIVAL,
                             EventQueue)
from repro.fl.latency import LatencyModel, PoissonAvailability
from repro.fl.staleness import compose_hops, make_staleness
from repro.obs import metrics as obs_metrics
from repro.obs import monitors as obs_monitors
from repro.obs import trace as obs_trace

Array = jax.Array

ROOT = ("root",)            # pending-counter key for the root server

# bit accounting is single-sourced in the core wire model; re-exported
# here because the fleet public API grew up around these names
GROUP_HEADER_BITS = wire.GROUP_HEADER_BITS
payload_bits = wire.payload_bits


# ----------------------------------------------------------------------
# Configuration
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierConfig:
    """One aggregator tier.  ``buffer_size=None`` is the barrier tier
    (flush when the subtree is quiet); ``K`` flushes after exactly K
    buffered items.  ``latency`` prices the aggregator→parent uplink
    (reliable transport: dropout on infrastructure links is rejected);
    ``max_staleness`` discards contributions whole at flush time."""
    aggregators: int
    buffer_size: Optional[int] = None
    latency: Optional[LatencyModel] = None
    max_staleness: Optional[int] = None

    def __post_init__(self):
        if self.aggregators < 1:
            raise ValueError("aggregators must be >= 1")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1 (or None)")
        if self.latency is not None and self.latency.dropout > 0.0:
            raise ValueError("tier uplinks are infrastructure links; "
                             "dropout belongs on the client latency "
                             "model, not a TierConfig")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Tree topology + root policy.  ``tiers=()`` is the depth-0
    (flat) fleet: clients feed the root directly."""
    tiers: Tuple[TierConfig, ...] = ()
    buffer_size: Optional[int] = None      # root K (messages); None=barrier
    staleness_policy: str = "power"
    staleness_exponent: float = 0.5
    max_staleness: Optional[int] = None
    value_bits: float = wire.FLOAT_BITS

    def __post_init__(self):
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1 (or None)")
        make_staleness(self.staleness_policy)   # raises on unknown names
        for lo, hi in zip(self.tiers[1:], self.tiers[:-1]):
            if lo.aggregators > hi.aggregators:
                raise ValueError("tiers must narrow toward the root")

    @property
    def depth(self) -> int:
        return len(self.tiers)


# ----------------------------------------------------------------------
# Workloads: what one dispatch computes (the client-side math)
# ----------------------------------------------------------------------

class FleetDispatch(NamedTuple):
    """One round of client work for the dispatched cohort only."""
    x_new: np.ndarray                 # (d,)   float32
    idx: np.ndarray                   # (C,)   global client ids
    m_rows: np.ndarray                # (C, d) compressed uplink messages
    h_rows: np.ndarray                # (C, d) tracker rows after update
    hij_rows: Optional[np.ndarray]    # (C, m, d) component-tracker delta
    oracle_calls: float


class FleetWorkload:
    """The client-side math of one round.  ``dispatch`` computes rows
    for the cohort ONLY (against tracker rows gathered from the store),
    which is what keeps the runtime O(cohort) per round regardless of
    ``n``."""

    sampler: ParticipationSampler
    n: int
    d: int
    wire_bits: float
    has_hij: bool = False

    def store_fields(self) -> Mapping[str, Tuple[int, ...]]:
        raise NotImplementedError

    def init(self, key: Array, x0: np.ndarray, store: ClientStore
             ) -> Tuple[np.ndarray, np.ndarray]:
        """Populate the store; return ``(x0_f32, g0_f64)``."""
        raise NotImplementedError

    def dispatch(self, key_t: Array, t: int, x: np.ndarray,
                 g: np.ndarray, store: ClientStore,
                 eff: np.ndarray) -> FleetDispatch:
        raise NotImplementedError

    def measure(self, x: np.ndarray, g: np.ndarray
                ) -> Tuple[float, float]:
        raise NotImplementedError


class DenseProblemWorkload(FleetWorkload):
    """Reference-scale workload over a :class:`DistributedProblem`,
    routed through the *exact* :meth:`DashaPP.dispatch` (all four
    variants, pallas on/off) — the parity anchor.  Materializes (n, d)
    per dispatch, so reference scale only."""

    def __init__(self, problem, compressor: Compressor,
                 sampler: ParticipationSampler, config: DashaPPConfig):
        self.engine = DashaPP(problem, compressor, sampler, config)
        self.problem = problem
        self.sampler = sampler
        self.cfg = config
        self.n, self.d = problem.n, problem.d
        self.has_hij = variants.get_rule(config.variant).component_trackers
        self.wire_bits = float(compressor.wire_bits(problem.d))
        self._dispatch = jax.jit(self.engine.dispatch)
        self._measure = jax.jit(
            lambda x: (problem.loss(x),
                       jnp.sum(problem.full_grad(x) ** 2)))

    def store_fields(self):
        fields = {"g_i": (self.d,), "h_i": (self.d,)}
        if self.has_hij:
            fields["h_ij"] = (self.problem.m, self.d)
        return fields

    def init(self, key, x0, store):
        state = self.engine.init(key, jnp.asarray(x0, jnp.float32))
        everyone = np.arange(self.n)
        store.scatter_set("g_i", everyone, np.asarray(state.g_i))
        store.scatter_set("h_i", everyone, np.asarray(state.h_i))
        if self.has_hij:
            store.scatter_set("h_ij", everyone, np.asarray(state.h_ij))
        return (np.asarray(state.x, np.float32),
                np.asarray(state.g, np.float64))

    def _state(self, t: int, x, g, store) -> DashaPPState:
        everyone = np.arange(self.n)
        hij = (jnp.asarray(store.gather("h_ij", everyone))
               if self.has_hij else None)
        return DashaPPState(
            x=jnp.asarray(x, jnp.float32),
            g=jnp.asarray(g, jnp.float32),
            g_i=jnp.asarray(store.gather("g_i", everyone)),
            h_i=jnp.asarray(store.gather("h_i", everyone)),
            h_ij=hij, step=jnp.asarray(t, jnp.int32))

    def dispatch(self, key_t, t, x, g, store, eff):
        out = self._dispatch(key_t, self._state(t, x, g, store),
                             jnp.asarray(eff))
        idx = np.nonzero(eff)[0]
        hij = (np.asarray(out.h_ij_delta, np.float32)[idx]
               if self.has_hij else None)
        return FleetDispatch(
            x_new=np.asarray(out.x_new, np.float32), idx=idx,
            m_rows=np.asarray(out.m_i, np.float32)[idx],
            h_rows=np.asarray(out.h_new, np.float32)[idx],
            hij_rows=hij, oracle_calls=float(out.oracle_calls))

    def measure(self, x, g):
        loss, gnsq = self._measure(jnp.asarray(x, jnp.float32))
        return float(loss), float(gnsq)


class StreamedGradientWorkload(FleetWorkload):
    """Fleet-scale workload: DASHA-PP gradient variant (Alg. 2) over
    per-client synthetic logistic-sigmoid data (paper eq. 11) that is
    *regenerated from the client's key on demand* — no (n, m, d)
    dataset, no (n, d) dispatch.  One round computes gradients,
    trackers and compressed messages for the C cohort rows only
    (cohort size is constant under s-nice samplers, so the jit traces
    once).  Loss/grad-norm are estimated on a fixed client subset."""

    def __init__(self, *, sampler: ParticipationSampler, d: int,
                 compressor: Compressor, gamma: float, a: float,
                 b: float, m_per_client: int = 2,
                 heterogeneity: float = 0.5, data_seed: int = 0,
                 init_chunk: int = 16384, eval_clients: int = 256):
        self.sampler = sampler
        self.n, self.d = int(sampler.n), int(d)
        self.gamma, self.a, self.b = float(gamma), float(a), float(b)
        self.m_per_client = int(m_per_client)
        self.wire_bits = float(compressor.wire_bits(d))
        self.has_hij = False
        self._init_chunk = int(init_chunk)
        pa = float(sampler.p_a)

        kd = jax.random.key(data_seed)
        k_star, self._k_data = jax.random.split(kd)
        w_star = jax.random.normal(k_star, (d,)) / jnp.sqrt(float(d))

        def client_data(cid):
            kc = jax.random.fold_in(self._k_data, cid)
            kf, ks = jax.random.split(kc)
            feats = jax.random.normal(kf, (m_per_client, d))
            w_c = w_star + heterogeneity * (
                jax.random.normal(ks, (d,)) / jnp.sqrt(float(d)))
            y = jnp.where(feats @ w_c >= 0, 1.0, -1.0)
            return feats, y

        def client_grad(cid, x):
            feats, y = client_data(cid)
            z = (feats @ x) * y
            s = jax.nn.sigmoid(-z)
            coef = -2.0 * s**2 * (1.0 - s) * y
            return jnp.mean(coef[:, None] * feats, axis=0)

        def client_loss(cid, x):
            feats, y = client_data(cid)
            return jnp.mean(jax.nn.sigmoid(-(feats @ x) * y) ** 2)

        grad_rows = jax.vmap(client_grad, in_axes=(0, None))
        self._grad_rows = jax.jit(grad_rows)

        def rows(k_comp, idx, x_new, x_old, h, g_i):
            gn = grad_rows(idx, x_new)
            go = grad_rows(idx, x_old)
            k = variants.k_same_sample(gn, go, h, b=b)
            h_new = h + k / pa
            payload = k / pa - (a / pa) * (g_i - h)
            keys = jax.vmap(
                lambda i: variants.leaf_node_key(k_comp, 0, i))(idx)
            m = jax.vmap(compressor.compress)(keys, payload)
            return m, h_new

        self._rows = jax.jit(rows)

        n_eval = min(self.n, int(eval_clients))
        stride = max(1, self.n // n_eval)
        self._eval_idx = jnp.arange(n_eval, dtype=jnp.int32) * stride

        def measure(x):
            losses = jax.vmap(client_loss, in_axes=(0, None))(
                self._eval_idx, x)
            grads = grad_rows(self._eval_idx, x)
            return jnp.mean(losses), jnp.sum(jnp.mean(grads, 0) ** 2)

        self._measure = jax.jit(measure)

    def store_fields(self):
        return {"g_i": (self.d,), "h_i": (self.d,)}

    def init(self, key, x0, store):
        del key   # h0 = exact local gradient; data is its own seed
        x = np.asarray(x0, np.float32)
        xj = jnp.asarray(x)
        g_sum = np.zeros(self.d, np.float64)
        for lo in range(0, self.n, self._init_chunk):
            hi = min(self.n, lo + self._init_chunk)
            idx = np.arange(lo, hi)
            h0 = np.asarray(self._grad_rows(jnp.asarray(idx), xj),
                            np.float32)
            store.scatter_set("h_i", idx, h0)
            store.scatter_set("g_i", idx, h0)
            g_sum += h0.sum(axis=0, dtype=np.float64)
        return x, g_sum / self.n

    def dispatch(self, key_t, t, x, g, store, eff):
        x_new = (x - self.gamma * g).astype(np.float32)
        idx = np.nonzero(eff)[0]
        if len(idx) == 0:
            empty = np.zeros((0, self.d), np.float32)
            return FleetDispatch(x_new, idx, empty, empty, None, 0.0)
        _, _, k_comp = variants.round_keys(key_t)
        # Pad the cohort to the next power of two so the jit retraces
        # O(log n) times as busy-skips shrink the effective cohort,
        # not once per distinct size.  Padded rows (duplicates of the
        # last client) are computed and discarded.
        C = len(idx)
        P = 1 << (C - 1).bit_length()
        idx_p = np.concatenate([idx, np.full(P - C, idx[-1])])
        h = jnp.asarray(store.gather("h_i", idx_p))
        g_i = jnp.asarray(store.gather("g_i", idx_p))
        m, h_new = self._rows(k_comp, jnp.asarray(idx_p),
                              jnp.asarray(x_new), jnp.asarray(x), h, g_i)
        # repro: ignore[host-sync] -- the fleet handoff IS host-side:
        # contribution rows enter the event queue as numpy (one sync
        # per dispatch, amortized over the whole cohort)
        return FleetDispatch(
            x_new=x_new, idx=idx,
            m_rows=np.asarray(m, np.float32)[:C],
            h_rows=np.asarray(h_new, np.float32)[:C], hij_rows=None,
            oracle_calls=float(2 * self.m_per_client * C))

    def measure(self, x, g):
        loss, gnsq = self._measure(jnp.asarray(x, jnp.float32))
        return float(loss), float(gnsq)


# ----------------------------------------------------------------------
# Runtime records
# ----------------------------------------------------------------------

class MessageRecord(NamedTuple):
    """One tier flush, as measured on the wire."""
    tier: int
    agg: int
    round_idx: int
    bits: float
    n_groups: int
    n_members: int
    forced: bool


class CommitRecord(NamedTuple):
    """One contribution's life, stamped at root commit."""
    client: int
    dispatch_round: int
    hops: Tuple[Tuple[int, int], ...]   # (tier, root-round at flush)
    commit_round: int
    staleness: int
    weight: float


class _Contrib(NamedTuple):
    client: int
    round_idx: int
    m: np.ndarray
    h: Optional[np.ndarray]
    hij: Optional[np.ndarray]


@dataclasses.dataclass
class _Msg:
    src_tier: int
    src_agg: int
    # groups: dispatch round -> [float64 partial sum, member cids]
    groups: Dict[int, Tuple[np.ndarray, List[int]]]
    bits: float
    n_members: int


class FleetState(NamedTuple):
    x: np.ndarray            # (d,) float32
    g: np.ndarray            # (d,) float64 root estimator
    store: ClientStore       # per-client trackers (g_i, h_i[, h_ij])


@dataclasses.dataclass
class FleetRunResult:
    """Per-root-step trajectories + end-of-run trace aggregates."""
    time: np.ndarray
    loss: np.ndarray
    grad_norm_sq: np.ndarray
    committed: np.ndarray          # contributions applied per step
    committed_msgs: np.ndarray     # root buffer units applied per step
    participants: np.ndarray
    skipped_busy: np.ndarray
    skipped_offline: np.ndarray
    staleness_mean: np.ndarray
    staleness_max: np.ndarray
    bits_cum: np.ndarray           # cumulative wire bits over ALL hops
    root_bits_cum: np.ndarray      # cumulative bits delivered to the root
    staleness_hist: Dict[int, int]
    tier_bits: np.ndarray          # (depth+1,) final per-hop totals
    dropped: int
    discarded_stale: int
    forced_flushes: int
    total_time: float
    event_log: List[Tuple[float, int, str, int, int]]
    message_log: List[MessageRecord]
    commit_log: List[CommitRecord]
    flush_sizes: Dict[int, Dict[int, int]]   # tier -> {#members: count}


# ----------------------------------------------------------------------
# The fleet runtime
# ----------------------------------------------------------------------

class HierarchicalFleet:
    """Event-driven aggregation tree over a :class:`FleetWorkload`.
    ``run(key, x0, num_rounds)`` plays the whole schedule and returns
    ``(FleetState, FleetRunResult)``."""

    def __init__(self, workload: FleetWorkload, fleet_config: FleetConfig,
                 latency: LatencyModel,
                 availability: Optional[PoissonAvailability] = None, *,
                 store_backend: str = "ram",
                 store_dir: Optional[str] = None):
        self.workload = workload
        self.fcfg = fleet_config
        self.latency = latency
        self.availability = availability
        self.store_backend = store_backend
        self.store_dir = store_dir

        T = fleet_config.depth
        n = workload.n
        # Tier 0 partitions clients; tier k+1 partitions tier-k aggs.
        # With tiers=() clients form one chunk feeding the root.
        first = fleet_config.tiers[0].aggregators if T else 1
        self.bounds = edge_partition(n, first)
        self._parents: List[np.ndarray] = []
        for k in range(T - 1):
            pb = edge_partition(fleet_config.tiers[k].aggregators,
                                fleet_config.tiers[k + 1].aggregators)
            self._parents.append(
                np.searchsorted(pb, np.arange(
                    fleet_config.tiers[k].aggregators),
                    side="right") - 1)

    # -- static topology helpers ---------------------------------------
    def _edge_of(self, client: int) -> int:
        return int(np.searchsorted(self.bounds, client, side="right") - 1)

    def _path(self, client: int) -> List[Tuple[int, int]]:
        """Aggregator (tier, index) chain from edge to top tier."""
        T = self.fcfg.depth
        if T == 0:
            return []
        path = [(0, self._edge_of(client))]
        for k in range(T - 1):
            path.append((k + 1, int(self._parents[k][path[-1][1]])))
        return path

    # -- the event loop -------------------------------------------------
    def run(self, key: Array, x0, num_rounds: int
            ) -> Tuple[FleetState, FleetRunResult]:
        wl, fcfg = self.workload, self.fcfg
        n, d, T = wl.n, wl.d, fcfg.depth
        K_root = fcfg.buffer_size
        policy = make_staleness(fcfg.staleness_policy,
                                exponent=fcfg.staleness_exponent)
        store = ClientStore(self.bounds, wl.store_fields(),
                            backend=self.store_backend,
                            directory=self.store_dir)
        init_key, run_key = jax.random.split(key)
        x, g = wl.init(init_key, np.asarray(x0, np.float32), store)
        g = np.asarray(g, np.float64)

        q = EventQueue()
        now = 0.0
        obs_trace.set_virtual_time(now)
        round_now = 0                       # the root's round clock
        idle = np.ones(n, bool)
        contribs: Dict[int, _Contrib] = {}
        hops: Dict[int, List[Tuple[int, int]]] = {}
        client_cid: Dict[int, int] = {}     # busy client -> live cid
        msgs: Dict[int, _Msg] = {}
        next_id = 0
        buffers = {(k, j): []
                   for k in range(T)
                   for j in range(fcfg.tiers[k].aggregators)}
        pending: Dict[Any, int] = dict.fromkeys(buffers, 0)
        pending[ROOT] = 0
        root_buffer: List[int] = []         # mids (or cids when T == 0)
        flush_seq: Counter = Counter()
        hop_bits = np.zeros(T + 1, np.float64)
        dropped = discarded = forced_flushes = 0
        hist: Counter = Counter()
        flush_sizes: Dict[int, Counter] = {k: Counter() for k in range(T)}
        message_log: List[MessageRecord] = []
        commit_log: List[CommitRecord] = []
        rows: List[Dict[str, Any]] = []

        def discard_contrib(cid: int, arrived_through: int) -> None:
            """Kill a live contribution: free its client, and release
            the pending counts of every tree level it never reached
            (levels <= ``arrived_through`` already decremented at their
            arrivals; -1 = nothing reached)."""
            nonlocal discarded
            c = contribs.pop(cid)
            hops.pop(cid, None)
            idle[c.client] = True
            client_cid.pop(c.client, None)
            discarded += 1
            for (k, j) in self._path(c.client):
                if k > arrived_through:
                    pending[(k, j)] -= 1
                    maybe_flush(k, j)
            pending[ROOT] -= 1

        def flush(k: int, j: int, nitems: int, forced: bool) -> None:
            """Merge the first ``nitems`` buffered items of aggregator
            (k, j) into one upstream message."""
            nonlocal next_id, forced_flushes
            tier = fcfg.tiers[k]
            buf = buffers[(k, j)]
            items, buffers[(k, j)] = buf[:nitems], buf[nitems:]
            groups: Dict[int, Tuple[np.ndarray, List[int]]] = {}
            members: List[int] = []

            def add(r: int, vec64: np.ndarray, cids: List[int]):
                if r not in groups:
                    groups[r] = (np.zeros(d, np.float64), [])
                groups[r][0][:] += vec64
                groups[r][1].extend(cids)
                members.extend(cids)

            if k == 0:
                h_idx: List[int] = []
                h_rows: List[np.ndarray] = []
                hij_rows: List[np.ndarray] = []
                for cid in items:
                    c = contribs[cid]
                    s = round_now - c.round_idx
                    if (tier.max_staleness is not None
                            and s > tier.max_staleness):
                        discard_contrib(cid, arrived_through=0)
                        continue
                    # The edge owns the client's tracker shard: h lands
                    # when the contribution is forwarded upstream.
                    h_idx.append(c.client)
                    h_rows.append(c.h)
                    if c.hij is not None:
                        hij_rows.append(c.hij)
                    contribs[cid] = c._replace(h=None, hij=None)
                    add(c.round_idx, c.m.astype(np.float64), [cid])
                if h_idx:
                    store.scatter_set("h_i", h_idx, np.stack(h_rows))
                    if hij_rows:
                        store.scatter_add("h_ij", h_idx,
                                          np.stack(hij_rows))
            else:
                for mid in items:
                    msg = msgs.pop(mid)
                    for r, (vec, cids) in msg.groups.items():
                        s = round_now - r
                        if (tier.max_staleness is not None
                                and s > tier.max_staleness):
                            for cid in cids:
                                discard_contrib(cid, arrived_through=k)
                            continue
                        add(r, vec, cids)
            if not members:
                return
            for cid in members:
                hops[cid].append((k, round_now))
            bits = sum(GROUP_HEADER_BITS
                       + payload_bits(int(np.count_nonzero(vec)), d,
                                      fcfg.value_bits)
                       for vec, _ in groups.values())
            if tier.latency is not None:
                timing = tier.latency.job(j, flush_seq[(k, j)], bits)
                link_compute_s = timing.compute_s
                link_network_s = timing.network_s
            else:
                link_compute_s = link_network_s = 0.0
            delay = link_compute_s + link_network_s
            flush_seq[(k, j)] += 1
            if forced:
                forced_flushes += 1
            mid = next_id
            next_id += 1
            msgs[mid] = _Msg(src_tier=k, src_agg=j, groups=groups,
                             bits=bits, n_members=len(members))
            q.push(now + delay, TIER_ARRIVAL, mid, round_now,
                   flow_id=mid)
            # Span (not instant) so the flow arrows have a slice to bind
            # to; args carry the causal edge set (inputs -> mid) and the
            # link pricing the critical-path engine re-walks.
            with obs_trace.span("fleet.flush", track="fleet", tier=k,
                                agg=j, mid=mid,
                                inputs=[int(i) for i in items],
                                members=len(members), bits=bits,
                                forced=forced,
                                link_compute_s=link_compute_s,
                                link_network_s=link_network_s):
                for cid in members:
                    obs_trace.flow_step("fleet.contrib", cid,
                                        track="fleet")
            message_log.append(MessageRecord(
                tier=k, agg=j, round_idx=round_now, bits=bits,
                n_groups=len(groups), n_members=len(members),
                forced=forced))
            flush_sizes[k][len(members)] += 1

        def maybe_flush(k: int, j: int) -> None:
            Kk = fcfg.tiers[k].buffer_size
            buf = buffers[(k, j)]
            if Kk is not None:
                while len(buffers[(k, j)]) >= Kk:
                    flush(k, j, Kk, forced=False)
            elif pending[(k, j)] == 0 and buf:
                flush(k, j, len(buf), forced=False)

        def handle(ev) -> None:
            nonlocal now, dropped
            now = max(now, ev.time)
            obs_trace.set_virtual_time(now)
            if ev.kind == REJOIN:
                idle[ev.client] = True
            elif ev.kind == DROP:
                # Detected at the edge: the expected arrival time passed
                # with no data.  Exclude the contribution everywhere and
                # schedule the rejoin from the detection instant.
                dropped += 1
                for (k, j) in self._path(ev.client):
                    pending[(k, j)] -= 1
                    maybe_flush(k, j)
                pending[ROOT] -= 1
                timing = self.latency.job(ev.client, ev.round_idx,
                                          wl.wire_bits)
                q.push(now + timing.rejoin_s, REJOIN, ev.client,
                       ev.round_idx)
            elif ev.kind == ARRIVAL:
                cid = client_cid[ev.client]
                if T == 0:
                    root_buffer.append(cid)
                    pending[ROOT] -= 1
                    hop_bits[0] += wl.wire_bits
                else:
                    e = self._edge_of(ev.client)
                    pending[(0, e)] -= 1
                    hop_bits[0] += wl.wire_bits
                    buffers[(0, e)].append(cid)
                    maybe_flush(0, e)
            elif ev.kind == TIER_ARRIVAL:
                msg = msgs[ev.client]            # client slot = mid
                k = msg.src_tier
                if k + 1 >= T:
                    root_buffer.append(ev.client)
                    pending[ROOT] -= msg.n_members
                    hop_bits[T] += msg.bits
                else:
                    pj = int(self._parents[k][msg.src_agg])
                    buffers[(k + 1, pj)].append(ev.client)
                    pending[(k + 1, pj)] -= msg.n_members
                    hop_bits[k + 1] += msg.bits
                    maybe_flush(k + 1, pj)
            else:                                # pragma: no cover
                raise RuntimeError(f"unknown event kind {ev.kind!r}")

        def step_event() -> None:
            """Advance the simulation by one event, or — when the heap
            is dry but contributions sit in under-full buffers — by one
            forced flush (the timeout path that guarantees progress)."""
            if len(q):
                handle(q.pop())
                return
            for key_kj in sorted(buffers):
                if buffers[key_kj]:
                    flush(*key_kj, len(buffers[key_kj]), forced=True)
                    return
            raise RuntimeError("fleet stuck: live contributions but no "
                               "events and no buffered items")

        def alive() -> int:
            return pending[ROOT] + len(root_buffer)

        def collect_and_commit() -> Tuple[List[int], int]:
            """Fill the root buffer per policy, then commit.  The
            barrier root (K_root=None) waits until no live contribution
            is still below it; the buffered root commits the first
            K_root buffered units (top-tier messages, or client
            contributions when depth is 0)."""
            if K_root is None:
                while pending[ROOT] > 0:
                    step_event()
                return commit_traced(len(root_buffer))
            while len(root_buffer) < K_root and pending[ROOT] > 0:
                step_event()
            return commit_traced(min(K_root, len(root_buffer)))

        def commit_traced(ncommit: int) -> Tuple[List[int], int]:
            with obs_trace.span("fleet.commit", track="fleet",
                                round=round_now, units=ncommit,
                                unit_ids=[int(i) for i in
                                          root_buffer[:ncommit]]) as sp:
                stale, nmsgs = commit(ncommit)
                sp.set(committed=len(stale))
            obs_trace.counter("fleet.bits_cum", float(hop_bits.sum()),
                              track="fleet")
            return stale, nmsgs

        def commit(ncommit: int) -> Tuple[List[int], int]:
            nonlocal g
            batch, del_n = root_buffer[:ncommit], ncommit
            del root_buffer[:del_n]
            stale: List[int] = []
            gi_idx: List[int] = []
            gi_rows: List[np.ndarray] = []
            h_idx: List[int] = []
            h_rows: List[np.ndarray] = []
            hij_rows: List[np.ndarray] = []
            for item in batch:
                if T == 0:
                    c = contribs[item]
                    groups = {c.round_idx: (c.m.astype(np.float64),
                                            [item])}
                else:
                    groups = msgs.pop(item).groups
                for r in sorted(groups):
                    vec, cids = groups[r]
                    s = round_now - r
                    if (fcfg.max_staleness is not None
                            and s > fcfg.max_staleness):
                        for cid in list(cids):
                            # already at the root: nothing left pending
                            discard_contrib(cid, arrived_through=T)
                            pending[ROOT] += 1   # undo the double count
                        continue
                    w = policy.weight(s)
                    for _ in cids:
                        policy.observe(s)
                    g = g + (w / n) * vec
                    for cid in cids:
                        obs_trace.flow_end("fleet.contrib", cid,
                                           track="fleet")
                        c = contribs.pop(cid)
                        hop_list = hops.pop(cid, [])
                        idle[c.client] = True
                        client_cid.pop(c.client, None)
                        gi_idx.append(c.client)
                        gi_rows.append(w * c.m)
                        if T == 0:
                            h_idx.append(c.client)
                            h_rows.append(c.h)
                            if c.hij is not None:
                                hij_rows.append(c.hij)
                        total, _ = compose_hops(
                            c.round_idx, [hr for _, hr in hop_list],
                            round_now)
                        assert total == s
                        commit_log.append(CommitRecord(
                            client=c.client, dispatch_round=c.round_idx,
                            hops=tuple(hop_list),
                            commit_round=round_now, staleness=s,
                            weight=w))
                        hist[s] += 1
                        stale.append(s)
            if gi_idx:
                store.scatter_add("g_i", gi_idx,
                                  np.stack(gi_rows).astype(np.float32))
            if h_idx:
                store.scatter_set("h_i", h_idx, np.stack(h_rows))
                if hij_rows:
                    store.scatter_add("h_ij", h_idx, np.stack(hij_rows))
            return stale, len(batch)

        def record(stale, nmsgs, participants, skipped, skipped_off):
            loss, gnsq = wl.measure(x, g)
            rows.append(dict(
                time=now, loss=loss, gnsq=gnsq, committed=len(stale),
                committed_msgs=nmsgs, participants=participants,
                skipped=skipped, skipped_off=skipped_off,
                bits=float(hop_bits.sum()), root_bits=float(hop_bits[T]),
                s_mean=float(np.mean(stale)) if stale else 0.0,
                s_max=int(max(stale)) if stale else 0))

        for t in range(num_rounds):
            round_now = t
            key_t = jax.random.fold_in(run_key, t)
            k_part, _, _ = variants.round_keys(key_t)
            sampled = np.asarray(wl.sampler.sample(k_part))
            avail = (self.availability.mask(n, now)
                     if self.availability is not None
                     else np.ones(n, bool))
            eff = sampled & idle & avail
            skipped = int((sampled & ~idle).sum())
            skipped_off = int((sampled & idle & ~avail).sum())

            with obs_trace.span("fleet.dispatch", track="fleet",
                                round=t, cohort=int(eff.sum())):
                disp = wl.dispatch(key_t, t, x, g, store, eff)
                x = disp.x_new
                for row_i, client in enumerate(disp.idx):
                    client = int(client)
                    timing = self.latency.job(client, t, wl.wire_bits)
                    idle[client] = False
                    arrival_t = now + timing.compute_s + timing.network_s
                    for agg in self._path(client):
                        pending[agg] += 1
                    pending[ROOT] += 1
                    if timing.dropped:
                        q.push(arrival_t, DROP, client, t)
                    else:
                        cid = next_id
                        next_id += 1
                        contribs[cid] = _Contrib(
                            client=client, round_idx=t,
                            m=disp.m_rows[row_i],
                            h=disp.h_rows[row_i],
                            hij=(disp.hij_rows[row_i]
                                 if disp.hij_rows is not None else None))
                        hops[cid] = []
                        client_cid[client] = cid
                        q.push(arrival_t, ARRIVAL, client, t,
                               flow_id=cid)
                        obs_trace.flow_start(
                            "fleet.contrib", cid, track="fleet",
                            client=client, round=t,
                            compute_s=timing.compute_s,
                            network_s=timing.network_s,
                            bits=wl.wire_bits)

            stale: List[int] = []
            nmsgs = 0
            if alive() == 0 and len(q):
                # Nothing can reach the root (everyone is dropped or
                # awaiting rejoin) — advance by one event so the fleet
                # recovers instead of idling out the run.
                handle(q.pop())
            elif alive() == 0 and self.availability is not None:
                # Frozen-clock guard: whole fleet idle inside Poisson
                # outage windows; availability depends on `now`.
                now += 1.0
                obs_trace.set_virtual_time(now)
            elif alive() > 0:
                stale, nmsgs = collect_and_commit()
            record(stale, nmsgs, int(eff.sum()), skipped, skipped_off)

        # Drain: every live contribution lands (chunks of K_root); each
        # chunk is one more dispatch-free root step, so the round clock
        # keeps advancing and staleness/discard semantics match the
        # in-loop commits (same contract as fl/server.py).
        while alive() > 0:
            round_now += 1
            stale, nmsgs = collect_and_commit()
            record(stale, nmsgs, 0, 0, 0)

        col = lambda k, dt: np.asarray([r[k] for r in rows], dtype=dt)
        result = FleetRunResult(
            time=col("time", np.float64),
            loss=col("loss", np.float64),
            grad_norm_sq=col("gnsq", np.float64),
            committed=col("committed", np.int64),
            committed_msgs=col("committed_msgs", np.int64),
            participants=col("participants", np.int64),
            skipped_busy=col("skipped", np.int64),
            skipped_offline=col("skipped_off", np.int64),
            staleness_mean=col("s_mean", np.float64),
            staleness_max=col("s_max", np.int64),
            bits_cum=col("bits", np.float64),
            root_bits_cum=col("root_bits", np.float64),
            staleness_hist=dict(sorted(hist.items())),
            tier_bits=hop_bits.copy(),
            dropped=dropped, discarded_stale=discarded,
            forced_flushes=forced_flushes, total_time=now,
            event_log=q.log_tuples(), message_log=message_log,
            commit_log=commit_log,
            flush_sizes={k: dict(v) for k, v in flush_sizes.items()})
        obs_metrics.publish_fleet(result)
        if obs_trace.active():
            obs_monitors.run_fleet_monitors(result)
        # Drop the simulated clock so a later run on the same tracer
        # cannot emit virtual twins against this run's final time.
        obs_trace.clear_virtual_time()
        return FleetState(x=x, g=g, store=store), result
