"""Per-client latency models for the async federated runtime.

A model prices one dispatched job in *virtual seconds*:

    compute_s  — local gradient/compression work,
    network_s  — ``uplink_bits / bandwidth``, with the bits coming from
                 the engine's wire accounting (``Compressor.wire_bits``,
                 which delegates to :func:`repro.core.variants.
                 message_bits` for the sharded wire formats) — so the
                 communication savings the paper claims show up as
                 virtual wall-clock, not just counters,
    dropped    — the client accepted the job but never delivers
                 (network partition / preemption); it rejoins the idle
                 pool ``rejoin_s`` after its compute would have ended.

Determinism: every draw comes from ``np.random.default_rng((seed,
client, dispatch_idx))`` — keyed by *position*, not call order — so a
replay with the same seed prices every job identically regardless of
event interleaving (the replay contract of DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class JobTiming:
    compute_s: float
    network_s: float
    dropped: bool
    rejoin_s: float


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Base: constant compute, optional bandwidth and dropout — the
    zero-jitter sync limit when left at defaults."""

    compute_s: float = 1.0
    bandwidth_bps: Optional[float] = None   # None => network time 0
    dropout: float = 0.0                    # Prob(job never arrives)
    rejoin_s: float = 5.0                   # idle-again delay after a drop
    seed: int = 0

    def _rng(self, client: int, dispatch_idx: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, int(client), int(dispatch_idx)))

    # -- hooks subclasses override ------------------------------------
    def _compute(self, client: int, rng: np.random.Generator) -> float:
        return self.compute_s

    def _bandwidth(self, client: int) -> Optional[float]:
        return self.bandwidth_bps

    # -- the API the event loop consumes ------------------------------
    def job(self, client: int, dispatch_idx: int,
            uplink_bits: float) -> JobTiming:
        rng = self._rng(client, dispatch_idx)
        compute = float(self._compute(client, rng))
        bw = self._bandwidth(client)
        network = float(uplink_bits / bw) if bw else 0.0
        dropped = bool(self.dropout > 0.0
                       and rng.random() < self.dropout)
        return JobTiming(compute_s=compute, network_s=network,
                         dropped=dropped, rejoin_s=self.rejoin_s)


@dataclasses.dataclass(frozen=True)
class ConstantLatency(LatencyModel):
    """Alias for the base model: every client takes exactly
    ``compute_s`` — the sync-limit anchor of the parity tests."""


@dataclasses.dataclass(frozen=True)
class LognormalLatency(LatencyModel):
    """Heterogeneous fleet: client ``i``'s speed is a *fixed* lognormal
    multiplier (slow phones stay slow), and each dispatch adds lognormal
    jitter on top.  ``sigma`` controls per-dispatch jitter,
    ``client_sigma`` the persistent spread across the fleet,
    ``bandwidth_sigma`` the spread of per-client uplink bandwidth."""

    sigma: float = 0.5
    client_sigma: float = 0.5
    bandwidth_sigma: float = 0.0

    # Salts live far above any dispatch index, so per-client persistent
    # draws never collide with per-dispatch streams.
    _SALT_COMPUTE = 2 ** 62
    _SALT_BANDWIDTH = 2 ** 62 + 1

    def _client_scale(self, client: int, sigma: float,
                      salt: int) -> float:
        rng = np.random.default_rng((self.seed, int(client), salt))
        return float(np.exp(sigma * rng.standard_normal()))

    def _compute(self, client: int, rng: np.random.Generator) -> float:
        persistent = self._client_scale(client, self.client_sigma,
                                        salt=self._SALT_COMPUTE)
        jitter = float(np.exp(self.sigma * rng.standard_normal()
                              - 0.5 * self.sigma ** 2))
        return self.compute_s * persistent * jitter

    def _bandwidth(self, client: int) -> Optional[float]:
        if self.bandwidth_bps is None:
            return None
        if self.bandwidth_sigma == 0.0:
            return self.bandwidth_bps
        return self.bandwidth_bps / self._client_scale(
            client, self.bandwidth_sigma, salt=self._SALT_BANDWIDTH)


class PoissonAvailability:
    """Client-availability windows beyond the latency-model-implied
    arrival process (the ROADMAP-deferred extension): per client,
    *outages* arrive as a Poisson process of ``rate`` events per
    virtual second (exponential inter-arrival gaps measured from the
    end of the previous outage) and last ``Exp(off_mean)`` seconds.
    A client is available whenever it is not inside an outage window.

    Determinism: client ``i``'s window sequence is a pure function of
    ``(seed, i)`` — windows are generated by one positional-keyed rng
    per client, extended lazily and monotonically, so replays see
    identical availability regardless of when/at what times the
    scheduler queries (:mod:`repro.fl` replay contract).

    ``rate=0`` means always available (the identity the sync-limit
    parity tests rely on)."""

    def __init__(self, rate: float = 0.0, off_mean: float = 5.0,
                 seed: int = 0):
        if rate < 0 or off_mean <= 0:
            raise ValueError("need rate >= 0 and off_mean > 0")
        self.rate = float(rate)
        self.off_mean = float(off_mean)
        self.seed = int(seed)
        self._rngs: dict = {}
        self._windows: dict = {}   # client -> list[(start, end)], sorted

    _SALT = 2 ** 62 + 2   # clear of the LognormalLatency salts

    def _extend(self, client: int, t: float) -> list:
        wins = self._windows.setdefault(client, [])
        if self.rate == 0.0:
            return wins
        rng = self._rngs.get(client)
        if rng is None:
            rng = self._rngs[client] = np.random.default_rng(
                (self.seed, int(client), self._SALT))
        horizon = wins[-1][1] if wins else 0.0
        while horizon <= t:
            gap = rng.exponential(1.0 / self.rate)
            dur = rng.exponential(self.off_mean)
            wins.append((horizon + gap, horizon + gap + dur))
            horizon = wins[-1][1]
        return wins

    def available(self, client: int, t: float) -> bool:
        for start, end in self._extend(client, float(t)):
            if start <= t < end:
                return False
            if start > t:
                break
        return True

    def mask(self, n: int, t: float) -> np.ndarray:
        """(n,) bool availability mask at virtual time ``t``."""
        return np.asarray([self.available(i, t) for i in range(n)])


def make_latency(name: str, **kwargs) -> LatencyModel:
    if name == "constant":
        return ConstantLatency(**kwargs)
    if name == "lognormal":
        return LognormalLatency(**kwargs)
    raise ValueError(f"unknown latency model {name!r}; "
                     "choose from ['constant', 'lognormal']")
