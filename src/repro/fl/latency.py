"""Per-client latency models for the async federated runtime.

A model prices one dispatched job in *virtual seconds*:

    compute_s  — local gradient/compression work,
    network_s  — ``uplink_bits / bandwidth``, with the bits coming from
                 the engine's wire accounting (``Compressor.wire_bits``,
                 which delegates to :func:`repro.core.variants.
                 message_bits` for the sharded wire formats) — so the
                 communication savings the paper claims show up as
                 virtual wall-clock, not just counters,
    dropped    — the client accepted the job but never delivers
                 (network partition / preemption); it rejoins the idle
                 pool ``rejoin_s`` after its compute would have ended.

Determinism: every draw comes from ``np.random.default_rng((seed,
client, dispatch_idx))`` — keyed by *position*, not call order — so a
replay with the same seed prices every job identically regardless of
event interleaving (the replay contract of DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class JobTiming:
    compute_s: float
    network_s: float
    dropped: bool
    rejoin_s: float


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Base: constant compute, optional bandwidth and dropout — the
    zero-jitter sync limit when left at defaults."""

    compute_s: float = 1.0
    bandwidth_bps: Optional[float] = None   # None => network time 0
    dropout: float = 0.0                    # Prob(job never arrives)
    rejoin_s: float = 5.0                   # idle-again delay after a drop
    seed: int = 0

    def _rng(self, client: int, dispatch_idx: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, int(client), int(dispatch_idx)))

    # -- hooks subclasses override ------------------------------------
    def _compute(self, client: int, rng: np.random.Generator) -> float:
        return self.compute_s

    def _bandwidth(self, client: int) -> Optional[float]:
        return self.bandwidth_bps

    # -- the API the event loop consumes ------------------------------
    def job(self, client: int, dispatch_idx: int,
            uplink_bits: float) -> JobTiming:
        rng = self._rng(client, dispatch_idx)
        compute = float(self._compute(client, rng))
        bw = self._bandwidth(client)
        network = float(uplink_bits / bw) if bw else 0.0
        dropped = bool(self.dropout > 0.0
                       and rng.random() < self.dropout)
        return JobTiming(compute_s=compute, network_s=network,
                         dropped=dropped, rejoin_s=self.rejoin_s)


@dataclasses.dataclass(frozen=True)
class ConstantLatency(LatencyModel):
    """Alias for the base model: every client takes exactly
    ``compute_s`` — the sync-limit anchor of the parity tests."""


@dataclasses.dataclass(frozen=True)
class LognormalLatency(LatencyModel):
    """Heterogeneous fleet: client ``i``'s speed is a *fixed* lognormal
    multiplier (slow phones stay slow), and each dispatch adds lognormal
    jitter on top.  ``sigma`` controls per-dispatch jitter,
    ``client_sigma`` the persistent spread across the fleet,
    ``bandwidth_sigma`` the spread of per-client uplink bandwidth."""

    sigma: float = 0.5
    client_sigma: float = 0.5
    bandwidth_sigma: float = 0.0

    # Salts live far above any dispatch index, so per-client persistent
    # draws never collide with per-dispatch streams.
    _SALT_COMPUTE = 2 ** 62
    _SALT_BANDWIDTH = 2 ** 62 + 1

    def _client_scale(self, client: int, sigma: float,
                      salt: int) -> float:
        rng = np.random.default_rng((self.seed, int(client), salt))
        return float(np.exp(sigma * rng.standard_normal()))

    def _compute(self, client: int, rng: np.random.Generator) -> float:
        persistent = self._client_scale(client, self.client_sigma,
                                        salt=self._SALT_COMPUTE)
        jitter = float(np.exp(self.sigma * rng.standard_normal()
                              - 0.5 * self.sigma ** 2))
        return self.compute_s * persistent * jitter

    def _bandwidth(self, client: int) -> Optional[float]:
        if self.bandwidth_bps is None:
            return None
        if self.bandwidth_sigma == 0.0:
            return self.bandwidth_bps
        return self.bandwidth_bps / self._client_scale(
            client, self.bandwidth_sigma, salt=self._SALT_BANDWIDTH)


def make_latency(name: str, **kwargs) -> LatencyModel:
    if name == "constant":
        return ConstantLatency(**kwargs)
    if name == "lognormal":
        return LognormalLatency(**kwargs)
    raise ValueError(f"unknown latency model {name!r}; "
                     "choose from ['constant', 'lognormal']")
