"""Gang-scheduled asynchronous cohorts for the sharded LM trainer
(DESIGN.md §10).

The event-driven runtime of :mod:`repro.fl.server` drives the
*reference-scale* engine: every client is an independent job, which is
incompatible with an SPMD trainer where all nodes advance in lockstep
inside one ``shard_map``.  This module reconciles the two: the
**cohort** — one lockstep SPMD dispatch over the mesh — is the atomic
unit of asynchrony.  Within a cohort everything is synchronous (one
XLA program); across cohorts the server is free, exactly like the
per-client runtime:

* each server round *gang-schedules* one cohort: the scheduler draws
  the participation mask host-side (``ShardedDasha.participation_mask``
  — the same ``k_part`` derivation the sync engine consumes), intersects
  it with its own idle/availability state, and runs
  :meth:`repro.training.trainer.Trainer.dispatch_step` — the model
  broadcast, the variant's gradient oracles, and Alg. 1 lines 7-11,
  WITHOUT touching the server estimators;
* the cohort's :class:`~repro.core.sharded.ShardedDispatch` is buffered
  under its virtual **arrival time**: lockstep compute finishes at the
  cohort-max compute latency, uplinks then overlap, so the cohort lands
  at ``max_i compute_i + max_i network_i`` (priced by the same
  :mod:`repro.fl.latency` models, with the wire bits from the engine's
  own accounting);
* ``buffer_cohorts`` is the cohort **flight capacity**: up to K
  dispatched cohorts ride concurrently; once the buffer is full the
  server commits the *first of the buffered cohorts to arrive* (one
  cohort is the atomic commit — there is no per-client first-K inside
  a gang), weighting each by the staleness policy
  (:mod:`repro.fl.staleness`) and discarding cohorts older than
  ``max_staleness`` whole.  ``None`` (or 1) = the barrier: every round
  waits for everything outstanding — time per round is the straggler
  cohort, the sync pricing;
* cohort members stay busy until their cohort commits, so concurrent
  cohorts never share a node — ``h_i`` row commits cannot conflict —
  and a :class:`~repro.fl.latency.PoissonAvailability` process can
  additionally gate who is dispatchable;
* **mid-flight dropout** (latency models with ``dropout > 0``): the
  gang's lockstep compute synchronizes over the full cohort, then a
  dropped member vanishes in the uplink — its ``g_i_inc`` row, its
  share of ``g_delta`` and its ``part`` flag are excised from the
  buffered dispatch (:meth:`CohortScheduler._exclude_impl`), so nothing
  of it leaks into ``g``/``g_i``/``h_i``, and it re-enters the idle
  pool through a REJOIN event after its rejoin delay, facing fresh
  round keys on its next dispatch.  The reliable-transport default
  (``dropout == 0``) never routes through the excision path and stays
  bit-identical to the sync-parity contract.

Sync-limit parity (the §9 contract, now at trainer scale;
tests/test_cohorts.py): zero latency jitter + the barrier buffer ⇒
every cohort commits in its own round with ``s = 0``, ``w = 1``, and
the trajectory reproduces the synchronous ``train()`` loop allclose —
both loops consume :func:`repro.training.loop.round_train_key` keys,
and the external mask equals the engine's internal draw.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sharding import place_batch
from repro.fl.events import ARRIVAL, REJOIN, EventQueue
from repro.fl.latency import LatencyModel, PoissonAvailability
from repro.fl.staleness import make_staleness
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.training.loop import round_train_key
from repro.training.trainer import TrainState, Trainer, _tree_norm


@dataclasses.dataclass(frozen=True)
class CohortConfig:
    """Scheduler policy.  ``buffer_cohorts`` = cohort flight capacity
    (K cohorts ride concurrently; ``None``/1 = barrier).  ``seed``
    feeds the same :func:`~repro.training.loop.round_train_key` stream
    the sync loop uses, which is what anchors trainer-scale parity."""
    buffer_cohorts: Optional[int] = None   # in-flight cohorts; None=barrier
    staleness_policy: str = "power"        # fl/staleness.py registry
    staleness_exponent: float = 0.5
    max_staleness: Optional[int] = None    # discard whole cohorts older
    seed: int = 0

    def __post_init__(self):
        if self.buffer_cohorts is not None and self.buffer_cohorts < 1:
            raise ValueError("buffer_cohorts must be >= 1 (or None)")
        make_staleness(self.staleness_policy)


@dataclasses.dataclass
class CohortRunResult:
    """Per-server-step trajectories + end-of-run trace aggregates."""
    time: np.ndarray             # virtual wall-clock after each step
    loss: np.ndarray             # mean node loss at the dispatched x^{t+1}
    grad_norm: np.ndarray        # ||g|| after the step's commits
    committed: np.ndarray        # cohorts applied per step
    committed_clients: np.ndarray
    participants: np.ndarray     # dispatched cohort size per round
    skipped_busy: np.ndarray     # sampled-but-busy nodes per round
    skipped_offline: np.ndarray  # sampled-but-unavailable nodes per round
    staleness_mean: np.ndarray
    staleness_max: np.ndarray
    bits_cum: np.ndarray         # cumulative uplink bits on the wire
    staleness_hist: Dict[int, int]
    discarded_stale: int         # cohorts beyond max_staleness
    total_time: float
    event_log: List[Tuple[float, int, str, int, int]]
    dropped_members: int = 0     # cohort members lost mid-flight


class CohortScheduler:
    """Drives a :class:`~repro.training.trainer.Trainer` through the
    virtual-time event stack.  ``run(state, batches, num_rounds)``
    plays the whole schedule and returns ``(state, CohortRunResult)``."""

    def __init__(self, trainer: Trainer, latency: LatencyModel,
                 config: Optional[CohortConfig] = None,
                 availability: Optional[PoissonAvailability] = None):
        self.trainer = trainer
        self.engine = trainer.engine
        self.latency = latency
        self.cfg = config or CohortConfig()
        self.availability = availability
        self.n = self.engine.n_nodes
        self._gnorm = jax.jit(_tree_norm)
        self._exclude = jax.jit(self._exclude_impl)

    # -- mid-flight dropout: excise members from a dispatched cohort ----
    def _exclude_impl(self, disp, keep):
        """A copy of ``disp`` with the ``keep==0`` members excised:
        their ``g_i_inc`` rows zeroed, their share subtracted from the
        cohort's ``g_delta`` (which is ``sum_i g_i_inc[i] / n``), and
        ``part`` masked so the commit's tracker-set skips their rows.
        Only called when a cohort actually has drops — the reliable-
        transport default never routes through here, keeping the sync-
        parity path bit-identical."""
        n = self.n

        def rows(x):
            return keep.reshape((-1,) + (1,) * (x.ndim - 1)) * x

        def fix_delta(gd, gi):
            drop = (disp.part * (1.0 - keep)).reshape(
                (-1,) + (1,) * (gi.ndim - 1))
            return gd - jnp.sum(gi * drop, axis=0) / n

        return disp._replace(
            g_i_inc=jax.tree.map(rows, disp.g_i_inc),
            g_delta=jax.tree.map(fix_delta, disp.g_delta, disp.g_i_inc),
            part=disp.part * keep)

    def run(self, state: TrainState, batches: Iterator[dict],
            num_rounds: int) -> Tuple[TrainState, CohortRunResult]:
        cfg, n = self.cfg, self.n
        K = cfg.buffer_cohorts
        mesh = self.trainer.mesh
        data_axes = self.trainer.cfg.dasha.data_axes
        policy = make_staleness(cfg.staleness_policy,
                                exponent=cfg.staleness_exponent)
        # per-node uplink bits from the engine's own wire accounting —
        # the same number the sync loop's bits_sent metric uses
        wire_per_node = self.engine._per_node_message_bits(state.dasha.h_i)

        batch = next(batches)
        dispatch_fn = self.trainer.jit_dispatch_step(batch)
        commit_fn = self.trainer.jit_commit_step()
        # key/participation streams continue from the restored state
        # (same resume contract as the sync loop)
        start = int(jax.device_get(state.step))
        dstep0 = int(jax.device_get(state.dasha.step))

        q = EventQueue()
        now = 0.0
        obs_trace.set_virtual_time(now)
        idle = np.ones(n, bool)
        jobs: Dict[int, Tuple[int, Any, np.ndarray]] = {}
        outstanding = 0
        bits_total = 0.0
        discarded = 0
        dropped_members = 0
        hist: Counter = Counter()
        rows: List[Dict[str, Any]] = []

        def collect(target: int):
            nonlocal now, outstanding
            got = []
            while len(got) < target:
                ev = q.pop()
                now = max(now, ev.time)
                obs_trace.set_virtual_time(now)
                if ev.kind == REJOIN:
                    idle[ev.client] = True
                    continue
                outstanding -= 1
                got.append(ev)
            return got

        def commit(arrivals, round_now: int):
            nonlocal state, bits_total, discarded
            stale, clients = [], 0
            for ev in arrivals:
                r, disp, members = jobs.pop(ev.client)
                obs_trace.flow_end("train.cohort", ev.client,
                                   track="train")
                idle[members] = True
                bits_total += len(members) * wire_per_node
                s = round_now - r
                if (cfg.max_staleness is not None
                        and s > cfg.max_staleness):
                    discarded += 1
                    continue
                w = policy.weight(s)
                policy.observe(s)
                state = commit_fn(state, disp, jnp.float32(w))
                hist[s] += 1
                stale.append(s)
                clients += len(members)
            return stale, clients

        for t in range(num_rounds):
            # -- gang-schedule one cohort as a single SPMD dispatch ----
            key = round_train_key(cfg.seed, start + t)
            sampled = np.asarray(self.engine.participation_mask(
                key, dstep0 + t))
            avail = (self.availability.mask(n, now)
                     if self.availability is not None
                     else np.ones(n, bool))
            eff = sampled & idle & avail
            skipped_busy = int((sampled & ~idle).sum())
            skipped_off = int((sampled & idle & ~avail).sum())

            placed = place_batch(batch, mesh, data_axes)
            with obs_trace.span("train.dispatch", track="train",
                                round=t, cohort=int(eff.sum())):
                # repro: ignore[prng-reuse] -- deliberate: both
                # participation_mask (above) and dispatch_fn re-derive
                # domain-separated streams from this round key via
                # variants.round_keys; the mask preview must see the
                # same k_part the dispatch draws internally
                state, disp, mets = dispatch_fn(state, placed, key,
                                                jnp.asarray(eff))
                members = np.nonzero(eff)[0]
                kept = members
                if len(members):
                    timings = [self.latency.job(int(i), t, wire_per_node)
                               for i in members]
                    idle[members] = False
                    # Mid-flight dropout: the gang's lockstep compute
                    # synchronizes over the FULL cohort, then dropped
                    # members vanish in the uplink — their increments are
                    # excised from the dispatch, they rejoin the idle pool
                    # after their compute + rejoin delay, and only the
                    # surviving uplinks race to the arrival time.
                    drop_flags = np.asarray([tm.dropped for tm in timings])
                    kept = members[~drop_flags]
                    compute_max = max(tm.compute_s for tm in timings)
                    for i, tm in zip(members, timings):
                        if tm.dropped:
                            dropped_members += 1
                            q.push(now + tm.compute_s + tm.rejoin_s,
                                   REJOIN, client=int(i), round_idx=t)
                    if len(kept):
                        if drop_flags.any():
                            keep = np.zeros(n, np.float32)
                            keep[kept] = 1.0
                            disp = self._exclude(disp, jnp.asarray(keep))
                        net_max = max(tm.network_s
                                      for tm, dr in zip(timings,
                                                        drop_flags)
                                      if not dr)
                        jobs[t] = (t, disp, kept)
                        q.push(now + compute_max + net_max, ARRIVAL,
                               client=t, round_idx=t, flow_id=t)
                        outstanding += 1
                        # One flow per cohort: the gang is the unit of
                        # causality here (flow id = dispatch round).
                        obs_trace.flow_start(
                            "train.cohort", t, track="train",
                            round=t, members=len(kept),
                            compute_s=compute_max, network_s=net_max,
                            bits=len(kept) * wire_per_node)
            if not len(kept) and outstanding == 0:
                if len(q):
                    # only rejoins can be on the heap: advance to the
                    # next one so the fleet recovers
                    ev = q.pop()
                    now = max(now, ev.time)
                    obs_trace.set_virtual_time(now)
                    idle[ev.client] = True
                else:
                    # empty cohort and nothing in flight (e.g. the whole
                    # fleet inside Poisson outage windows): advance the
                    # clock one virtual second so availability can
                    # recover instead of spinning the remaining rounds
                    # at t=now
                    now += 1.0
                    obs_trace.set_virtual_time(now)

            # -- commit: drain the flight buffer down to K-1 cohorts so
            # there is room to gang-schedule the next round; the pops
            # are the earliest arrivals among everything buffered ------
            target = (outstanding if K is None
                      else max(0, outstanding - (K - 1)))
            if target == 0 and not len(kept) and outstanding > 0:
                # nothing was dispatchable (every node rides an
                # in-flight cohort or sits in an outage window) and the
                # buffer is not full: without a commit the clock never
                # advances and the fleet can never free up — wait for
                # the earliest in-flight cohort instead of spinning
                # degenerate empty rounds at a frozen virtual time
                target = 1
            stale: List[int] = []
            clients = 0
            if target > 0:
                arrivals = collect(target)
                with obs_trace.span("train.commit", track="train",
                                    round=t, cohorts=target,
                                    unit_ids=[int(ev.flow_id)
                                              for ev in arrivals
                                              if ev.flow_id >= 0]) as sp:
                    stale, clients = commit(arrivals, t)
                    sp.set(clients=clients)
            rows.append(dict(
                time=now, loss=float(mets.loss),
                gnorm=float(self._gnorm(state.dasha.g)),
                committed=len(stale), clients=clients,
                participants=int(eff.sum()), skipped=skipped_busy,
                skipped_off=skipped_off, bits=bits_total,
                s_mean=float(np.mean(stale)) if stale else 0.0,
                s_max=int(max(stale)) if stale else 0))
            if t < num_rounds - 1:
                batch = next(batches)

        # Drain: every in-flight cohort lands; each chunk is one more
        # dispatch-free server step, so the effective round index keeps
        # advancing (the §9 drain-staleness semantics).  One cohort
        # commits per drain step (the in-loop commit rate once no new
        # dispatches refill the buffer); the barrier drains in one.
        t_eff = num_rounds
        while outstanding:
            chunk = outstanding if K is None else 1
            arrivals = collect(chunk)
            with obs_trace.span("train.commit", track="train",
                                round=t_eff, cohorts=chunk,
                                unit_ids=[int(ev.flow_id)
                                          for ev in arrivals
                                          if ev.flow_id >= 0]) as sp:
                stale, clients = commit(arrivals, t_eff)
                sp.set(clients=clients)
            t_eff += 1
            rows.append(dict(
                time=now, loss=rows[-1]["loss"] if rows else 0.0,
                gnorm=float(self._gnorm(state.dasha.g)),
                committed=len(stale), clients=clients,
                participants=0, skipped=0, skipped_off=0,
                bits=bits_total,
                s_mean=float(np.mean(stale)) if stale else 0.0,
                s_max=int(max(stale)) if stale else 0))

        col = lambda k, dt: np.asarray([r[k] for r in rows], dtype=dt)
        result = CohortRunResult(
            time=col("time", np.float64),
            loss=col("loss", np.float64),
            grad_norm=col("gnorm", np.float64),
            committed=col("committed", np.int64),
            committed_clients=col("clients", np.int64),
            participants=col("participants", np.int64),
            skipped_busy=col("skipped", np.int64),
            skipped_offline=col("skipped_off", np.int64),
            staleness_mean=col("s_mean", np.float64),
            staleness_max=col("s_max", np.int64),
            bits_cum=col("bits", np.float64),
            staleness_hist=dict(sorted(hist.items())),
            discarded_stale=discarded,
            total_time=now, event_log=q.log_tuples(),
            dropped_members=dropped_members)
        reg = obs_metrics.get_registry()
        reg.gauge("train.bits_sent").set(float(bits_total))
        reg.gauge("train.committed").set(float(result.committed.sum()))
        reg.gauge("train.virtual_time").set(float(now))
        obs_trace.clear_virtual_time()
        return state, result


def train_async(trainer: Trainer, state: TrainState,
                batches: Iterator[dict], num_rounds: int,
                latency: LatencyModel,
                config: Optional[CohortConfig] = None,
                availability: Optional[PoissonAvailability] = None,
                logger=None, log_every: int = 10
                ) -> Tuple[TrainState, CohortRunResult]:
    """The async counterpart of :func:`repro.training.loop.train`: run
    the gang-scheduled cohort schedule and log per-step metrics."""
    sched = CohortScheduler(trainer, latency, config=config,
                            availability=availability)
    state, res = sched.run(state, batches, num_rounds)
    if logger is not None:
        for i in range(len(res.time)):
            if i % log_every == 0 or i == len(res.time) - 1:
                logger.log(i, t_virtual=res.time[i], loss=res.loss[i],
                           grad_norm=res.grad_norm[i],
                           committed=int(res.committed[i]),
                           staleness_mean=res.staleness_mean[i],
                           mbits=res.bits_cum[i] / 1e6)
    return state, res
