"""Staleness-weight policies for the async runtimes (DESIGN.md §9-§10).

A commit of a contribution dispatched at round ``r`` and applied at
round ``t`` carries staleness ``s = t - r``; a policy maps ``s`` to the
weight ``w(s) ∈ (0, 1]`` applied to the compressed increment (both
``g_i`` and ``g``, preserving the estimator invariant — the weighting
semantics live in the commit, not here).  One policy instance is
created per run and is *stateful*: :meth:`observe` feeds it the
realized staleness of every commit, which is what makes the
delay-adaptive variant possible while keeping replays deterministic
(the weight sequence is a pure function of the commit sequence).

Policies (:func:`make_staleness`):

* ``power``    — the fixed FedBuff-style power law
  ``w(s) = (1 + s)^-rho``; ignores observations.
* ``adaptive`` — delay-adaptive: ``w(s) = ((1 + s) / (1 + s̄))^-rho``
  clipped to ≤ 1, where ``s̄`` is the running mean of *observed* commit
  staleness.  A commit is discounted for being unusually stale
  relative to the fleet the server actually sees, not against an
  absolute scale — on a uniformly slow fleet the fixed power law
  over-discounts every commit, while the adaptive weight recenters at
  w(s̄) = 1.  In the zero-jitter sync limit every ``s`` is 0, so
  ``w ≡ 1`` and the sync-limit parity contract is untouched.

Shared by :class:`repro.fl.server.AsyncDashaServer` (per-client jobs)
and :class:`repro.fl.cohorts.CohortScheduler` (per-cohort commits).
"""
from __future__ import annotations

import dataclasses


class StalenessPolicy:
    """Maps observed staleness to commit weights; stateful per run."""

    def weight(self, s: int) -> float:
        raise NotImplementedError

    def observe(self, s: int) -> None:
        """Record the staleness of a commit that was just applied.
        Called AFTER :meth:`weight` for the same commit, so a commit's
        own staleness never influences its own weight."""

    @property
    def mean_observed(self) -> float:
        return 0.0


@dataclasses.dataclass
class PowerLawStaleness(StalenessPolicy):
    """``w(s) = (1 + s)^-exponent`` (FedBuff uses exponent 1/2)."""

    exponent: float = 0.5

    def weight(self, s: int) -> float:
        return float((1.0 + s) ** -self.exponent)


@dataclasses.dataclass
class AdaptiveStaleness(StalenessPolicy):
    """Delay-adaptive weights from observed per-commit staleness:
    ``w(s) = min(1, ((1 + s) / (1 + s̄))^-exponent)`` with ``s̄`` the
    running mean of everything :meth:`observe` has seen this run."""

    exponent: float = 0.5
    _count: int = dataclasses.field(default=0, repr=False)
    _total: float = dataclasses.field(default=0.0, repr=False)

    def weight(self, s: int) -> float:
        if s <= 0:
            return 1.0
        w = ((1.0 + s) / (1.0 + self.mean_observed)) ** -self.exponent
        return float(min(1.0, w))

    def observe(self, s: int) -> None:
        self._count += 1
        self._total += float(s)

    @property
    def mean_observed(self) -> float:
        return self._total / self._count if self._count else 0.0


def compose_hops(dispatch_round: int, hop_rounds, commit_round: int):
    """Decompose end-to-end staleness into per-hop increments.

    A contribution dispatched at round ``r`` traverses the tree and is
    stamped with the (root-clock) round at which each tier flushes it;
    ``hop_rounds`` is that ascending stamp sequence and ``commit_round``
    the root commit.  Returns ``(total, increments)`` where
    ``increments[k]`` is the staleness picked up on hop ``k`` and the
    telescoping identity ``sum(increments) == commit_round -
    dispatch_round == total`` holds by construction — the root weights a
    contribution by ``w(total)``, so composing staleness across hops is
    exactly the flat-server semantics (tests/test_tree_invariants.py
    property b).
    """
    points = [int(dispatch_round), *[int(h) for h in hop_rounds],
              int(commit_round)]
    if any(b < a for a, b in zip(points, points[1:])):
        raise ValueError(f"hop stamps must be non-decreasing: {points}")
    increments = tuple(b - a for a, b in zip(points, points[1:]))
    return int(commit_round) - int(dispatch_round), increments


STALENESS_POLICIES = ("power", "adaptive")


def make_staleness(name: str, *, exponent: float = 0.5) -> StalenessPolicy:
    """A FRESH policy instance (stateful — never share across runs)."""
    if name == "power":
        return PowerLawStaleness(exponent=exponent)
    if name == "adaptive":
        return AdaptiveStaleness(exponent=exponent)
    raise ValueError(f"unknown staleness policy {name!r}; choose from "
                     f"{list(STALENESS_POLICIES)}")
