"""AsyncDashaServer: buffered, staleness-aware DASHA-PP over virtual
time (DESIGN.md §9).

The sync engines wait for every sampled node each round; this server
does not.  One *dispatch* is exactly :meth:`repro.core.dasha_pp.DashaPP.
dispatch` — Alg. 1 lines 4-11 through the shared variant-rule layer and
fused kernels — but the per-node results are delivered by the event
queue at their latency-priced virtual arrival times, and the server
commits a buffer of the **first K arrivals** per step (FedBuff-style;
``buffer_size=None`` waits for the full cohort = the barrier baseline).

Staleness: a contribution dispatched at round ``r`` and committed at
round ``t`` has staleness ``s = t - r``.  Its compressed increment is
applied with weight ``w(s)`` from the configured policy
(:mod:`repro.fl.staleness`: the fixed ``(1 + s)^-rho`` power law or
the delay-adaptive weight recentered on observed commit staleness) to
BOTH ``g_i`` and ``g`` (preserving the ``g = mean_i g_i`` estimator
invariant); the node trackers ``h_i`` (and ``h_ij``) are applied
unweighted — they are the *client's* local state, already computed.
Contributions older than ``max_staleness`` are discarded whole.
An optional :class:`~repro.fl.latency.PoissonAvailability` process
gates dispatch: sampled-but-offline clients skip the round
(``skipped_offline`` in the trace).

Sync-limit parity contract (tests/test_fl.py): zero latency jitter +
``buffer_size`` = cohort size ⇒ every dispatch commits in its own round
with ``s = 0`` and ``w = 1``, and the trajectory equals
:meth:`DashaPP.run` allclose for all four variants — the async runtime
is an anchored generalization, not a fork.

Participation is an *arrival process*: each round the existing
:class:`~repro.core.participation.ParticipationSampler` draws the
cohort with the canonical ``k_part`` key; sampled-but-busy clients
(still computing, or dropped and awaiting rejoin) skip the round,
which the trace records.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import variants
from repro.core.compressors import Compressor
from repro.core.dasha_pp import DashaPP, DashaPPConfig, DashaPPState
from repro.core.participation import ParticipationSampler
from repro.fl.events import ARRIVAL, REJOIN, EventQueue
from repro.fl.latency import LatencyModel, PoissonAvailability
from repro.fl.staleness import make_staleness
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Server-side async policy (the latency model is runtime, not
    config)."""
    buffer_size: Optional[int] = None   # K arrivals per step; None=barrier
    staleness_exponent: float = 0.5     # rho of the chosen policy
    # "power": w(s) = (1+s)^-rho (FedBuff); "adaptive": delay-adaptive
    # w from observed commit-staleness statistics (fl/staleness.py).
    staleness_policy: str = "power"
    max_staleness: Optional[int] = None  # discard contributions older
    use_pallas: bool = False            # buffered-commit kernel (ops.py)

    def __post_init__(self):
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1 (or None)")
        make_staleness(self.staleness_policy)   # raises on unknown names


class _Job(NamedTuple):
    round_idx: int
    m: np.ndarray          # (d,) compressed message
    h: np.ndarray          # (d,) tracker row after the client's update
    hij: Optional[np.ndarray]   # (m, d) component-tracker delta
    fid: int = -1          # trace flow id (dispatch -> commit arrow)


@dataclasses.dataclass
class AsyncRunResult:
    """Per-server-step trajectories + end-of-run trace aggregates."""
    time: np.ndarray            # virtual wall-clock after each commit
    loss: np.ndarray            # f(x) after each commit
    grad_norm_sq: np.ndarray    # ||∇f(x)||² after each commit
    committed: np.ndarray       # arrivals applied per step
    participants: np.ndarray    # dispatched cohort size per round
    skipped_busy: np.ndarray    # sampled-but-busy clients per round
    skipped_offline: np.ndarray  # sampled-but-unavailable (Poisson windows)
    staleness_mean: np.ndarray
    staleness_max: np.ndarray
    bits_cum: np.ndarray        # cumulative uplink bits on the wire
    staleness_hist: Dict[int, int]
    utilization: np.ndarray     # (n,) busy-fraction of virtual time
    dropped: int                # jobs lost to dropout
    discarded_stale: int        # arrivals beyond max_staleness
    total_time: float
    event_log: List[Tuple[float, int, str, int, int]]


class AsyncDashaServer:
    """Event-driven DASHA-PP.  ``run(key, x0, num_rounds)`` plays the
    whole schedule and returns ``(final_state, AsyncRunResult)``."""

    def __init__(self, problem, compressor: Compressor,
                 sampler: ParticipationSampler, config: DashaPPConfig,
                 async_config: AsyncConfig, latency: LatencyModel,
                 availability: Optional[PoissonAvailability] = None):
        self.engine = DashaPP(problem, compressor, sampler, config)
        self.problem = problem
        self.compressor = compressor
        self.sampler = sampler
        self.cfg = config
        self.acfg = async_config
        self.latency = latency
        self.availability = availability
        self.rule = variants.get_rule(config.variant)
        self._dispatch = jax.jit(self.engine.dispatch)
        self._commit = jax.jit(self._commit_impl)
        self._measure = jax.jit(
            lambda x: (problem.loss(x),
                       jnp.sum(problem.full_grad(x) ** 2)))

    # -- the buffered server step (fixed capacity n: pad with valid=0) --
    def _commit_impl(self, state: DashaPPState, idx: Array, valid: Array,
                     w: Array, m_rows: Array, h_rows: Array,
                     hij_rows: Optional[Array]) -> DashaPPState:
        n = self.problem.n
        wv = w * valid
        if self.acfg.use_pallas:
            from repro.kernels import ops
            g = ops.buffered_commit_op(state.g, m_rows, wv,
                                       n_nodes=n).astype(state.g.dtype)
        else:
            g = state.g + (wv @ m_rows) / n
        # Scatter-adds are duplicate-safe (padding rows carry weight 0);
        # the tracker "set" is expressed as a masked delta-add for the
        # same reason.
        g_i = state.g_i.at[idx].add(wv[:, None] * m_rows)
        h_i = state.h_i.at[idx].add(
            valid[:, None] * (h_rows - state.h_i[idx]))
        h_ij = state.h_ij
        if hij_rows is not None:
            h_ij = state.h_ij.at[idx].add(valid[:, None, None] * hij_rows)
        return state._replace(g=g, g_i=g_i, h_i=h_i, h_ij=h_ij)

    # -- the event loop -------------------------------------------------
    def run(self, key: Array, x0: Array, num_rounds: int,
            b_init: Optional[int] = None
            ) -> Tuple[DashaPPState, AsyncRunResult]:
        n, d = self.problem.n, self.problem.d
        K = self.acfg.buffer_size
        policy = make_staleness(self.acfg.staleness_policy,
                                exponent=self.acfg.staleness_exponent)
        has_hij = self.rule.component_trackers
        wire_bits = float(self.compressor.wire_bits(d))

        init_key, run_key = jax.random.split(key)
        state = self.engine.init(init_key, x0, b_init=b_init)

        q = EventQueue()
        now = 0.0
        obs_trace.set_virtual_time(now)
        idle = np.ones(n, bool)
        jobs: Dict[int, _Job] = {}
        next_fid = 0                  # trace flow ids (one per job)
        outstanding = 0               # undelivered ARRIVAL events
        # (client, start, duration) busy windows — clipped to the final
        # virtual clock at the end, so utilization stays in [0, 1] even
        # when a dropped job's window outlives the run
        busy: List[Tuple[int, float, float]] = []
        bits_total = 0.0
        dropped = discarded = 0
        hist: Counter = Counter()
        rows: List[Dict[str, Any]] = []

        def collect(target: int):
            """Pop events until ``target`` arrivals are in hand (rejoins
            processed inline); returns the arrival events."""
            nonlocal now, outstanding
            got = []
            while len(got) < target:
                ev = q.pop()
                now = max(now, ev.time)
                obs_trace.set_virtual_time(now)
                if ev.kind == REJOIN:
                    idle[ev.client] = True
                    continue
                outstanding -= 1
                got.append(ev)
            return got

        def commit(arrivals, round_now: int):
            nonlocal bits_total, discarded
            buf_idx = np.zeros(n, np.int32)
            buf_valid = np.zeros(n, np.float32)
            buf_w = np.zeros(n, np.float32)
            buf_m = np.zeros((n, d), np.float32)
            buf_h = np.zeros((n, d), np.float32)
            buf_hij = (np.zeros((n, self.problem.m, d), np.float32)
                       if has_hij else None)
            stale = []
            for slot, ev in enumerate(arrivals):
                job = jobs.pop(ev.client)
                if job.fid >= 0:
                    obs_trace.flow_end("async.contrib", job.fid,
                                       track="async")
                idle[ev.client] = True
                bits_total += wire_bits
                s = round_now - job.round_idx
                if (self.acfg.max_staleness is not None
                        and s > self.acfg.max_staleness):
                    discarded += 1
                    continue
                hist[s] += 1
                stale.append(s)
                buf_idx[slot] = ev.client
                buf_valid[slot] = 1.0
                # weight BEFORE observe: a commit's own staleness never
                # influences its own weight (fl/staleness.py contract)
                buf_w[slot] = policy.weight(s)
                policy.observe(s)
                buf_m[slot] = job.m
                buf_h[slot] = job.h
                if has_hij:
                    buf_hij[slot] = job.hij
            new_state = self._commit(
                state, jnp.asarray(buf_idx), jnp.asarray(buf_valid),
                jnp.asarray(buf_w), jnp.asarray(buf_m),
                jnp.asarray(buf_h),
                jnp.asarray(buf_hij) if has_hij else None)
            return new_state, stale

        for t in range(num_rounds):
            key_t = jax.random.fold_in(run_key, t)
            k_part, _, _ = variants.round_keys(key_t)
            sampled = np.asarray(self.sampler.sample(k_part))
            avail = (self.availability.mask(n, now)
                     if self.availability is not None
                     else np.ones(n, bool))
            eff = sampled & idle & avail
            skipped = int((sampled & ~idle).sum())
            skipped_off = int((sampled & idle & ~avail).sum())

            with obs_trace.span("fleet.dispatch", track="async",
                                round=t, cohort=int(eff.sum())):
                out = self._dispatch(key_t, state, jnp.asarray(eff))
                m_np = np.asarray(out.m_i, np.float32)
                h_np = np.asarray(out.h_new, np.float32)
                hij_np = (np.asarray(out.h_ij_delta, np.float32)
                          if has_hij else None)
                for i in np.nonzero(eff)[0]:
                    timing = self.latency.job(int(i), t, wire_bits)
                    idle[i] = False
                    if timing.dropped:
                        dropped += 1
                        busy.append((int(i), now, timing.compute_s))
                        q.push(now + timing.compute_s + timing.rejoin_s,
                               REJOIN, int(i), t)
                    else:
                        dur = timing.compute_s + timing.network_s
                        busy.append((int(i), now, dur))
                        fid = next_fid
                        next_fid += 1
                        jobs[int(i)] = _Job(t, m_np[i], h_np[i],
                                            hij_np[i] if has_hij else None,
                                            fid=fid)
                        q.push(now + dur, ARRIVAL, int(i), t,
                               flow_id=fid)
                        outstanding += 1
                        obs_trace.flow_start(
                            "async.contrib", fid, track="async",
                            client=int(i), round=t,
                            compute_s=timing.compute_s,
                            network_s=timing.network_s, bits=wire_bits)
            state = state._replace(x=out.x_new, step=state.step + 1)

            target = outstanding if K is None else min(K, outstanding)
            stale: List[int] = []
            if target == 0 and len(q):
                # Nothing in flight and nobody dispatchable (all
                # sampled clients await rejoin) — the heap can only
                # hold rejoins, so advance the clock by one event and
                # let the fleet recover instead of idling out the run.
                ev = q.pop()
                now = max(now, ev.time)
                obs_trace.set_virtual_time(now)
                idle[ev.client] = True
            elif target == 0 and self.availability is not None:
                # Frozen-clock guard (mirrors fl/cohorts.py): nothing
                # in flight, nothing on the heap, the whole fleet idle
                # but inside Poisson outage windows — availability is a
                # function of `now`, so the clock must advance for the
                # windows to ever end.
                now += 1.0
                obs_trace.set_virtual_time(now)
            elif target > 0:
                arrivals = collect(target)
                with obs_trace.span("fleet.commit", track="async",
                                    round=t, units=target,
                                    unit_ids=[int(ev.flow_id)
                                              for ev in arrivals
                                              if ev.flow_id >= 0]) as sp:
                    state, stale = commit(arrivals, t)
                    sp.set(committed=len(stale))
            loss, gnsq = self._measure(state.x)
            rows.append(dict(
                time=now, loss=float(loss), gnsq=float(gnsq),
                committed=len(stale), participants=int(eff.sum()),
                skipped=skipped, skipped_off=skipped_off,
                bits=bits_total,
                s_mean=float(np.mean(stale)) if stale else 0.0,
                s_max=int(max(stale)) if stale else 0))

        # Drain: every in-flight arrival eventually lands (chunks of K).
        # Each chunk is one more (dispatch-free) server step, so the
        # effective round index KEEPS ADVANCING — stamping everything
        # with the last in-loop round would understate the staleness of
        # jobs that land several virtual steps after the run, and let
        # them dodge the max_staleness discard the in-loop commits face.
        t_eff = num_rounds
        while outstanding:
            chunk = outstanding if K is None else min(K, outstanding)
            arrivals = collect(chunk)
            with obs_trace.span("fleet.commit", track="async",
                                round=t_eff, units=chunk,
                                unit_ids=[int(ev.flow_id)
                                          for ev in arrivals
                                          if ev.flow_id >= 0]) as sp:
                state, stale = commit(arrivals, t_eff)
                sp.set(committed=len(stale))
            t_eff += 1
            loss, gnsq = self._measure(state.x)
            rows.append(dict(
                time=now, loss=float(loss), gnsq=float(gnsq),
                committed=len(stale), participants=0, skipped=0,
                skipped_off=0, bits=bits_total,
                s_mean=float(np.mean(stale)) if stale else 0.0,
                s_max=int(max(stale)) if stale else 0))

        total = max(now, 1e-12)
        busy_s = np.zeros(n)
        for client, start, dur in busy:
            busy_s[client] += max(0.0, min(start + dur, total) - start)
        col = lambda k, dt: np.asarray([r[k] for r in rows], dtype=dt)
        result = AsyncRunResult(
            time=col("time", np.float64),
            loss=col("loss", np.float64),
            grad_norm_sq=col("gnsq", np.float64),
            committed=col("committed", np.int64),
            participants=col("participants", np.int64),
            skipped_busy=col("skipped", np.int64),
            skipped_offline=col("skipped_off", np.int64),
            staleness_mean=col("s_mean", np.float64),
            staleness_max=col("s_max", np.int64),
            bits_cum=col("bits", np.float64),
            staleness_hist=dict(sorted(hist.items())),
            utilization=busy_s / total,
            dropped=dropped, discarded_stale=discarded,
            total_time=now, event_log=q.log_tuples())
        reg = obs_metrics.get_registry()
        reg.gauge("fleet.async.bits_total").set(float(bits_total))
        reg.gauge("fleet.async.committed").set(
            float(result.committed.sum()))
        reg.gauge("fleet.async.dropped").set(float(dropped))
        reg.gauge("fleet.async.virtual_time").set(float(now))
        obs_trace.clear_virtual_time()
        return state, result
