"""Sharded, out-of-core client-state store for the hierarchical fleet
(DESIGN.md §12).

The flat async server keeps every per-client DASHA-PP tracker
(``g_i``, ``h_i``, and for finite-MVR the component table ``h_ij``) as
dense ``(n, d)`` jax arrays — fine for tens of clients, hopeless for
the ROADMAP's million-client fleet, where ``(n, d)`` float32 at
n = 1e6, d = 256 is already a GiB per field.  The fleet runtime only
ever touches the *cohort* rows of those tables each round, so the store
holds them out of core: one numpy array (``ram`` backend) or one
``.npy`` memmap (``memmap`` backend) **per edge chunk**, with clients
assigned to contiguous index ranges per edge aggregator.  Gathers and
scatters address global client ids and are routed to the owning chunk,
so a round with a 64-client cohort reads/writes 64 rows regardless of
``n``.

The chunking deliberately mirrors the aggregation tree's leaf tier:
an edge aggregator's clients live in one chunk, so per-edge batch
updates (the h-row writes at edge flush) touch exactly one file.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

BACKENDS = ("ram", "memmap")


def edge_partition(n: int, num_edges: int) -> np.ndarray:
    """Contiguous near-equal split of ``range(n)`` into ``num_edges``
    chunks: ascending bounds array of shape ``(num_edges + 1,)`` with
    ``bounds[0] == 0`` and ``bounds[-1] == n``.  Chunk sizes differ by
    at most one (the first ``n % num_edges`` edges get the extra
    client), matching :func:`numpy.array_split` order."""
    if num_edges < 1:
        raise ValueError(f"num_edges must be >= 1, got {num_edges}")
    if n < num_edges:
        raise ValueError(f"need n >= num_edges, got n={n} < {num_edges}")
    base, extra = divmod(n, num_edges)
    sizes = np.full(num_edges, base, dtype=np.int64)
    sizes[:extra] += 1
    bounds = np.zeros(num_edges + 1, dtype=np.int64)
    np.cumsum(sizes, out=bounds[1:])
    return bounds


class ClientStore:
    """Per-field, edge-chunked row store addressed by global client id.

    ``fields`` maps a field name to its trailing (per-client) shape,
    e.g. ``{"g_i": (d,), "h_i": (d,), "h_ij": (m, d)}``.  All fields
    share the client axis defined by ``bounds`` (see
    :func:`edge_partition`).  ``backend="ram"`` keeps plain numpy
    arrays; ``backend="memmap"`` keeps one ``.npy`` memmap per
    (field, edge) under ``directory`` (a private temporary directory by
    default, removed when the store is closed/garbage-collected).
    """

    def __init__(self, bounds: Sequence[int],
                 fields: Mapping[str, Tuple[int, ...]],
                 *, backend: str = "ram",
                 directory: Optional[str] = None,
                 dtype=np.float32):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {list(BACKENDS)}")
        self.bounds = np.asarray(bounds, dtype=np.int64)
        if self.bounds.ndim != 1 or len(self.bounds) < 2 \
                or self.bounds[0] != 0 \
                or np.any(np.diff(self.bounds) <= 0):
            raise ValueError(f"bounds must be ascending with bounds[0]=0 "
                             f"and non-empty chunks, got {bounds}")
        self.n = int(self.bounds[-1])
        self.num_edges = len(self.bounds) - 1
        self.backend = backend
        self.dtype = np.dtype(dtype)
        self._shapes: Dict[str, Tuple[int, ...]] = {
            name: tuple(int(s) for s in shape)
            for name, shape in fields.items()}
        self._tmpdir = None
        if backend == "memmap":
            if directory is None:
                self._tmpdir = tempfile.TemporaryDirectory(
                    prefix="fleet_store_")
                directory = self._tmpdir.name
            os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self._chunks: Dict[str, list] = {}
        for name, shape in self._shapes.items():
            chunks = []
            for e in range(self.num_edges):
                rows = int(self.bounds[e + 1] - self.bounds[e])
                full = (rows,) + shape
                if backend == "ram":
                    chunks.append(np.zeros(full, dtype=self.dtype))
                else:
                    path = os.path.join(directory, f"{name}_edge{e}.npy")
                    chunks.append(np.lib.format.open_memmap(
                        path, mode="w+", dtype=self.dtype, shape=full))
            self._chunks[name] = chunks

    # ------------------------------------------------------------------
    @property
    def fields(self) -> Tuple[str, ...]:
        return tuple(self._shapes)

    def field_shape(self, field: str) -> Tuple[int, ...]:
        return self._shapes[field]

    @property
    def nbytes(self) -> int:
        """Total stored bytes across all fields (on disk for the memmap
        backend — NOT resident memory)."""
        per_row = sum(int(np.prod((1,) + s)) for s in self._shapes.values())
        return self.n * per_row * self.dtype.itemsize

    def edge_of(self, idx) -> np.ndarray:
        """Owning edge index for each global client id."""
        idx = np.asarray(idx, dtype=np.int64)
        return np.searchsorted(self.bounds, idx, side="right") - 1

    def edge_slice(self, edge: int) -> slice:
        return slice(int(self.bounds[edge]), int(self.bounds[edge + 1]))

    # ------------------------------------------------------------------
    def _route(self, idx: np.ndarray) -> Iterable[Tuple[int, np.ndarray,
                                                        np.ndarray]]:
        """Yield ``(edge, positions_into_idx, local_rows)`` groups."""
        if len(idx) and (idx.min() < 0 or idx.max() >= self.n):
            raise IndexError(f"client ids out of range [0, {self.n})")
        edges = self.edge_of(idx)
        for e in np.unique(edges):
            pos = np.nonzero(edges == e)[0]
            yield int(e), pos, idx[pos] - int(self.bounds[e])

    def gather(self, field: str, idx) -> np.ndarray:
        """Rows ``field[idx]`` as a fresh ``(len(idx), *shape)`` array."""
        idx = np.asarray(idx, dtype=np.int64)
        out = np.empty((len(idx),) + self._shapes[field], dtype=self.dtype)
        chunks = self._chunks[field]
        for e, pos, local in self._route(idx):
            out[pos] = chunks[e][local]
        return out

    def scatter_set(self, field: str, idx, values) -> None:
        """``field[idx] = values`` (rows must be unique per call)."""
        idx = np.asarray(idx, dtype=np.int64)
        values = np.asarray(values, dtype=self.dtype)
        chunks = self._chunks[field]
        for e, pos, local in self._route(idx):
            chunks[e][local] = values[pos]

    def scatter_add(self, field: str, idx, values) -> None:
        """``field[idx] += values`` (rows must be unique per call)."""
        idx = np.asarray(idx, dtype=np.int64)
        values = np.asarray(values, dtype=self.dtype)
        chunks = self._chunks[field]
        for e, pos, local in self._route(idx):
            chunks[e][local] += values[pos]

    def edge_block(self, field: str, edge: int) -> np.ndarray:
        """The raw per-edge chunk (a view — mutating it mutates the
        store).  Handy for chunked initialization at scale."""
        return self._chunks[field][edge]

    def dense(self, field: str) -> np.ndarray:
        """Materialize the full ``(n, *shape)`` field.  Reference-scale
        parity checks only — defeats the point at fleet scale."""
        return np.concatenate([np.asarray(c)
                               for c in self._chunks[field]], axis=0)

    def flush(self) -> None:
        """Flush memmap chunks to disk (no-op for the ram backend)."""
        if self.backend == "memmap":
            for chunks in self._chunks.values():
                for c in chunks:
                    c.flush()

    def close(self) -> None:
        """Drop chunk references and delete the private temp directory
        (if the store created one)."""
        self._chunks = {}
        if self._tmpdir is not None:
            self._tmpdir.cleanup()
            self._tmpdir = None
