"""Deterministic virtual-time event scheduler for the async federated
runtime (DESIGN.md §9).

The simulator advances a *virtual* clock: the server dispatches work,
the latency models (:mod:`repro.fl.latency`) price each job in virtual
seconds, and completion/rejoin events land on a heap keyed by
``(time, seq)`` — ``seq`` is a monotonic counter, so simultaneous
events (the sync limit: zero jitter makes a whole cohort finish at the
same instant) pop in dispatch order and the schedule is a pure
function of the seed.  Every popped event is appended to ``log``;
replay determinism (same seed ⇒ identical log and final iterate) is
asserted by tests/test_fl.py.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

ARRIVAL = "arrival"    # a client's compressed message reaches the server
REJOIN = "rejoin"      # a dropped client becomes available again
# Hierarchical-fleet kinds (fl/tree.py, DESIGN.md §12):
TIER_ARRIVAL = "tier_arrival"   # an aggregator's merged message reaches
#                                 its parent tier (or the root)
DROP = "drop"          # a mid-flight dropout is *detected* at the edge
#                        (the would-be arrival time passes with no data)


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    """One scheduled occurrence.  Ordered by ``(time, seq)`` — the
    dataclass field order — so heap pops are deterministic even under
    ties."""
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    client: int = dataclasses.field(compare=False)
    round_idx: int = dataclasses.field(compare=False)
    # Trace-context propagation (DESIGN.md §15): the flow id of the
    # contribution/message this event carries, or -1 when the event is
    # not part of a causal chain (rejoins, drops, untraced runs).  Not
    # part of the replay-determinism ordering or the log_tuples record.
    flow_id: int = dataclasses.field(compare=False, default=-1)


class EventQueue:
    """Min-heap of :class:`Event` with a monotonic tie-break counter and
    a log of everything popped (the replay record)."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0
        self.log: List[Event] = []

    def push(self, time: float, kind: str, client: int,
             round_idx: int, flow_id: int = -1) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   client=client, round_idx=round_idx, flow_id=flow_id)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        ev = heapq.heappop(self._heap)
        self.log.append(ev)
        return ev

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def log_tuples(self) -> List[Tuple[float, int, str, int, int]]:
        """The popped-event log as plain tuples (stable across runs of
        the same seed; handy for equality asserts and JSON traces)."""
        return [(ev.time, ev.seq, ev.kind, ev.client, ev.round_idx)
                for ev in self.log]
