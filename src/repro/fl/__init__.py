"""repro.fl — event-driven asynchronous federated runtime (DESIGN.md §9).

Layout:
    events.py    deterministic virtual-time event queue (replayable log)
    latency.py   per-client latency models (constant, lognormal,
                 bandwidth-proportional network, dropout/rejoin)
    server.py    AsyncDashaServer: buffered first-K, staleness-aware
                 DASHA-PP over the shared variant-rule layer
"""
from repro.fl.events import ARRIVAL, REJOIN, Event, EventQueue
from repro.fl.latency import (ConstantLatency, JobTiming, LatencyModel,
                              LognormalLatency, make_latency)
from repro.fl.server import AsyncConfig, AsyncDashaServer, AsyncRunResult

__all__ = [
    "ARRIVAL", "REJOIN", "Event", "EventQueue",
    "ConstantLatency", "JobTiming", "LatencyModel", "LognormalLatency",
    "make_latency",
    "AsyncConfig", "AsyncDashaServer", "AsyncRunResult",
]
