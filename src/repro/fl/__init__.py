"""repro.fl — event-driven asynchronous federated runtime (DESIGN.md
§9-§10).

Layout:
    events.py    deterministic virtual-time event queue (replayable log)
    latency.py   per-client latency models (constant, lognormal,
                 bandwidth-proportional network, dropout/rejoin) +
                 Poisson client-availability windows
    staleness.py staleness-weight policies (fixed power law and
                 delay-adaptive), shared by both async runtimes
    server.py    AsyncDashaServer: buffered first-K, staleness-aware
                 DASHA-PP over the shared variant-rule layer
    cohorts.py   CohortScheduler: gang-scheduled async cohorts for the
                 sharded SPMD LM trainer (cohort = atomic unit of
                 asynchrony)
"""
from repro.fl.cohorts import (CohortConfig, CohortRunResult,
                              CohortScheduler, train_async)
from repro.fl.events import ARRIVAL, REJOIN, Event, EventQueue
from repro.fl.latency import (ConstantLatency, JobTiming, LatencyModel,
                              LognormalLatency, PoissonAvailability,
                              make_latency)
from repro.fl.server import AsyncConfig, AsyncDashaServer, AsyncRunResult
from repro.fl.staleness import (STALENESS_POLICIES, AdaptiveStaleness,
                                PowerLawStaleness, StalenessPolicy,
                                make_staleness)

__all__ = [
    "ARRIVAL", "REJOIN", "Event", "EventQueue",
    "ConstantLatency", "JobTiming", "LatencyModel", "LognormalLatency",
    "PoissonAvailability", "make_latency",
    "AsyncConfig", "AsyncDashaServer", "AsyncRunResult",
    "STALENESS_POLICIES", "AdaptiveStaleness", "PowerLawStaleness",
    "StalenessPolicy", "make_staleness",
    "CohortConfig", "CohortRunResult", "CohortScheduler", "train_async",
]
