"""repro.fl — event-driven asynchronous federated runtime (DESIGN.md
§9-§10, §12).

Layout:
    events.py       deterministic virtual-time event queue (replayable log)
    latency.py      per-client latency models (constant, lognormal,
                    bandwidth-proportional network, dropout/rejoin) +
                    Poisson client-availability windows
    staleness.py    staleness-weight policies (fixed power law and
                    delay-adaptive) + hop composition, shared by all
                    async runtimes
    server.py       AsyncDashaServer: buffered first-K, staleness-aware
                    DASHA-PP over the shared variant-rule layer
    cohorts.py      CohortScheduler: gang-scheduled async cohorts for the
                    sharded SPMD LM trainer (cohort = atomic unit of
                    asynchrony), with mid-flight dropout/rejoin
    client_store.py out-of-core per-client tracker store, chunked by
                    edge (numpy / memmap backends)
    tree.py         HierarchicalFleet: configurable aggregation tree of
                    edge aggregators pre-reducing DASHA-PP increments
                    with per-tier buffering + wire accounting
"""
from repro.fl.client_store import BACKENDS, ClientStore, edge_partition
from repro.fl.cohorts import (CohortConfig, CohortRunResult,
                              CohortScheduler, train_async)
from repro.fl.events import (ARRIVAL, DROP, REJOIN, TIER_ARRIVAL, Event,
                             EventQueue)
from repro.fl.latency import (ConstantLatency, JobTiming, LatencyModel,
                              LognormalLatency, PoissonAvailability,
                              make_latency)
from repro.fl.server import AsyncConfig, AsyncDashaServer, AsyncRunResult
from repro.fl.staleness import (STALENESS_POLICIES, AdaptiveStaleness,
                                PowerLawStaleness, StalenessPolicy,
                                compose_hops, make_staleness)
from repro.fl.tree import (CommitRecord, DenseProblemWorkload,
                           FleetConfig, FleetDispatch, FleetRunResult,
                           FleetState, FleetWorkload, HierarchicalFleet,
                           MessageRecord, StreamedGradientWorkload,
                           TierConfig, payload_bits)

__all__ = [
    "ARRIVAL", "DROP", "REJOIN", "TIER_ARRIVAL", "Event", "EventQueue",
    "ConstantLatency", "JobTiming", "LatencyModel", "LognormalLatency",
    "PoissonAvailability", "make_latency",
    "AsyncConfig", "AsyncDashaServer", "AsyncRunResult",
    "STALENESS_POLICIES", "AdaptiveStaleness", "PowerLawStaleness",
    "StalenessPolicy", "compose_hops", "make_staleness",
    "CohortConfig", "CohortRunResult", "CohortScheduler", "train_async",
    "BACKENDS", "ClientStore", "edge_partition",
    "CommitRecord", "DenseProblemWorkload", "FleetConfig",
    "FleetDispatch", "FleetRunResult", "FleetState", "FleetWorkload",
    "HierarchicalFleet", "MessageRecord", "StreamedGradientWorkload",
    "TierConfig", "payload_bits",
]
