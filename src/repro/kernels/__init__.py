"""Pallas TPU kernels for the DASHA-PP hot path (DESIGN.md §6).

Layout: one module per kernel family (``dasha_update``, ``randk``),
``ops`` for the jit'd public wrappers with interpret-mode auto-detect,
``ref`` for the pure-jnp oracles every kernel is tested against.
"""
from repro.kernels.ops import (block_gather_op, block_scatter_op,
                               dasha_h_update_op, dasha_page_update_op,
                               dasha_payload_blocks_op, dasha_tail_op,
                               dasha_update_batched_op, dasha_update_op,
                               interpret_default, paged_attention_op)

__all__ = [
    "block_gather_op", "block_scatter_op", "dasha_h_update_op",
    "dasha_page_update_op", "dasha_payload_blocks_op", "dasha_tail_op",
    "dasha_update_batched_op", "dasha_update_op", "interpret_default",
    "paged_attention_op",
]
