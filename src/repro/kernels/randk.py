"""BlockRandK compress as a Pallas TPU kernel: gather K random
(8x128-aligned) blocks out of a grad-sized vector, scaled for
unbiasedness.

Why a kernel (DESIGN.md §6): XLA lowers a gather of K blocks from an
(nb, bs) array on TPU as either a full-array dynamic-slice loop or a
one-hot matmul — both touch O(nb*bs) HBM.  With scalar-prefetch
(`PrefetchScalarGridSpec`), the block indices land in SMEM before the
body runs and the kernel's BlockSpec index_map *is* the gather: only the
K selected blocks are ever read from HBM — O(K*bs) traffic, the whole
point of RandK compression.

The companion scatter (server-side decompress/accumulate) has the same
structure with input/output roles swapped; implemented here as
``block_scatter_pallas`` with `input_output_aliasing` so the base buffer
is updated in place.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _gather_kernel(idx_ref, x_ref, out_ref, *, scale: float):
    # x_ref block is chosen by the index_map via idx_ref (scalar prefetch);
    # the body just scales and copies.
    out_ref[...] = x_ref[...] * scale


@functools.partial(jax.jit, static_argnames=("k_blocks", "scale",
                                             "interpret"))
def block_gather_pallas(x_blocks: Array, block_idx: Array, *, k_blocks: int,
                        scale: float, interpret: bool = True) -> Array:
    """x_blocks: (nb, bs) f32; block_idx: (k_blocks,) int32 ->
    (k_blocks, bs) = x_blocks[block_idx] * scale."""
    nb, bs = x_blocks.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k_blocks,),
        in_specs=[pl.BlockSpec((1, bs), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, bs), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_blocks, bs), x_blocks.dtype),
        interpret=interpret,
    )(block_idx, x_blocks)


def _scatter_kernel(idx_ref, vals_ref, base_ref, out_ref):
    # grid step i accumulates vals[i] into the block idx[i] of the base;
    # out aliases base so untouched blocks pass through.
    out_ref[...] = base_ref[...] + vals_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_scatter_pallas(base_blocks: Array, vals: Array, block_idx: Array,
                         *, interpret: bool = True) -> Array:
    """base (nb, bs) += vals (kb, bs) at rows block_idx.  Assumes the
    selected rows are distinct (RandK samples without replacement)."""
    nb, bs = base_blocks.shape
    kb = vals.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kb,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda i, idx: (i, 0)),       # vals
            pl.BlockSpec((1, bs), lambda i, idx: (idx[i], 0)),  # base row
        ],
        out_specs=pl.BlockSpec((1, bs), lambda i, idx: (idx[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, bs), base_blocks.dtype),
        interpret=interpret,
        input_output_aliases={2: 0},   # alias base (input 2) -> out 0
    )(block_idx, vals, base_blocks)
