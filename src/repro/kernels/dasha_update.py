"""Fused DASHA control-variate updates as Pallas TPU kernels.

Why kernels (DESIGN.md §6): the per-node update is a chain of five
elementwise passes over grad-sized vectors

    k       = gn - go - b (h - go)
    h_new   = h + part * k / pa
    payload = k / pa - (a/pa)(g_i - h)

with arithmetic intensity ~O(1) — pure HBM-bandwidth-bound.  Unfused,
XLA may materialize k and intermediate diffs; the fused kernel streams
the four inputs once and writes the three outputs once: 7 HBM transfers
of D instead of ~11+, a ~1.6x memory-roofline win on the optimizer phase
(validated against the HLO bytes in benchmarks/bench_kernels.py).

Kernel family (one per ``k_i`` rule of Algorithm 1, DESIGN.md §6):

* :func:`dasha_update_pallas`          — flat (D,) single-node form
  (Algs. 2/5; the sharded engine's per-leaf local vector).
* :func:`dasha_update_batched_pallas`  — node-major (n, D) form with a
  per-node participation mask; one launch updates every simulated node
  of the reference :class:`~repro.core.dasha_pp.DashaPP` engine.
* :func:`dasha_page_update_batched_pallas` — the Alg. 3 PAGE rule: both
  branches (full ``gn - go - (b/p_page)(h - go)`` and minibatch
  ``bn - bo``) fused with the shared Bernoulli coin select.
* :func:`dasha_tail_batched_pallas`    — lines 10-11 only, for variants
  whose ``k_i`` is produced elsewhere (Alg. 4 finite-MVR scatter).
* :func:`dasha_h_update_pallas` / :func:`dasha_payload_blocks_pallas` —
  the compressed-wire split: a dense h-tracker pass plus a
  scalar-prefetch block gather that computes the Alg. 1 line-11 payload
  *only at the BlockRandK-selected blocks*, so the dense payload never
  round-trips through HBM.
* :func:`buffered_commit_pallas` — the async server-step commit
  (DESIGN.md §9): ``g += (1/n) sum_k w_k m_k`` over the ``(K, D)``
  arrival buffer with per-contribution staleness weights, one pass —
  the buffer rows stream through VMEM once and the weighted reduction
  stays in-register instead of XLA materializing the ``(K, D)``
  scaled intermediate.

Tiling: inputs are reshaped to (rows, 128) lanes; the grid walks row
tiles of ``block_rows`` (default 512 rows = 256 KB/operand in VMEM ->
4 inputs + 3 outputs ~ 1.75 MB, comfortably inside ~16 MB VMEM).

``b, a, pa`` are compile-time constants (algorithm hyperparameters);
``participates`` (and the PAGE coin) are runtime scalars streamed via
(1, 1) / (n, 1) operands.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

LANES = 128
DEFAULT_BLOCK_ROWS = 512


def _pad_rows(d: int, block_rows: int) -> Tuple[int, int]:
    """Rows after padding ``d`` lanes-wise up to a tile multiple, and the
    flat pad length."""
    rows = -(-d // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    return rows_pad, rows_pad * LANES - d


def _prep_flat(x: Array, rows_pad: int, pad: int) -> Array:
    return jnp.pad(x, (0, pad)).reshape(rows_pad, LANES)


def _unprep_flat(x: Array, d: int) -> Array:
    return x.reshape(-1)[:d]


def _unprep_batched(x: Array, n: int, d: int) -> Array:
    return x.reshape(n, -1)[:, :d]


def _kernel(part_ref, gn_ref, go_ref, h_ref, gi_ref,
            k_ref, h_new_ref, payload_ref, *, b: float, a: float,
            pa: float):
    part = part_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    gi = gi_ref[...]
    k = gn - go - b * (h - go)
    inv_pa = 1.0 / pa
    k_ref[...] = k
    h_new_ref[...] = h + part * (k * inv_pa)
    payload_ref[...] = k * inv_pa - (a * inv_pa) * (gi - h)


@functools.partial(jax.jit, static_argnames=("b", "a", "pa", "block_rows",
                                             "interpret"))
def dasha_update_pallas(gn: Array, go: Array, h: Array, gi: Array,
                        participates: Array, *, b: float, a: float,
                        pa: float,
                        block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = True
                        ) -> Tuple[Array, Array, Array]:
    """Inputs are flat (D,) float32 vectors; returns (k, h_new, payload).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass ``interpret=False``.
    """
    (d,) = gn.shape
    rows_pad, pad = _pad_rows(d, block_rows)
    gn2, go2, h2, gi2 = (_prep_flat(x, rows_pad, pad)
                         for x in (gn, go, h, gi))
    part = jnp.reshape(participates.astype(jnp.float32), (1, 1))
    grid = (rows_pad // block_rows,)

    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))

    k2, hn2, pay2 = pl.pallas_call(
        functools.partial(_kernel, b=b, a=a, pa=pa),
        grid=grid,
        in_specs=[scalar, tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((rows_pad, LANES), jnp.float32)] * 3,
        interpret=interpret,
    )(part, gn2, go2, h2, gi2)

    return (_unprep_flat(k2, d), _unprep_flat(hn2, d),
            _unprep_flat(pay2, d))


# ----------------------------------------------------------------------
# Node-major batched forms (the reference DashaPP engine's layout)
# ----------------------------------------------------------------------

def _prep_batched(x: Array, rows_pad: int, pad: int) -> Array:
    n = x.shape[0]
    return jnp.pad(x, ((0, 0), (0, pad))).reshape(n, rows_pad, LANES)


def _batched_specs(n: int, rows_pad: int, block_rows: int):
    grid = (n, rows_pad // block_rows)
    tile = pl.BlockSpec((1, block_rows, LANES), lambda i, j: (i, j, 0))
    per_node = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return grid, tile, per_node


def _batched_kernel(mask_ref, gn_ref, go_ref, h_ref, gi_ref,
                    k_ref, h_new_ref, payload_ref, *, b: float, a: float,
                    pa: float):
    part = mask_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    gi = gi_ref[...]
    k = gn - go - b * (h - go)
    inv_pa = 1.0 / pa
    k_ref[...] = k
    h_new_ref[...] = h + part * (k * inv_pa)
    payload_ref[...] = k * inv_pa - (a * inv_pa) * (gi - h)


@functools.partial(jax.jit, static_argnames=("b", "a", "pa", "block_rows",
                                             "interpret"))
def dasha_update_batched_pallas(gn: Array, go: Array, h: Array, gi: Array,
                                mask: Array, *, b: float, a: float,
                                pa: float,
                                block_rows: int = DEFAULT_BLOCK_ROWS,
                                interpret: bool = True
                                ) -> Tuple[Array, Array, Array]:
    """Node-major fused update: inputs (n, d) float32, ``mask`` (n,) —
    the per-node participation indicator.  Returns (k, h_new, payload),
    each (n, d).  One launch covers all ``n`` simulated nodes: the grid
    walks (node, row-tile) so the Alg. 2/5 chain never materializes
    per-node intermediates (DESIGN.md §6)."""
    n, d = gn.shape
    rows_pad, pad = _pad_rows(d, block_rows)
    gn2, go2, h2, gi2 = (_prep_batched(x, rows_pad, pad)
                         for x in (gn, go, h, gi))
    mask2 = jnp.reshape(mask.astype(jnp.float32), (n, 1))
    grid, tile, per_node = _batched_specs(n, rows_pad, block_rows)

    k2, hn2, pay2 = pl.pallas_call(
        functools.partial(_batched_kernel, b=b, a=a, pa=pa),
        grid=grid,
        in_specs=[per_node, tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((n, rows_pad, LANES),
                                        jnp.float32)] * 3,
        interpret=interpret,
    )(mask2, gn2, go2, h2, gi2)

    return (_unprep_batched(k2, n, d), _unprep_batched(hn2, n, d),
            _unprep_batched(pay2, n, d))


def _page_kernel(mask_ref, coin_ref, gn_ref, go_ref, bn_ref, bo_ref, h_ref,
                 gi_ref, k_ref, h_new_ref, payload_ref, *, b: float,
                 a: float, pa: float, p_page: float):
    part = mask_ref[0, 0]
    coin = coin_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    gi = gi_ref[...]
    k_full = gn - go - (b / p_page) * (h - go)
    k_mini = bn_ref[...] - bo_ref[...]
    k = coin * k_full + (1.0 - coin) * k_mini
    inv_pa = 1.0 / pa
    k_ref[...] = k
    h_new_ref[...] = h + part * (k * inv_pa)
    payload_ref[...] = k * inv_pa - (a * inv_pa) * (gi - h)


@functools.partial(jax.jit, static_argnames=("b", "a", "pa", "p_page",
                                             "block_rows", "interpret"))
def dasha_page_update_batched_pallas(gn: Array, go: Array, bn: Array,
                                     bo: Array, h: Array, gi: Array,
                                     mask: Array, coin: Array, *, b: float,
                                     a: float, pa: float, p_page: float,
                                     block_rows: int = DEFAULT_BLOCK_ROWS,
                                     interpret: bool = True
                                     ) -> Tuple[Array, Array, Array]:
    """Alg. 3 (PAGE) rule fused with lines 10-11: the full-gradient branch
    ``gn - go - (b/p_page)(h - go)`` and the minibatch branch ``bn - bo``
    are both computed in-register and selected by the shared Bernoulli
    ``coin`` (a runtime (1,1) scalar — one compilation serves both
    branches).  Inputs (n, d); returns (k, h_new, payload)."""
    n, d = gn.shape
    rows_pad, pad = _pad_rows(d, block_rows)
    gn2, go2, bn2, bo2, h2, gi2 = (_prep_batched(x, rows_pad, pad)
                                   for x in (gn, go, bn, bo, h, gi))
    mask2 = jnp.reshape(mask.astype(jnp.float32), (n, 1))
    coin2 = jnp.reshape(coin.astype(jnp.float32), (1, 1))
    grid, tile, per_node = _batched_specs(n, rows_pad, block_rows)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))

    k2, hn2, pay2 = pl.pallas_call(
        functools.partial(_page_kernel, b=b, a=a, pa=pa, p_page=p_page),
        grid=grid,
        in_specs=[per_node, scalar, tile, tile, tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((n, rows_pad, LANES),
                                        jnp.float32)] * 3,
        interpret=interpret,
    )(mask2, coin2, gn2, go2, bn2, bo2, h2, gi2)

    return (_unprep_batched(k2, n, d), _unprep_batched(hn2, n, d),
            _unprep_batched(pay2, n, d))


def _tail_kernel(mask_ref, k_ref, h_ref, gi_ref, h_new_ref, payload_ref, *,
                 a: float, pa: float):
    part = mask_ref[0, 0]
    k = k_ref[...]
    h = h_ref[...]
    inv_pa = 1.0 / pa
    h_new_ref[...] = h + part * (k * inv_pa)
    payload_ref[...] = k * inv_pa - (a * inv_pa) * (gi_ref[...] - h)


@functools.partial(jax.jit, static_argnames=("a", "pa", "block_rows",
                                             "interpret"))
def dasha_tail_batched_pallas(k: Array, h: Array, gi: Array, mask: Array, *,
                              a: float, pa: float,
                              block_rows: int = DEFAULT_BLOCK_ROWS,
                              interpret: bool = True
                              ) -> Tuple[Array, Array]:
    """Lines 10-11 of Algorithm 1 given a precomputed ``k_i`` (n, d):
    the finite-MVR rule (Alg. 4) builds ``k_i`` by a component scatter
    that has no dense-elementwise shape, so only the tail fuses.
    Returns (h_new, payload)."""
    n, d = k.shape
    rows_pad, pad = _pad_rows(d, block_rows)
    k2, h2, gi2 = (_prep_batched(x, rows_pad, pad) for x in (k, h, gi))
    mask2 = jnp.reshape(mask.astype(jnp.float32), (n, 1))
    grid, tile, per_node = _batched_specs(n, rows_pad, block_rows)

    hn2, pay2 = pl.pallas_call(
        functools.partial(_tail_kernel, a=a, pa=pa),
        grid=grid,
        in_specs=[per_node, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((n, rows_pad, LANES),
                                        jnp.float32)] * 2,
        interpret=interpret,
    )(mask2, k2, h2, gi2)

    return _unprep_batched(hn2, n, d), _unprep_batched(pay2, n, d)


# ----------------------------------------------------------------------
# Compressed-wire split: dense h pass + payload-at-selected-blocks
# ----------------------------------------------------------------------

def _h_update_kernel(part_ref, gn_ref, go_ref, h_ref, h_new_ref, *,
                     b: float, pa: float):
    part = part_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    k = gn - go - b * (h - go)
    h_new_ref[...] = h + part * (k * (1.0 / pa))


@functools.partial(jax.jit, static_argnames=("b", "pa", "block_rows",
                                             "interpret"))
def dasha_h_update_pallas(gn: Array, go: Array, h: Array,
                          participates: Array, *, b: float, pa: float,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True) -> Array:
    """Line 10 only, flat (D,): ``h += part * k / pa`` with ``k``
    recomputed in-register (3 reads + 1 write of D — ``k`` itself never
    touches HBM).  Pairs with :func:`dasha_payload_blocks_pallas` for the
    sparse wire path (DESIGN.md §6)."""
    (d,) = gn.shape
    rows_pad, pad = _pad_rows(d, block_rows)
    gn2, go2, h2 = (_prep_flat(x, rows_pad, pad) for x in (gn, go, h))
    part = jnp.reshape(participates.astype(jnp.float32), (1, 1))
    grid = (rows_pad // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))

    hn2 = pl.pallas_call(
        functools.partial(_h_update_kernel, b=b, pa=pa),
        grid=grid,
        in_specs=[scalar, tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.float32),
        interpret=interpret,
    )(part, gn2, go2, h2)
    return _unprep_flat(hn2, d)


def _page_h_update_kernel(part_ref, coin_ref, gn_ref, go_ref, bn_ref,
                          bo_ref, h_ref, h_new_ref, *, b: float, pa: float,
                          p_page: float):
    part = part_ref[0, 0]
    coin = coin_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    k_full = gn - go - (b / p_page) * (h - go)
    k_mini = bn_ref[...] - bo_ref[...]
    k = coin * k_full + (1.0 - coin) * k_mini
    h_new_ref[...] = h + part * (k * (1.0 / pa))


@functools.partial(jax.jit, static_argnames=("b", "pa", "p_page",
                                             "block_rows", "interpret"))
def dasha_page_h_update_pallas(gn: Array, go: Array, bn: Array, bo: Array,
                               h: Array, participates: Array, coin: Array,
                               *, b: float, pa: float, p_page: float,
                               block_rows: int = DEFAULT_BLOCK_ROWS,
                               interpret: bool = True) -> Array:
    """Line 10 with the Alg. 3 PAGE ``k`` recomputed in-register, flat
    (D,): both branches + the shared-coin select never touch HBM.
    Pairs with :func:`dasha_page_payload_blocks_pallas` for the PAGE
    sparse wire (DESIGN.md §8)."""
    (d,) = gn.shape
    rows_pad, pad = _pad_rows(d, block_rows)
    gn2, go2, bn2, bo2, h2 = (_prep_flat(x, rows_pad, pad)
                              for x in (gn, go, bn, bo, h))
    part = jnp.reshape(participates.astype(jnp.float32), (1, 1))
    coin2 = jnp.reshape(coin.astype(jnp.float32), (1, 1))
    grid = (rows_pad // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))

    hn2 = pl.pallas_call(
        functools.partial(_page_h_update_kernel, b=b, pa=pa,
                          p_page=p_page),
        grid=grid,
        in_specs=[scalar, scalar, tile, tile, tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.float32),
        interpret=interpret,
    )(part, coin2, gn2, go2, bn2, bo2, h2)
    return _unprep_flat(hn2, d)


# ----------------------------------------------------------------------
# Async buffered commit (DESIGN.md §9)
# ----------------------------------------------------------------------

def _buffered_commit_kernel(w_ref, g_ref, m_ref, out_ref, *, inv_n: float):
    # m tile: (K, block_rows, LANES); w: (K, 1) staleness weights.
    w = w_ref[...]                          # (K, 1)
    m = m_ref[...]
    acc = jnp.sum(m * w[:, :, None], axis=0)
    out_ref[...] = g_ref[...] + inv_n * acc


def _commit_block_rows(k: int, budget_bytes: int = 4 << 20) -> int:
    """Largest multiple-of-8 row tile such that the (K + 2) operands of
    one grid step fit the VMEM budget."""
    rows = budget_bytes // ((k + 2) * LANES * 4)
    return max(8, min(DEFAULT_BLOCK_ROWS, (rows // 8) * 8))


@functools.partial(jax.jit, static_argnames=("inv_n", "interpret"))
def buffered_commit_pallas(g: Array, m_buf: Array, weights: Array, *,
                           inv_n: float, interpret: bool = True) -> Array:
    """The async server step (DESIGN.md §9): commit a buffer of ``K``
    arrived messages into the server estimator in one fused pass,

        g_new = g + (1/n) * sum_k weights[k] * m_buf[k],

    with ``weights`` the per-contribution staleness weights ``w(s)``.
    ``g`` is flat (D,), ``m_buf`` (K, D), ``weights`` (K,) — all
    float32.  The grid walks row tiles; each step streams the K buffer
    rows of that tile through VMEM once and reduces in-register."""
    (d,) = g.shape
    kk = int(m_buf.shape[0])
    block_rows = _commit_block_rows(kk)
    rows_pad, pad = _pad_rows(d, block_rows)
    g2 = _prep_flat(g, rows_pad, pad)
    m2 = jnp.pad(m_buf, ((0, 0), (0, pad))).reshape(kk, rows_pad, LANES)
    w2 = jnp.reshape(weights.astype(jnp.float32), (kk, 1))
    grid = (rows_pad // block_rows,)

    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    buf_tile = pl.BlockSpec((kk, block_rows, LANES), lambda i: (0, i, 0))
    wspec = pl.BlockSpec((kk, 1), lambda i: (0, 0))

    out = pl.pallas_call(
        functools.partial(_buffered_commit_kernel, inv_n=inv_n),
        grid=grid,
        in_specs=[wspec, tile, buf_tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.float32),
        interpret=interpret,
    )(w2, g2, m2)
    return _unprep_flat(out, d)


def _payload_blocks_kernel(idx_ref, gn_ref, go_ref, h_ref, gi_ref, out_ref,
                           *, b: float, a: float, pa: float, scale: float):
    # The BlockSpec index_map (scalar prefetch) already routed block
    # idx[i] of every input here; the body is the full line-9..11 chain
    # plus the RandK unbiasedness scale, in-register.
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    k = gn - go - b * (h - go)
    inv_pa = 1.0 / pa
    payload = k * inv_pa - (a * inv_pa) * (gi_ref[...] - h)
    out_ref[...] = payload * scale


@functools.partial(jax.jit, static_argnames=("b", "a", "pa", "scale",
                                             "block_size", "interpret"))
def dasha_payload_blocks_pallas(gn: Array, go: Array, h: Array, gi: Array,
                                block_idx: Array, *, b: float, a: float,
                                pa: float, scale: float, block_size: int,
                                interpret: bool = True) -> Array:
    """Fused update+compress for the BlockRandK wire (DESIGN.md §6):
    computes the Alg. 1 line-11 payload **only at the selected blocks**
    and scales it for unbiasedness — the dense payload intermediate
    never exists in HBM.  Inputs are flat (D,) float32; ``block_idx``
    is (k_blocks,) int32 over the (ceil(D/bs), bs) block view.  Returns
    (k_blocks, block_size) wire values."""
    (d,) = gn.shape
    kb = int(block_idx.shape[0])
    bs = block_size
    nb = -(-d // bs)
    pad = nb * bs - d

    def prep(x):
        return jnp.pad(x, (0, pad)).reshape(nb, bs)

    gn2, go2, h2, gi2 = map(prep, (gn, go, h, gi))
    row = pl.BlockSpec((1, bs), lambda i, idx: (idx[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kb,),
        in_specs=[row, row, row, row],
        out_specs=pl.BlockSpec((1, bs), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_payload_blocks_kernel, b=b, a=a, pa=pa,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kb, bs), jnp.float32),
        interpret=interpret,
    )(block_idx.astype(jnp.int32), gn2, go2, h2, gi2)


def _page_payload_blocks_kernel(idx_ref, coin_ref, gn_ref, go_ref, bn_ref,
                                bo_ref, h_ref, gi_ref, out_ref, *,
                                b: float, a: float, pa: float,
                                p_page: float, scale: float):
    # Same scalar-prefetch gather as _payload_blocks_kernel, with the
    # Alg. 3 k-rule (both branches + shared coin) in-register.
    coin = coin_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    k_full = gn - go - (b / p_page) * (h - go)
    k_mini = bn_ref[...] - bo_ref[...]
    k = coin * k_full + (1.0 - coin) * k_mini
    inv_pa = 1.0 / pa
    payload = k * inv_pa - (a * inv_pa) * (gi_ref[...] - h)
    out_ref[...] = payload * scale


@functools.partial(jax.jit, static_argnames=("b", "a", "pa", "p_page",
                                             "scale", "block_size",
                                             "interpret"))
def dasha_page_payload_blocks_pallas(gn: Array, go: Array, bn: Array,
                                     bo: Array, h: Array, gi: Array,
                                     block_idx: Array, coin: Array, *,
                                     b: float, a: float, pa: float,
                                     p_page: float, scale: float,
                                     block_size: int,
                                     interpret: bool = True) -> Array:
    """Fused PAGE update+compress for the BlockRandK wire: the Alg. 3
    line-11 payload evaluated **only at the selected blocks** (the
    dense payload never exists in HBM), pre-scaled for unbiasedness.
    Inputs are flat (D,) float32 plus the shared (scalar) coin; returns
    (k_blocks, block_size) wire values."""
    (d,) = gn.shape
    kb = int(block_idx.shape[0])
    bs = block_size
    nb = -(-d // bs)
    pad = nb * bs - d

    def prep(x):
        return jnp.pad(x, (0, pad)).reshape(nb, bs)

    gn2, go2, bn2, bo2, h2, gi2 = map(prep, (gn, go, bn, bo, h, gi))
    coin2 = jnp.reshape(coin.astype(jnp.float32), (1, 1))
    row = pl.BlockSpec((1, bs), lambda i, idx: (idx[i], 0))
    scalar = pl.BlockSpec((1, 1), lambda i, idx: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kb,),
        in_specs=[scalar, row, row, row, row, row, row],
        out_specs=pl.BlockSpec((1, bs), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_page_payload_blocks_kernel, b=b, a=a, pa=pa,
                          p_page=p_page, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kb, bs), jnp.float32),
        interpret=interpret,
    )(block_idx.astype(jnp.int32), coin2, gn2, go2, bn2, bo2, h2, gi2)
