"""Fused DASHA control-variate update as a Pallas TPU kernel.

Why a kernel (DESIGN.md §6): the per-node update is a chain of five
elementwise passes over grad-sized vectors

    k       = gn - go - b (h - go)
    h_new   = h + part * k / pa
    payload = k / pa - (a/pa)(g_i - h)

with arithmetic intensity ~O(1) — pure HBM-bandwidth-bound.  Unfused,
XLA may materialize k and intermediate diffs; the fused kernel streams
the four inputs once and writes the three outputs once: 7 HBM transfers
of D instead of ~11+, a ~1.6x memory-roofline win on the optimizer phase
(validated against the HLO bytes in benchmarks/bench_kernels.py).

Tiling: inputs are reshaped to (rows, 128) lanes; the grid walks row
tiles of ``block_rows`` (default 512 rows = 256 KB/operand in VMEM ->
4 inputs + 3 outputs ~ 1.75 MB, comfortably inside ~16 MB VMEM).

``b, a, pa`` are compile-time constants (algorithm hyperparameters);
``participates`` is a runtime scalar streamed via a (1, 1) operand.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

LANES = 128
DEFAULT_BLOCK_ROWS = 512


def _kernel(part_ref, gn_ref, go_ref, h_ref, gi_ref,
            k_ref, h_new_ref, payload_ref, *, b: float, a: float,
            pa: float):
    part = part_ref[0, 0]
    gn = gn_ref[...]
    go = go_ref[...]
    h = h_ref[...]
    gi = gi_ref[...]
    k = gn - go - b * (h - go)
    inv_pa = 1.0 / pa
    k_ref[...] = k
    h_new_ref[...] = h + part * (k * inv_pa)
    payload_ref[...] = k * inv_pa - (a * inv_pa) * (gi - h)


@functools.partial(jax.jit, static_argnames=("b", "a", "pa", "block_rows",
                                             "interpret"))
def dasha_update_pallas(gn: Array, go: Array, h: Array, gi: Array,
                        participates: Array, *, b: float, a: float,
                        pa: float,
                        block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = True
                        ) -> Tuple[Array, Array, Array]:
    """Inputs are flat (D,) float32 vectors; returns (k, h_new, payload).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass ``interpret=False``.
    """
    (d,) = gn.shape
    rows = -(-d // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * LANES - d

    def prep(x):
        return jnp.pad(x, (0, pad)).reshape(rows_pad, LANES)

    gn2, go2, h2, gi2 = map(prep, (gn, go, h, gi))
    part = jnp.reshape(participates.astype(jnp.float32), (1, 1))
    grid = (rows_pad // block_rows,)

    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))

    k2, hn2, pay2 = pl.pallas_call(
        functools.partial(_kernel, b=b, a=a, pa=pa),
        grid=grid,
        in_specs=[scalar, tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((rows_pad, LANES), jnp.float32)] * 3,
        interpret=interpret,
    )(part, gn2, go2, h2, gi2)

    unprep = lambda x: x.reshape(-1)[:d]
    return unprep(k2), unprep(hn2), unprep(pay2)
