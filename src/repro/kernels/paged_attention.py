"""Paged-attention decode kernel (DESIGN.md §11).

The paged serving engine stores the KV cache as fixed-size token pages
in a shared pool: per layer ``k_pages``/``v_pages`` are
``(num_pages, page_size, kvH, hd)`` and each request owns an ordered
page table mapping its logical positions ``[i*P, (i+1)*P)`` to physical
page ids.  The decode read is therefore a *gather* attention: for each
batch slot, collect that slot's pages via its page-table row and run
online softmax over the valid token range.

Why a kernel: the jnp path materializes the gathered ``(B, M*P, kvH,
hd)`` K and V in HBM (2 extra round trips of the whole attended
context per layer per token) before the attention reduction reads them
again.  The kernel gathers each page HBM→VMEM exactly once via the
scalar-prefetched page table (the BlockSpec index_map routes physical
page ``table[b, j]`` to grid step ``(b, j)`` — the same idiom as
``dasha_payload_blocks_pallas``) and keeps the online-softmax
accumulators (``acc``, ``m``, ``l``) in VMEM scratch across the page
walk, so the gathered context never exists densely in HBM.

VMEM budget (mirrors ``buffered_commit_pallas``): one grid step holds a
``(rows, kvH, hd)`` K tile + V tile + the query + accumulators.  Pages
larger than the row budget are walked in sub-page tiles of
``_page_tile_rows`` rows (a multiple of 8 f32 sublanes) so the working
set stays inside ``PAGE_VMEM_BUDGET`` regardless of ``page_size``.

Masking contract: the fed token's KV is written *before* the read (the
serving engine's write-then-attend step), so the query at position
``lens-1`` attends every index ``i < lens`` — and, for sliding-window
archs, ``lens - 1 - i < window``.  Padded page-table entries point at
page 0; their positions are ``>= lens`` and masked.  Pool pages carry
stale bytes from previous occupants in their unwritten slots; those
positions are also ``>= lens`` for the owning slot, so the validity
mask is the single source of isolation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

PAGE_VMEM_BUDGET = 4 << 20   # bytes per grid step, as buffered_commit


def _page_tile_rows(page_size: int, kvh: int, hd: int,
                    budget: int = PAGE_VMEM_BUDGET) -> int:
    """Largest multiple-of-8 divisor of ``page_size`` whose K+V tiles fit
    the VMEM budget; falls back to the full page when ``page_size`` has
    no 8-aligned divisor (small smoke pages in interpret mode)."""
    row_bytes = 2 * kvh * hd * 4            # K + V, f32
    max_rows = max(1, budget // max(row_bytes, 1))
    if page_size <= max_rows:
        return page_size
    best = page_size   # fallback: caller sized pages past the budget
    for rows in range(8, page_size, 8):
        if page_size % rows == 0 and rows <= max_rows:
            best = rows
    return best


def paged_attention_vmem_bytes(page_size: int, kvh: int, hd: int,
                               num_q_heads: int) -> int:
    """Worst-case VMEM bytes of one grid step (f32): K/V tile + query +
    accumulators — the number the §11 budget table reports."""
    rows = _page_tile_rows(page_size, kvh, hd)
    tile = 2 * rows * kvh * hd * 4
    q = num_q_heads * hd * 4
    acc = num_q_heads * hd * 4 + 2 * num_q_heads * 4
    return tile + q + acc


# ----------------------------------------------------------------------
# jnp reference (the oracle the kernel is tested against)
# ----------------------------------------------------------------------

def paged_attention_ref(q: Array, k_pages: Array, v_pages: Array,
                        page_table: Array, lens: Array, *,
                        window: int | None = None) -> Array:
    """Gather-attention oracle.  q: (B, H, hd) one query per slot;
    k_pages/v_pages: (NP, P, kvH, hd); page_table: (B, M) int32;
    lens: (B,) int32 — valid tokens per slot INCLUDING the one just
    written.  Returns (B, H, hd) f32."""
    B, H, hd = q.shape
    _, P, kvh, _ = k_pages.shape
    M = page_table.shape[1]
    G = H // kvh
    k = k_pages[page_table].reshape(B, M * P, kvh, hd).astype(jnp.float32)
    v = v_pages[page_table].reshape(B, M * P, kvh, hd).astype(jnp.float32)
    idx = jnp.arange(M * P)[None, :]
    valid = idx < lens[:, None]
    if window is not None:
        valid &= idx >= lens[:, None] - window
    qg = q.reshape(B, kvh, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v)
    return out.reshape(B, H, hd)


# ----------------------------------------------------------------------
# Pallas kernel
# ----------------------------------------------------------------------

def _paged_attention_kernel(table_ref, lens_ref, q_ref, k_ref, v_ref,
                            out_ref, acc_ref, m_ref, l_ref, *,
                            page_size: int, tile_rows: int, groups: int,
                            window: int | None, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_j = pl.num_programs(1)
    tiles_per_page = page_size // tile_rows

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    lens = lens_ref[b]
    base = (j // tiles_per_page) * page_size + (j % tiles_per_page) * tile_rows
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, tile_rows), 2)
    valid = pos < lens
    if window is not None:
        valid &= pos >= lens - window

    kvh = k_ref.shape[2]
    hd = k_ref.shape[3]
    q = q_ref[0].reshape(kvh, groups, hd).astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)                 # (tile_rows, kvH, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.einsum("kgh,skh->kgs", q, k) * scale     # (kvH, G, tile_rows)
    s = jnp.where(valid, s, -1e30)

    m_old = m_ref[...]                               # (kvH, G)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[..., None])
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("kgs,skh->kgh", p, v))

    @pl.when(j == n_j - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0] = out.reshape(kvh * groups, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_pallas(q: Array, k_pages: Array, v_pages: Array,
                           page_table: Array, lens: Array, *,
                           window: int | None = None,
                           interpret: bool = True) -> Array:
    """Pallas paged-attention decode; same contract as
    :func:`paged_attention_ref`.  Grid walks (slot, page-tile); the
    scalar-prefetched page table routes physical pages into VMEM and the
    online-softmax state lives in scratch across each slot's walk."""
    B, H, hd = q.shape
    NP, P, kvh, _ = k_pages.shape
    M = page_table.shape[1]
    G = H // kvh
    tile_rows = _page_tile_rows(P, kvh, hd)
    tiles_per_page = P // tile_rows
    scale = 1.0 / math.sqrt(hd)

    q3 = q.reshape(B, 1, H, hd).astype(jnp.float32)

    def page_idx(b, j, table, lens_):
        return (table[b, (j * tile_rows) // P], (j % tiles_per_page), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, M * tiles_per_page),
        in_specs=[
            pl.BlockSpec((1, 1, H, hd), lambda b, j, t, l: (b, 0, 0, 0)),
            pl.BlockSpec((1, tile_rows, kvh, hd), page_idx),
            pl.BlockSpec((1, tile_rows, kvh, hd), page_idx),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, t, l: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, G, hd), jnp.float32),
            pltpu.VMEM((kvh, G), jnp.float32),
            pltpu.VMEM((kvh, G), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attention_kernel, page_size=P,
                          tile_rows=tile_rows, groups=G, window=window,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lens.astype(jnp.int32),
      q3, k_pages.astype(jnp.float32), v_pages.astype(jnp.float32))
