"""Paged-attention decode kernels (DESIGN.md §11).

The paged serving engine stores the KV cache as fixed-size token pages
in a shared pool: per layer ``k_pages``/``v_pages`` are
``(num_pages, page_size, kvH, hd)`` and each request owns an ordered
page table mapping its logical positions ``[i*P, (i+1)*P)`` to physical
page ids.  The decode read is therefore a *gather* attention: for each
batch slot, collect that slot's pages via its page-table row and run
online softmax over the valid token range.

Why a kernel: the jnp path materializes the gathered ``(B, M*P, kvH,
hd)`` K and V in HBM (2 extra round trips of the whole attended
context per layer per token) before the attention reduction reads them
again.  The kernel gathers each page HBM→VMEM exactly once via the
scalar-prefetched page table (the BlockSpec index_map routes physical
page ``table[b, j]`` to its grid step — the same idiom as
``dasha_payload_blocks_pallas``) and keeps the online-softmax
accumulators (``acc``, ``m``, ``l``) in VMEM scratch across the page
walk, so the gathered context never exists densely in HBM.

Two kernels share the page-table-walk machinery:

* :func:`paged_attention_batched_pallas` — the fused multi-request GQA
  launch.  ONE invocation serves every active sequence of a serve pass:
  the grid walks ``(slot, kv_head, page_tile)`` and each slot carries
  ``C >= 1`` queries (``q_lens`` per slot), so a chunked-prefill pass
  (several prompt tokens for some slots, one decode token for others)
  is the same launch as a pure decode pass with ``C == 1``.
* :func:`paged_mla_attention_pallas` — the rank-compressed latent
  cache (MLA).  Works in the *absorbed* form: scores are taken directly
  against the latent pages ``q_abs · c_kv + q_rope · k_rope`` (W_uk
  folded into the query by the caller) and the output is the latent-
  space accumulation ``p · c_kv`` (W_uv applied by the caller), so the
  per-token page traffic stays ``r + rope_hd`` floats — the up-projected
  K/V never exist, in HBM *or* VMEM.

VMEM budget (mirrors ``buffered_commit_pallas``): one grid step holds
one K tile + V tile (GQA: a single kv head; MLA: the latent + rope
rows), the query block, and the accumulators.  Pages larger than the
row budget are walked in sub-page tiles of ``_page_tile_rows`` rows (a
multiple of 8 f32 sublanes) so the working set stays inside
``PAGE_VMEM_BUDGET`` regardless of ``page_size``.

Masking contract: the fed tokens' KV is written *before* the read (the
serving engine's write-then-attend step).  ``start`` is the tokens per
slot BEFORE this pass's writes, so query ``c`` of a slot sits at
absolute position ``start + c`` and attends every index
``i < start + c + 1`` — and, for sliding-window archs,
``start + c - i < window``.  Padded page-table entries point at page 0;
their positions are ``>= lens`` and masked.  Pool pages carry stale
bytes from previous occupants in their unwritten slots; those positions
are also ``>= lens`` for the owning slot, so the validity mask is the
single source of isolation.  Queries ``c >= q_lens[b]`` are padding;
their outputs are well-defined (position-0 attention) but garbage by
contract — callers must ignore them.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

PAGE_VMEM_BUDGET = 4 << 20   # bytes per grid step, as buffered_commit


def _page_tile_rows(page_size: int, row_bytes: int,
                    budget: int = PAGE_VMEM_BUDGET) -> int:
    """Largest multiple-of-8 divisor of ``page_size`` whose K+V tiles fit
    the VMEM budget; falls back to the full page when ``page_size`` has
    no 8-aligned divisor (small smoke pages in interpret mode)."""
    max_rows = max(1, budget // max(row_bytes, 1))
    if page_size <= max_rows:
        return page_size
    best = page_size   # fallback: caller sized pages past the budget
    for rows in range(8, page_size, 8):
        if page_size % rows == 0 and rows <= max_rows:
            best = rows
    return best


def paged_attention_vmem_bytes(page_size: int, kvh: int, hd: int,
                               num_q_heads: int) -> int:
    """Worst-case VMEM bytes of one grid step (f32): K/V tile + query +
    accumulators — the number the §11 budget table reports.  The fused
    grid walks one kv head per step, so the tile is ``rows * hd``
    regardless of ``kvh``."""
    rows = _page_tile_rows(page_size, 2 * hd * 4)
    tile = 2 * rows * hd * 4
    q = num_q_heads * hd * 4
    acc = num_q_heads * hd * 4 + 2 * num_q_heads * 4
    return tile + q + acc


# ----------------------------------------------------------------------
# jnp references (the oracles the kernels are tested against)
# ----------------------------------------------------------------------

def paged_attention_batched_ref(q: Array, k_pages: Array, v_pages: Array,
                                page_table: Array, start: Array,
                                q_lens: Array, *,
                                window: int | None = None) -> Array:
    """Batched gather-attention oracle.  q: (B, C, H, hd) — up to C
    queries per slot; k_pages/v_pages: (NP, P, kvH, hd); page_table:
    (B, M) int32; start: (B,) tokens per slot BEFORE this pass's writes;
    q_lens: (B,) valid queries per slot (query ``c`` sits at position
    ``start + c``; rows ``c >= q_lens`` are garbage by contract).
    Returns (B, C, H, hd) f32."""
    B, C, H, hd = q.shape
    _, P, kvh, _ = k_pages.shape
    M = page_table.shape[1]
    G = H // kvh
    k = k_pages[page_table].reshape(B, M * P, kvh, hd).astype(jnp.float32)
    v = v_pages[page_table].reshape(B, M * P, kvh, hd).astype(jnp.float32)
    idx = jnp.arange(M * P)[None, None, :]                  # (1, 1, S)
    q_pos = start[:, None] + jnp.arange(C)[None, :]         # (B, C)
    valid = idx < (q_pos + 1)[:, :, None]
    if window is not None:
        valid &= idx > (q_pos[:, :, None] - window)
    qg = q.reshape(B, C, kvh, G, hd).astype(jnp.float32)
    s = jnp.einsum("bckgh,bskh->bkgcs", qg, k) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", p, v)
    return out.reshape(B, C, H, hd)


def paged_attention_ref(q: Array, k_pages: Array, v_pages: Array,
                        page_table: Array, lens: Array, *,
                        window: int | None = None) -> Array:
    """Single-query decode oracle (the legacy contract): q (B, H, hd),
    ``lens`` (B,) valid tokens per slot INCLUDING the one just written.
    A thin C=1 view of :func:`paged_attention_batched_ref`."""
    B = q.shape[0]
    out = paged_attention_batched_ref(
        q[:, None], k_pages, v_pages, page_table,
        jnp.maximum(lens - 1, 0), jnp.ones((B,), jnp.int32), window=window)
    return out[:, 0]


def paged_mla_attention_ref(q_abs: Array, q_rope: Array, ckv_pages: Array,
                            kr_pages: Array, page_table: Array,
                            start: Array, q_lens: Array, *,
                            scale: float,
                            window: int | None = None) -> Array:
    """Absorbed-form MLA latent attention oracle.  q_abs: (B, C, H, r)
    — the nope query with W_uk folded in (``q_nope · W_uk``); q_rope:
    (B, C, H, rope_hd); ckv_pages: (NP, P, r); kr_pages: (NP, P,
    rope_hd).  Returns the latent-space output (B, C, H, r) — the
    caller applies W_uv.  ``scale`` is 1/sqrt(qk_nope + qk_rope), the
    full-head softmax scale of the unabsorbed math."""
    B, C, H, r = q_abs.shape
    _, P, _ = ckv_pages.shape
    M = page_table.shape[1]
    ckv = ckv_pages[page_table].reshape(B, M * P, r).astype(jnp.float32)
    kr = kr_pages[page_table].reshape(B, M * P, -1).astype(jnp.float32)
    idx = jnp.arange(M * P)[None, None, :]
    q_pos = start[:, None] + jnp.arange(C)[None, :]
    valid = idx < (q_pos + 1)[:, :, None]
    if window is not None:
        valid &= idx > (q_pos[:, :, None] - window)
    s = (jnp.einsum("bchr,bsr->bhcs", q_abs.astype(jnp.float32), ckv)
         + jnp.einsum("bchx,bsx->bhcs", q_rope.astype(jnp.float32), kr))
    s = s * scale
    s = jnp.where(valid[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhcs,bsr->bchr", p, ckv)


# ----------------------------------------------------------------------
# fused multi-request GQA kernel
# ----------------------------------------------------------------------

def _paged_attention_batched_kernel(table_ref, start_ref, qlen_ref,
                                    q_ref, k_ref, v_ref, out_ref,
                                    acc_ref, m_ref, l_ref, *,
                                    page_size: int, tile_rows: int,
                                    window: int | None, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_j = pl.num_programs(2)
    tiles_per_page = page_size // tile_rows
    del qlen_ref   # rows past q_lens are garbage by contract

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    C = q_ref.shape[1]
    start = start_ref[b]
    base = (j // tiles_per_page) * page_size \
        + (j % tiles_per_page) * tile_rows
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile_rows), 1)
    q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    valid = pos < q_pos + 1                          # (C, tile_rows)
    if window is not None:
        valid &= pos > q_pos - window

    hd = k_ref.shape[3]
    q = q_ref[0, :, 0].astype(jnp.float32)           # (C, G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (tile_rows, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jnp.einsum("cgh,sh->cgs", q, k) * scale      # (C, G, tile_rows)
    s = jnp.where(valid[:, None], s, -1e30)

    m_old = m_ref[...]                               # (C, G)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[..., None])
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("cgs,sh->cgh", p, v))

    @pl.when(j == n_j - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0, :, 0] = out.reshape(C, acc_ref.shape[1], hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_batched_pallas(q: Array, k_pages: Array,
                                   v_pages: Array, page_table: Array,
                                   start: Array, q_lens: Array, *,
                                   window: int | None = None,
                                   interpret: bool = True) -> Array:
    """Fused multi-request paged attention; same contract as
    :func:`paged_attention_batched_ref`.  ONE launch per serve pass:
    the grid walks ``(slot, kv_head, page_tile)``, the scalar-prefetched
    page table routes physical pages into VMEM, and the online-softmax
    state lives in scratch across each (slot, head) walk.  Walking one
    kv head per step keeps the tile at ``rows * hd`` bytes independent
    of ``kvH``, so big-GQA configs stay under the VMEM budget."""
    B, C, H, hd = q.shape
    NP, P, kvh, _ = k_pages.shape
    M = page_table.shape[1]
    G = H // kvh
    tile_rows = _page_tile_rows(P, 2 * hd * 4)
    tiles_per_page = P // tile_rows
    scale = 1.0 / math.sqrt(hd)

    q5 = q.reshape(B, C, kvh, G, hd).astype(jnp.float32)

    def page_idx(b, h, j, table, start_, qlens_):
        return (table[b, (j * tile_rows) // P], (j % tiles_per_page), h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, kvh, M * tiles_per_page),
        in_specs=[
            pl.BlockSpec((1, C, 1, G, hd),
                         lambda b, h, j, t, s, ql: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, tile_rows, 1, hd), page_idx),
            pl.BlockSpec((1, tile_rows, 1, hd), page_idx),
        ],
        out_specs=pl.BlockSpec((1, C, 1, G, hd),
                               lambda b, h, j, t, s, ql: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, G, hd), jnp.float32),
            pltpu.VMEM((C, G), jnp.float32),
            pltpu.VMEM((C, G), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_attention_batched_kernel, page_size=P,
                          tile_rows=tile_rows, window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, kvh, G, hd), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start.astype(jnp.int32),
      q_lens.astype(jnp.int32), q5, k_pages.astype(jnp.float32),
      v_pages.astype(jnp.float32))
    return out.reshape(B, C, H, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_pallas(q: Array, k_pages: Array, v_pages: Array,
                           page_table: Array, lens: Array, *,
                           window: int | None = None,
                           interpret: bool = True) -> Array:
    """Single-query decode view of the fused kernel (legacy contract of
    :func:`paged_attention_ref`): ``lens`` counts the token just
    written, so ``start = lens - 1`` and every slot carries one query."""
    B = q.shape[0]
    out = paged_attention_batched_pallas(
        q[:, None], k_pages, v_pages, page_table,
        jnp.maximum(lens - 1, 0), jnp.ones((B,), jnp.int32),
        window=window, interpret=interpret)
    return out[:, 0]


# ----------------------------------------------------------------------
# paged MLA latent-attention kernel (absorbed form)
# ----------------------------------------------------------------------

def _paged_mla_kernel(table_ref, start_ref, qlen_ref, qa_ref, qr_ref,
                      ckv_ref, kr_ref, out_ref, acc_ref, m_ref, l_ref, *,
                      page_size: int, tile_rows: int,
                      window: int | None, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_j = pl.num_programs(1)
    tiles_per_page = page_size // tile_rows
    del qlen_ref

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    C = qa_ref.shape[1]
    start = start_ref[b]
    base = (j // tiles_per_page) * page_size \
        + (j % tiles_per_page) * tile_rows
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile_rows), 1)
    q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    valid = pos < q_pos + 1                          # (C, tile_rows)
    if window is not None:
        valid &= pos > q_pos - window

    qa = qa_ref[0].astype(jnp.float32)               # (C, H, r)
    qr = qr_ref[0].astype(jnp.float32)               # (C, H, rope_hd)
    ckv = ckv_ref[0].astype(jnp.float32)             # (tile_rows, r)
    kr = kr_ref[0].astype(jnp.float32)               # (tile_rows, rope_hd)
    s = (jnp.einsum("chr,sr->chs", qa, ckv)
         + jnp.einsum("chx,sx->chs", qr, kr)) * scale
    s = jnp.where(valid[:, None], s, -1e30)

    m_old = m_ref[...]                               # (C, H)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[..., None])
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("chs,sr->chr", p, ckv))

    @pl.when(j == n_j - 1)
    def _():
        out_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...],
                                                1e-30)[..., None]


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                             "interpret"))
def paged_mla_attention_pallas(q_abs: Array, q_rope: Array,
                               ckv_pages: Array, kr_pages: Array,
                               page_table: Array, start: Array,
                               q_lens: Array, *, scale: float,
                               window: int | None = None,
                               interpret: bool = True) -> Array:
    """Paged MLA decode in the absorbed form; same contract as
    :func:`paged_mla_attention_ref`.  Shares the page-table-walk idiom
    with the GQA kernel: grid ``(slot, page_tile)`` (every head reads
    the same rank-``r`` latent rows, so there is no head axis to walk),
    scores taken directly against the latent pages, output accumulated
    in latent space — the up-projected K/V never exist."""
    B, C, H, r = q_abs.shape
    NP, P, _ = ckv_pages.shape
    rr = kr_pages.shape[2]
    M = page_table.shape[1]
    tile_rows = _page_tile_rows(P, (r + rr) * 4)
    tiles_per_page = P // tile_rows

    def page_idx(b, j, table, start_, qlens_):
        return (table[b, (j * tile_rows) // P], (j % tiles_per_page), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, M * tiles_per_page),
        in_specs=[
            pl.BlockSpec((1, C, H, r), lambda b, j, t, s, ql: (b, 0, 0, 0)),
            pl.BlockSpec((1, C, H, rr), lambda b, j, t, s, ql: (b, 0, 0, 0)),
            pl.BlockSpec((1, tile_rows, r), page_idx),
            pl.BlockSpec((1, tile_rows, rr), page_idx),
        ],
        out_specs=pl.BlockSpec((1, C, H, r),
                               lambda b, j, t, s, ql: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, H, r), jnp.float32),
            pltpu.VMEM((C, H), jnp.float32),
            pltpu.VMEM((C, H), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_mla_kernel, page_size=P,
                          tile_rows=tile_rows, window=window,
                          scale=float(scale)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, r), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start.astype(jnp.int32),
      q_lens.astype(jnp.int32), q_abs.astype(jnp.float32),
      q_rope.astype(jnp.float32), ckv_pages.astype(jnp.float32),
      kr_pages.astype(jnp.float32))
