"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

The formulas themselves live in :mod:`repro.core.variants` — the
variant-rule layer is the single source of truth for the Algs. 2-5
math (DESIGN.md §8); these wrappers only compose them into the exact
input/output shapes each kernel exposes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.variants import (control_variate_tail, k_page,
                                 k_same_sample)

Array = jax.Array


def dasha_update_ref(gn: Array, go: Array, h: Array, g_i: Array, *,
                     b: float, a: float, pa: float, participates: Array
                     ) -> Tuple[Array, Array, Array]:
    """The per-node control-variate chain (Alg. 1 lines 9-11, k-rule of
    Algs. 2/5):

        k       = gn - go - b (h - go)
        h_new   = h + participates * k / pa
        payload = k / pa - (a / pa) (g_i - h)
    """
    k = k_same_sample(gn, go, h, b=b)
    h_new, payload = control_variate_tail(k, h, g_i, a=a, pa=pa,
                                          part=participates)
    return k, h_new, payload


def dasha_update_batched_ref(gn: Array, go: Array, h: Array, g_i: Array,
                             mask: Array, *, b: float, a: float, pa: float
                             ) -> Tuple[Array, Array, Array]:
    """Node-major (n, d) form of :func:`dasha_update_ref`; ``mask`` is the
    (n,) participation indicator."""
    k = k_same_sample(gn, go, h, b=b)
    h_new, payload = control_variate_tail(
        k, h, g_i, a=a, pa=pa, part=mask.astype(gn.dtype)[:, None])
    return k, h_new, payload


def dasha_page_update_ref(gn: Array, go: Array, bn: Array, bo: Array,
                          h: Array, g_i: Array, mask: Array, coin: Array,
                          *, b: float, a: float, pa: float, p_page: float
                          ) -> Tuple[Array, Array, Array]:
    """Alg. 3 PAGE rule + lines 10-11: shared Bernoulli ``coin`` selects
    the full-gradient branch (prob. p_page) vs the minibatch branch."""
    k = k_page(gn, go, bn, bo, h, coin, b=b, p_page=p_page)
    h_new, payload = control_variate_tail(
        k, h, g_i, a=a, pa=pa, part=mask.astype(gn.dtype)[:, None])
    return k, h_new, payload


def dasha_tail_ref(k: Array, h: Array, g_i: Array, mask: Array, *,
                   a: float, pa: float) -> Tuple[Array, Array]:
    """Lines 10-11 given a precomputed ``k`` (n, d) (finite-MVR path)."""
    return control_variate_tail(k, h, g_i, a=a, pa=pa,
                                part=mask.astype(k.dtype)[:, None])


def _blocks_of(payload: Array, block_size: int) -> Array:
    d = payload.shape[0]
    nb = -(-d // block_size)
    padded = jnp.pad(payload, (0, nb * block_size - d))
    return padded.reshape(nb, block_size)


def dasha_payload_blocks_ref(gn: Array, go: Array, h: Array, g_i: Array,
                             block_idx: Array, *, b: float, a: float,
                             pa: float, scale: float, block_size: int
                             ) -> Array:
    """Unfused composition the fused update+compress kernel must match:
    dense payload -> pad to blocks -> gather selected rows -> scale."""
    _, _, payload = dasha_update_ref(gn, go, h, g_i, b=b, a=a, pa=pa,
                                     participates=jnp.asarray(1.0))
    return _blocks_of(payload, block_size)[block_idx] * scale


def dasha_page_h_update_ref(gn: Array, go: Array, bn: Array, bo: Array,
                            h: Array, participates: Array, coin: Array,
                            *, b: float, pa: float, p_page: float
                            ) -> Array:
    """Line 10 with the PAGE k-rule (flat (D,))."""
    k = k_page(gn, go, bn, bo, h, coin, b=b, p_page=p_page)
    h_new, _ = control_variate_tail(k, h, jnp.zeros_like(h), a=0.0,
                                    pa=pa, part=participates)
    return h_new


def dasha_page_payload_blocks_ref(gn: Array, go: Array, bn: Array,
                                  bo: Array, h: Array, g_i: Array,
                                  block_idx: Array, coin: Array, *,
                                  b: float, a: float, pa: float,
                                  p_page: float, scale: float,
                                  block_size: int) -> Array:
    """Dense PAGE payload -> block gather -> scale (the fused kernel's
    oracle)."""
    k = k_page(gn, go, bn, bo, h, coin, b=b, p_page=p_page)
    _, payload = control_variate_tail(k, h, g_i, a=a, pa=pa,
                                      part=jnp.asarray(1.0))
    return _blocks_of(payload, block_size)[block_idx] * scale


def block_gather_ref(x_blocks: Array, block_idx: Array, scale: float
                     ) -> Array:
    """RandK block gather: x_blocks (nb, bs), block_idx (kb,) ->
    (kb, bs) scaled by ``scale`` (= nb / kb for unbiasedness)."""
    return x_blocks[block_idx] * scale


def block_scatter_add_ref(base_blocks: Array, vals: Array, block_idx: Array
                          ) -> Array:
    """base_blocks (nb, bs) += vals (kb, bs) at rows block_idx."""
    return base_blocks.at[block_idx].add(vals)
