"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def dasha_update_ref(gn: Array, go: Array, h: Array, g_i: Array, *,
                     b: float, a: float, pa: float, participates: Array
                     ) -> Tuple[Array, Array, Array]:
    """The per-node control-variate chain (Alg. 1 lines 9-11, k-rule of
    Algs. 2/5):

        k       = gn - go - b (h - go)
        h_new   = h + participates * k / pa
        payload = k / pa - (a / pa) (g_i - h)
    """
    k = gn - go - b * (h - go)
    h_new = h + participates * (k / pa)
    payload = k / pa - (a / pa) * (g_i - h)
    return k, h_new, payload


def block_gather_ref(x_blocks: Array, block_idx: Array, scale: float
                     ) -> Array:
    """RandK block gather: x_blocks (nb, bs), block_idx (kb,) ->
    (kb, bs) scaled by ``scale`` (= nb / kb for unbiasedness)."""
    return x_blocks[block_idx] * scale


def block_scatter_add_ref(base_blocks: Array, vals: Array, block_idx: Array
                          ) -> Array:
    """base_blocks (nb, bs) += vals (kb, bs) at rows block_idx."""
    return base_blocks.at[block_idx].add(vals)
