"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def dasha_update_ref(gn: Array, go: Array, h: Array, g_i: Array, *,
                     b: float, a: float, pa: float, participates: Array
                     ) -> Tuple[Array, Array, Array]:
    """The per-node control-variate chain (Alg. 1 lines 9-11, k-rule of
    Algs. 2/5):

        k       = gn - go - b (h - go)
        h_new   = h + participates * k / pa
        payload = k / pa - (a / pa) (g_i - h)
    """
    k = gn - go - b * (h - go)
    h_new = h + participates * (k / pa)
    payload = k / pa - (a / pa) * (g_i - h)
    return k, h_new, payload


def dasha_update_batched_ref(gn: Array, go: Array, h: Array, g_i: Array,
                             mask: Array, *, b: float, a: float, pa: float
                             ) -> Tuple[Array, Array, Array]:
    """Node-major (n, d) form of :func:`dasha_update_ref`; ``mask`` is the
    (n,) participation indicator."""
    m = mask.astype(gn.dtype)[:, None]
    k = gn - go - b * (h - go)
    h_new = h + m * (k / pa)
    payload = k / pa - (a / pa) * (g_i - h)
    return k, h_new, payload


def dasha_page_update_ref(gn: Array, go: Array, bn: Array, bo: Array,
                          h: Array, g_i: Array, mask: Array, coin: Array,
                          *, b: float, a: float, pa: float, p_page: float
                          ) -> Tuple[Array, Array, Array]:
    """Alg. 3 PAGE rule + lines 10-11: shared Bernoulli ``coin`` selects
    the full-gradient branch (prob. p_page) vs the minibatch branch."""
    m = mask.astype(gn.dtype)[:, None]
    k_full = gn - go - (b / p_page) * (h - go)
    k_mini = bn - bo
    k = jnp.where(coin.astype(bool), k_full, k_mini)
    h_new = h + m * (k / pa)
    payload = k / pa - (a / pa) * (g_i - h)
    return k, h_new, payload


def dasha_tail_ref(k: Array, h: Array, g_i: Array, mask: Array, *,
                   a: float, pa: float) -> Tuple[Array, Array]:
    """Lines 10-11 given a precomputed ``k`` (n, d) (finite-MVR path)."""
    m = mask.astype(k.dtype)[:, None]
    h_new = h + m * (k / pa)
    payload = k / pa - (a / pa) * (g_i - h)
    return h_new, payload


def dasha_payload_blocks_ref(gn: Array, go: Array, h: Array, g_i: Array,
                             block_idx: Array, *, b: float, a: float,
                             pa: float, scale: float, block_size: int
                             ) -> Array:
    """Unfused composition the fused update+compress kernel must match:
    dense payload -> pad to blocks -> gather selected rows -> scale."""
    _, _, payload = dasha_update_ref(gn, go, h, g_i, b=b, a=a, pa=pa,
                                     participates=jnp.asarray(1.0))
    d = payload.shape[0]
    nb = -(-d // block_size)
    padded = jnp.pad(payload, (0, nb * block_size - d))
    return padded.reshape(nb, block_size)[block_idx] * scale


def block_gather_ref(x_blocks: Array, block_idx: Array, scale: float
                     ) -> Array:
    """RandK block gather: x_blocks (nb, bs), block_idx (kb,) ->
    (kb, bs) scaled by ``scale`` (= nb / kb for unbiasedness)."""
    return x_blocks[block_idx] * scale


def block_scatter_add_ref(base_blocks: Array, vals: Array, block_idx: Array
                          ) -> Array:
    """base_blocks (nb, bs) += vals (kb, bs) at rows block_idx."""
    return base_blocks.at[block_idx].add(vals)
