"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (the body
executes in Python, numerics identical); on TPU set
``REPRO_PALLAS_INTERPRET=0`` or pass ``interpret=False``.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dasha_update import dasha_update_pallas
from repro.kernels.randk import block_gather_pallas, block_scatter_pallas

Array = jax.Array


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def dasha_update_op(gn: Array, go: Array, h: Array, gi: Array, *,
                    b: float, a: float, pa: float, participates: Array,
                    interpret: bool | None = None
                    ) -> Tuple[Array, Array, Array]:
    """Fused (k, h_new, payload); see kernels/dasha_update.py."""
    interp = _interpret_default() if interpret is None else interpret
    part = jnp.asarray(participates, jnp.float32)
    return dasha_update_pallas(
        gn.astype(jnp.float32), go.astype(jnp.float32),
        h.astype(jnp.float32), gi.astype(jnp.float32), part,
        b=float(b), a=float(a), pa=float(pa), interpret=interp)


def block_gather_op(x_blocks: Array, block_idx: Array, *, scale: float,
                    interpret: bool | None = None) -> Array:
    interp = _interpret_default() if interpret is None else interpret
    return block_gather_pallas(
        x_blocks.astype(jnp.float32), block_idx.astype(jnp.int32),
        k_blocks=int(block_idx.shape[0]), scale=float(scale),
        interpret=interp)


def block_scatter_op(base_blocks: Array, vals: Array, block_idx: Array,
                     interpret: bool | None = None) -> Array:
    interp = _interpret_default() if interpret is None else interpret
    return block_scatter_pallas(
        base_blocks.astype(jnp.float32), vals.astype(jnp.float32),
        block_idx.astype(jnp.int32), interpret=interp)
