"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (the body
executes in Python, numerics identical); on TPU set
``REPRO_PALLAS_INTERPRET=0`` or pass ``interpret=False``.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dasha_update import (buffered_commit_pallas,
                                        dasha_h_update_pallas,
                                        dasha_page_h_update_pallas,
                                        dasha_page_payload_blocks_pallas,
                                        dasha_page_update_batched_pallas,
                                        dasha_payload_blocks_pallas,
                                        dasha_tail_batched_pallas,
                                        dasha_update_batched_pallas,
                                        dasha_update_pallas)
from repro.kernels.paged_attention import (paged_attention_batched_pallas,
                                           paged_attention_pallas,
                                           paged_mla_attention_pallas)
from repro.kernels.randk import block_gather_pallas, block_scatter_pallas
from repro.obs.trace import kernel_scope

Array = jax.Array


def _scoped(name: str):
    """Wrap an op in :func:`repro.obs.trace.kernel_scope` so its Pallas
    launch is attributable (``repro.kernel.<name>``) in jax.profiler /
    Perfetto device traces.  named_scope costs only at trace time."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with kernel_scope(name):
                return fn(*args, **kwargs)
        return wrapper
    return deco


def interpret_default() -> bool:
    """Whether Pallas kernels run in interpret mode by default here:
    yes unless on TPU, overridable via ``REPRO_PALLAS_INTERPRET``."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


_interpret_default = interpret_default   # internal alias


def _f32(*xs: Array) -> tuple:
    return tuple(x.astype(jnp.float32) for x in xs)


@_scoped("dasha_update")
def dasha_update_op(gn: Array, go: Array, h: Array, gi: Array, *,
                    b: float, a: float, pa: float, participates: Array,
                    interpret: bool | None = None
                    ) -> Tuple[Array, Array, Array]:
    """Fused (k, h_new, payload); see kernels/dasha_update.py."""
    interp = _interpret_default() if interpret is None else interpret
    part = jnp.asarray(participates, jnp.float32)
    return dasha_update_pallas(
        *_f32(gn, go, h, gi), part,
        b=float(b), a=float(a), pa=float(pa), interpret=interp)


@_scoped("dasha_update_batched")
def dasha_update_batched_op(gn: Array, go: Array, h: Array, gi: Array,
                            mask: Array, *, b: float, a: float, pa: float,
                            interpret: bool | None = None
                            ) -> Tuple[Array, Array, Array]:
    """Node-major fused (k, h_new, payload), inputs (n, d), mask (n,).
    Covers the Alg. 2 (gradient) and Alg. 5 (MVR) k-rules — they share
    the ``gn - go - b (h - go)`` shape with ``gn/go`` = full vs minibatch
    gradients respectively."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_update_batched_pallas(
        *_f32(gn, go, h, gi), mask.astype(jnp.float32),
        b=float(b), a=float(a), pa=float(pa), interpret=interp)


@_scoped("dasha_page_update")
def dasha_page_update_op(gn: Array, go: Array, bn: Array, bo: Array,
                         h: Array, gi: Array, mask: Array, coin: Array, *,
                         b: float, a: float, pa: float, p_page: float,
                         interpret: bool | None = None
                         ) -> Tuple[Array, Array, Array]:
    """Fused Alg. 3 (PAGE) update: both branches + coin select + lines
    10-11 in one kernel launch.  Inputs (n, d); coin is a () scalar."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_page_update_batched_pallas(
        *_f32(gn, go, bn, bo, h, gi), mask.astype(jnp.float32),
        jnp.asarray(coin, jnp.float32),
        b=float(b), a=float(a), pa=float(pa), p_page=float(p_page),
        interpret=interp)


@_scoped("dasha_tail")
def dasha_tail_op(k: Array, h: Array, gi: Array, mask: Array, *,
                  a: float, pa: float, interpret: bool | None = None
                  ) -> Tuple[Array, Array]:
    """Fused lines 10-11 given precomputed k (finite-MVR, Alg. 4)."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_tail_batched_pallas(
        *_f32(k, h, gi), mask.astype(jnp.float32),
        a=float(a), pa=float(pa), interpret=interp)


@_scoped("dasha_h_update")
def dasha_h_update_op(gn: Array, go: Array, h: Array, *, b: float,
                      pa: float, participates: Array,
                      interpret: bool | None = None) -> Array:
    """Line-10 h-tracker pass only (flat (D,)); k stays in-register."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_h_update_pallas(
        *_f32(gn, go, h), jnp.asarray(participates, jnp.float32),
        b=float(b), pa=float(pa), interpret=interp)


@_scoped("dasha_payload_blocks")
def dasha_payload_blocks_op(gn: Array, go: Array, h: Array, gi: Array,
                            block_idx: Array, *, b: float, a: float,
                            pa: float, scale: float, block_size: int,
                            interpret: bool | None = None) -> Array:
    """Fused update+BlockRandK-compress: line-11 payload evaluated only
    at the selected blocks (never dense in HBM), pre-scaled for
    unbiasedness.  Returns (k_blocks, block_size) wire values."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_payload_blocks_pallas(
        *_f32(gn, go, h, gi), block_idx.astype(jnp.int32),
        b=float(b), a=float(a), pa=float(pa), scale=float(scale),
        block_size=int(block_size), interpret=interp)


@_scoped("dasha_page_h_update")
def dasha_page_h_update_op(gn: Array, go: Array, bn: Array, bo: Array,
                           h: Array, coin: Array, *, b: float, pa: float,
                           p_page: float, participates: Array,
                           interpret: bool | None = None) -> Array:
    """Line-10 h-tracker pass with the Alg. 3 PAGE k-rule in-register
    (flat (D,)); pairs with :func:`dasha_page_payload_blocks_op`."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_page_h_update_pallas(
        *_f32(gn, go, bn, bo, h), jnp.asarray(participates, jnp.float32),
        jnp.asarray(coin, jnp.float32),
        b=float(b), pa=float(pa), p_page=float(p_page), interpret=interp)


@_scoped("dasha_page_payload_blocks")
def dasha_page_payload_blocks_op(gn: Array, go: Array, bn: Array,
                                 bo: Array, h: Array, gi: Array,
                                 block_idx: Array, coin: Array, *,
                                 b: float, a: float, pa: float,
                                 p_page: float, scale: float,
                                 block_size: int,
                                 interpret: bool | None = None) -> Array:
    """Fused PAGE update+BlockRandK compress: the Alg. 3 payload
    evaluated only at the selected blocks (never dense in HBM)."""
    interp = _interpret_default() if interpret is None else interpret
    return dasha_page_payload_blocks_pallas(
        *_f32(gn, go, bn, bo, h, gi), block_idx.astype(jnp.int32),
        jnp.asarray(coin, jnp.float32),
        b=float(b), a=float(a), pa=float(pa), p_page=float(p_page),
        scale=float(scale), block_size=int(block_size), interpret=interp)


@_scoped("buffered_commit")
def buffered_commit_op(g: Array, m_buf: Array, weights: Array, *,
                       n_nodes: int, interpret: bool | None = None
                       ) -> Array:
    """Async server-step commit: ``g + (1/n_nodes) * (weights @ m_buf)``
    fused into one pass over the (K, D) arrival buffer (DESIGN.md §9)."""
    interp = _interpret_default() if interpret is None else interpret
    return buffered_commit_pallas(
        *_f32(g, m_buf, weights), inv_n=1.0 / float(n_nodes),
        interpret=interp)


@_scoped("paged_attention")
def paged_attention_op(q: Array, k_pages: Array, v_pages: Array,
                       page_table: Array, lens: Array, *,
                       window: int | None = None,
                       interpret: bool | None = None) -> Array:
    """Paged-attention decode read (DESIGN.md §11): online softmax over
    the pool pages selected by each slot's page-table row.  q (B, H,
    hd), pages (NP, P, kvH, hd), table (B, M), lens (B,) valid tokens
    per slot including the one just written.  Returns (B, H, hd) f32."""
    interp = _interpret_default() if interpret is None else interpret
    return paged_attention_pallas(
        q.astype(jnp.float32), k_pages.astype(jnp.float32),
        v_pages.astype(jnp.float32), page_table.astype(jnp.int32),
        lens.astype(jnp.int32),
        window=None if window is None else int(window), interpret=interp)


@_scoped("paged_attention_batched")
def paged_attention_batched_op(q: Array, k_pages: Array, v_pages: Array,
                               page_table: Array, start: Array,
                               q_lens: Array, *,
                               window: int | None = None,
                               interpret: bool | None = None) -> Array:
    """Fused multi-request paged-attention launch (DESIGN.md §11): one
    kernel invocation serves every active slot of a serve pass, each
    carrying up to C queries (chunked prefill folds prompt chunks into
    the same launch as single-token decode).  q (B, C, H, hd), start
    (B,) tokens per slot BEFORE this pass's writes, q_lens (B,) valid
    queries per slot.  Returns (B, C, H, hd) f32; rows ``c >= q_lens``
    are garbage by contract."""
    interp = _interpret_default() if interpret is None else interpret
    return paged_attention_batched_pallas(
        q.astype(jnp.float32), k_pages.astype(jnp.float32),
        v_pages.astype(jnp.float32), page_table.astype(jnp.int32),
        start.astype(jnp.int32), q_lens.astype(jnp.int32),
        window=None if window is None else int(window), interpret=interp)


@_scoped("paged_mla_attention")
def paged_mla_attention_op(q_abs: Array, q_rope: Array, ckv_pages: Array,
                           kr_pages: Array, page_table: Array,
                           start: Array, q_lens: Array, *, scale: float,
                           window: int | None = None,
                           interpret: bool | None = None) -> Array:
    """Paged MLA latent attention in the absorbed form (DESIGN.md §11):
    scores taken directly against the rank-r latent pages, output
    accumulated in latent space (caller applies W_uv).  q_abs (B, C, H,
    r) is ``q_nope · W_uk``; pages are (NP, P, r) / (NP, P, rope_hd)."""
    interp = _interpret_default() if interpret is None else interpret
    return paged_mla_attention_pallas(
        q_abs.astype(jnp.float32), q_rope.astype(jnp.float32),
        ckv_pages.astype(jnp.float32), kr_pages.astype(jnp.float32),
        page_table.astype(jnp.int32), start.astype(jnp.int32),
        q_lens.astype(jnp.int32), scale=float(scale),
        window=None if window is None else int(window), interpret=interp)


@_scoped("block_gather")
def block_gather_op(x_blocks: Array, block_idx: Array, *, scale: float,
                    interpret: bool | None = None) -> Array:
    interp = _interpret_default() if interpret is None else interpret
    return block_gather_pallas(
        x_blocks.astype(jnp.float32), block_idx.astype(jnp.int32),
        k_blocks=int(block_idx.shape[0]), scale=float(scale),
        interpret=interp)


@_scoped("block_scatter")
def block_scatter_op(base_blocks: Array, vals: Array, block_idx: Array,
                     interpret: bool | None = None) -> Array:
    interp = _interpret_default() if interpret is None else interpret
    return block_scatter_pallas(
        base_blocks.astype(jnp.float32), vals.astype(jnp.float32),
        block_idx.astype(jnp.int32), interpret=interp)
