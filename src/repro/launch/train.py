"""Production training entrypoint:

    python -m repro.launch.train --arch granite-3-2b --shape train_4k \
        [--multi-pod] [--steps N] [--smoke]

On real TPU hardware this builds the production mesh and runs the full
config; ``--smoke`` (the CPU path) shrinks to the reduced config on a
host mesh — same code path end to end.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--p-a", type=float, default=0.5)
    ap.add_argument("--ratio", type=float, default=1 / 64)
    ap.add_argument("--aggregation", default="sparse_allgather")
    ap.add_argument("--variant", default="mvr",
                    choices=["mvr", "gradient", "page", "finite_mvr"],
                    help="k_i rule (core/variants.py); gradient and "
                         "finite_mvr are fixed-batch finite-sum settings")
    ap.add_argument("--p-page", type=float, default=1 / 8,
                    help="page variant: full-pass probability")
    ap.add_argument("--page-mini-batch", type=int, default=1,
                    help="page variant: per-node minibatch examples")
    ap.add_argument("--component-batch", type=int, default=1,
                    help="finite_mvr variant: components (examples) "
                         "sampled per node per round")
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused Pallas update path (DESIGN.md §6)")
    ap.add_argument("--server", choices=["paper", "adamw"], default="paper")
    ap.add_argument("--gamma", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log", default=None)
    from repro.obs import add_cli_flags
    add_cli_flags(ap)
    args = ap.parse_args()

    if args.smoke and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro.compat import use_mesh
    from repro.obs import start_run
    from repro.core.sharded import ShardedDashaConfig
    from repro.data.synthetic import DataConfig, make_batch
    from repro.launch.mesh import (data_axes_of, make_host_mesh,
                                   make_production_mesh, num_nodes)
    from repro.models import Model, get_config, get_smoke_config
    from repro.models.registry import INPUT_SHAPES
    from repro.training.loop import train
    from repro.training.metrics import MetricsLogger
    from repro.training.optim import adamw_server, paper_server
    from repro.training.trainer import Trainer, TrainerConfig

    if args.smoke:
        mesh = make_host_mesh(data=4, model=2)
        cfg = get_smoke_config(args.arch).with_overrides(dtype="float32")
        seq, gbatch = 64, 8
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
        shp = INPUT_SHAPES[args.shape]
        seq, gbatch = shp.seq_len, shp.global_batch

    model = Model(cfg)
    axes = data_axes_of(mesh)
    n = num_nodes(mesh)
    omega = 1.0 / args.ratio - 1.0
    dcfg = ShardedDashaConfig(
        gamma=args.gamma,
        a=args.p_a / (2 * omega + 1),
        b=args.p_a / (2 - args.p_a),
        p_a=args.p_a, sampler="independent",
        compression_ratio=args.ratio,
        aggregation=args.aggregation, data_axes=axes,
        variant=args.variant, p_page=args.p_page,
        use_pallas=args.use_pallas)
    server = (paper_server(args.gamma) if args.server == "paper"
              else adamw_server(lr=3e-4))
    trainer = Trainer(model, mesh, TrainerConfig(
        dasha=dcfg, server=server,
        page_mini_batch=args.page_mini_batch,
        num_components=(gbatch // n if args.variant == "finite_mvr"
                        else None),
        component_batch=args.component_batch))
    state = trainer.init(jax.random.key(0))

    data = DataConfig(seq_len=seq, global_batch=gbatch, num_nodes=n,
                      vocab_size=cfg.vocab_size)

    def batches():
        # The gradient and finite_mvr variants (Algs. 2/4) are finite-
        # sum settings: each node's dataset is FIXED across rounds
        # (this is also what makes the gradient old-grad cache exact,
        # and what makes the finite_mvr h_ij trackers track anything).
        # Streaming fresh batches would break the correlated gn/go pair;
        # use mvr/page for stochastic data.
        if args.variant in ("gradient", "finite_mvr"):
            fixed = make_batch(cfg, data, 0, dtype=cfg.dtype)
            while True:
                yield fixed
        i = 0
        while True:
            yield make_batch(cfg, data, i, dtype=cfg.dtype)
            i += 1

    obsrun = start_run(trace_out=args.trace_out,
                       metrics_out=args.metrics_out,
                       meta={"cli": "train", "arch": args.arch,
                             "variant": args.variant})
    from repro.obs import profiler_trace
    with use_mesh(mesh), profiler_trace(args.profile_dir):
        train(trainer, state, batches(), num_steps=args.steps,
              logger=MetricsLogger(args.log, print_every=10),
              checkpoint_dir=args.ckpt,
              checkpoint_every=50 if args.ckpt else 0)
    obsrun.finish()


if __name__ == "__main__":
    main()
