"""Hierarchical-fleet training entrypoint (DESIGN.md §12):

    PYTHONPATH=src python -m repro.launch.fleet_train \
        --n 100000 --d 64 --edges 16 --mid 4 --s 4 \
        --edge-buffer 2 --root-buffer 2 --store memmap \
        [--workload streamed|dense] [--rounds N] [--log out.jsonl]

Runs :class:`repro.fl.HierarchicalFleet` — clients report to edge
aggregators, edges pre-reduce and forward (optionally through a middle
tier) to the root — over either the fleet-scale streamed workload
(per-client synthetic data regenerated on demand; out-of-core client
store, so ``--n 1000000`` is fine) or the reference dense-problem
workload (all four DASHA-PP variants, the parity anchor).  Logs
per-root-step metrics (virtual wall-clock, loss, ||∇f||², staleness,
total and root-hop wire bits) through the training MetricsLogger.
``--root-buffer 0`` / ``--edge-buffer 0`` mean barrier (flush when the
subtree is quiet).
"""
import argparse
import math


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="streamed",
                    choices=["streamed", "dense"])
    ap.add_argument("--variant", default="gradient",
                    choices=["mvr", "gradient", "page", "finite_mvr"],
                    help="dense workload only (streamed is Alg. 2)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--n", type=int, default=10000, help="clients")
    ap.add_argument("--m", type=int, default=2, help="examples/client")
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--edges", type=int, default=8,
                    help="edge aggregators (tier 0)")
    ap.add_argument("--mid", type=int, default=0,
                    help="middle-tier aggregators (0 = depth-1 tree; "
                         "--edges 0 would be flat, use --depth0)")
    ap.add_argument("--depth0", action="store_true",
                    help="flat topology: clients feed the root directly")
    ap.add_argument("--s", type=int, default=4,
                    help="per-edge s-nice cohort size")
    ap.add_argument("--edge-buffer", type=int, default=2,
                    help="per-edge FedBuff K; 0 = barrier")
    ap.add_argument("--mid-buffer", type=int, default=0,
                    help="middle-tier FedBuff K; 0 = barrier")
    ap.add_argument("--root-buffer", type=int, default=2,
                    help="root first-K messages per step; 0 = barrier")
    ap.add_argument("--staleness-exponent", type=float, default=0.5)
    ap.add_argument("--staleness-policy", default="power",
                    choices=["power", "adaptive"])
    ap.add_argument("--max-staleness", type=int, default=None)
    ap.add_argument("--tier-max-staleness", type=int, default=None,
                    help="discard-at-edge bound (root bound is "
                         "--max-staleness)")
    ap.add_argument("--latency", default="lognormal",
                    choices=["constant", "lognormal"])
    ap.add_argument("--sigma", type=float, default=0.8)
    ap.add_argument("--bandwidth", type=float, default=0.0,
                    help="uplink bits/s (0 = instant network)")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="mid-flight client dropout probability")
    ap.add_argument("--store", default="ram", choices=["ram", "memmap"])
    ap.add_argument("--store-dir", default=None)
    ap.add_argument("--ratio", type=float, default=0.05,
                    help="K/d of the RandK uplink compressor")
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--a", type=float, default=0.1)
    ap.add_argument("--b", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default=None)
    from repro.obs import add_cli_flags
    add_cli_flags(ap)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.core import RandK
    from repro.core.participation import EdgeSNice
    from repro.fl import (DenseProblemWorkload, FleetConfig,
                          HierarchicalFleet, StreamedGradientWorkload,
                          TierConfig, edge_partition, make_latency)
    from repro.training.metrics import MetricsLogger

    k = max(1, math.ceil(args.ratio * args.d))
    comp = RandK(k=k)
    bounds = tuple(int(b)
                   for b in edge_partition(args.n, args.edges))
    samp = EdgeSNice(bounds=bounds, s=args.s)

    if args.workload == "streamed":
        wl = StreamedGradientWorkload(
            sampler=samp, d=args.d, compressor=comp, gamma=args.gamma,
            a=args.a, b=args.b, m_per_client=args.m,
            data_seed=args.seed)
    else:
        from repro.core import (LogisticSigmoidProblem,
                                make_synthetic_classification)
        from repro.core.dasha_pp import DashaPPConfig
        feats, y = make_synthetic_classification(
            jax.random.key(args.seed), args.n, args.m, args.d)
        wl = DenseProblemWorkload(
            LogisticSigmoidProblem(feats, y), comp, samp,
            DashaPPConfig(args.variant, gamma=args.gamma, a=args.a,
                          b=args.b))

    tiers = ()
    if not args.depth0:
        tiers += (TierConfig(aggregators=args.edges,
                             buffer_size=args.edge_buffer or None,
                             max_staleness=args.tier_max_staleness),)
        if args.mid:
            tiers += (TierConfig(aggregators=args.mid,
                                 buffer_size=args.mid_buffer or None),)
    fcfg = FleetConfig(tiers=tiers,
                       buffer_size=args.root_buffer or None,
                       staleness_policy=args.staleness_policy,
                       staleness_exponent=args.staleness_exponent,
                       max_staleness=args.max_staleness)
    lat_kw = dict(bandwidth_bps=args.bandwidth or None,
                  dropout=args.dropout, seed=args.seed)
    if args.latency == "lognormal":
        lat_kw.update(sigma=args.sigma, client_sigma=args.sigma)
    latency = make_latency(args.latency, **lat_kw)

    from repro.obs import profiler_trace, start_run
    obsrun = start_run(trace_out=args.trace_out,
                       metrics_out=args.metrics_out,
                       meta={"cli": "fleet_train",
                             "depth": fcfg.depth})
    fleet = HierarchicalFleet(wl, fcfg, latency,
                              store_backend=args.store,
                              store_dir=args.store_dir)
    with profiler_trace(args.profile_dir):
        fs, res = fleet.run(jax.random.key(args.seed + 1),
                            np.zeros(args.d, np.float32), args.rounds)

    logger = MetricsLogger(args.log, name="fleet_train",
                           print_every=max(1, len(res.time) // 20))
    for i in range(len(res.time)):
        logger.log(i, t_virtual=res.time[i], loss=res.loss[i],
                   grad_norm_sq=res.grad_norm_sq[i],
                   committed=int(res.committed[i]),
                   staleness_mean=res.staleness_mean[i],
                   mbits=res.bits_cum[i] / 1e6,
                   root_mbits=res.root_bits_cum[i] / 1e6)
    logger.close()
    tier_mb = "/".join(f"{b / 1e6:.2f}" for b in res.tier_bits)
    print(f"\nfinal ||grad f||^2 = {res.grad_norm_sq[-1]:.3e}  "
          f"t_virtual = {res.total_time:.1f}s  "
          f"depth = {fcfg.depth}  store = {fs.store.backend} "
          f"({fs.store.nbytes / 2**20:.1f} MiB)\n"
          f"committed = {int(res.committed.sum())}  "
          f"dropped = {res.dropped}  "
          f"discarded = {res.discarded_stale}  "
          f"forced flushes = {res.forced_flushes}\n"
          f"per-hop Mbits client->root = {tier_mb}  "
          f"staleness hist = {res.staleness_hist}")
    obsrun.finish()
    fs.store.close()


if __name__ == "__main__":
    main()
