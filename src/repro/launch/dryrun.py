"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs (no allocation), and
record memory / cost / collective statistics for the roofline.

MUST set the placeholder device count before ANY other import — jax
locks the device count on first init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.sharded import ShardedDashaConfig
from repro.launch.mesh import data_axes_of, make_production_mesh, num_nodes
from repro.launch.specs import (decode_state_specs, prefill_input_specs,
                                to_shardings, train_input_specs)
from repro.models import Model, count_params, param_specs_like
from repro.models.registry import (ARCH_IDS, INPUT_SHAPES, get_config,
                                   pair_supported)
from repro.training.optim import paper_server
from repro.training.trainer import Trainer, TrainerConfig

# Architectures whose DASHA control variates exceed single-pod HBM with
# node = data-slice; on the multi-pod mesh they use node = pod
# ("pod-as-client", DESIGN.md §5) so variates shard over (data, model).
BIG_ARCHS = {"dbrx-132b", "qwen1.5-110b", "llama3-405b", "yi-34b"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective payload bytes by op type, from the optimized
    (SPMD-partitioned) HLO: for each collective instruction we count its
    output shape bytes (ring all-gather/reduce-scatter move ~(n-1)/n of
    this per link; we report the raw payload and apply link factors in
    the roofline)."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        matched = None
        for c in _COLLECTIVES:
            # "<name> = <shape> <op>(" — async ops appear as "<op>-start(";
            # "-done" carries no payload of its own.
            for suffix in ("(", "-start("):
                i = s.find(" " + c + suffix, eq)
                if i > 0:
                    matched = (c, s[eq + 3:i])
                    break
            if matched:
                break
        if matched:
            c, shape_txt = matched
            out[c] += _shape_bytes(shape_txt)
            out["count"] += 1
    return out


def _dasha_config_for(arch_id: str, mesh, n_params: int) -> ShardedDashaConfig:
    """Baseline (paper-faithful) DASHA-PP-MVR configuration per DESIGN.md:
    independent participation p_a = 0.5, BlockRandK with K/D = 1/64
    (omega = 63), theory momenta a = p_a/(2w+1), b = p_a/(2-p_a)."""
    axes = data_axes_of(mesh)
    if arch_id in BIG_ARCHS and "pod" in mesh.shape:
        axes = ("pod",)           # pod-as-client for the biggest models
    p_a = 0.5
    omega = 63.0
    return ShardedDashaConfig(
        gamma=1e-3,
        a=p_a / (2 * omega + 1),
        b=p_a / (2 - p_a),
        p_a=p_a,
        sampler="independent",
        compression_ratio=1.0 / 64,
        block_size=128,
        aggregation="sparse_allgather",
        data_axes=axes,
    )


def lower_pair(arch_id: str, shape_name: str, *, multi_pod: bool,
               dasha_overrides: Optional[dict] = None,
               arch_overrides: Optional[dict] = None,
               fsdp: bool = True) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch_id)
    if shape.name == "long_500k":
        cfg = cfg.for_long_context()
    if arch_overrides:
        cfg = cfg.with_overrides(**arch_overrides)
    ok, reason = pair_supported(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    params_shape = jax.eval_shape(model.init_params, jax.random.key(0))
    n_params = count_params(params_shape)
    rec["params"] = n_params
    pspecs = param_specs_like(params_shape, mesh,
                              fsdp_axis="data" if fsdp else None)

    t0 = time.time()
    if shape.kind == "train":
        dcfg = _dasha_config_for(arch_id, mesh, n_params)
        if dasha_overrides:
            import dataclasses as _dc
            dcfg = _dc.replace(dcfg, **dasha_overrides)
        trainer = Trainer(model, mesh, TrainerConfig(
            dasha=dcfg, server=paper_server(gamma=dcfg.gamma),
            fsdp=fsdp))
        batch_sds, _ = train_input_specs(cfg, shape, mesh)
        state_sds = jax.eval_shape(trainer._init_abstract, jax.random.key(0))
        key_sds = jax.eval_shape(lambda: jax.random.key(0))
        step_jit = trainer.jit_train_step(batch_sds)
        lowered = step_jit.lower(state_sds, batch_sds, key_sds)
        rec["dasha"] = {
            "data_axes": list(dcfg.data_axes),
            "variant": dcfg.variant,
            "p_a": dcfg.p_a,
            "ratio": dcfg.compression_ratio,
            "aggregation": dcfg.aggregation,
            "use_pallas": dcfg.use_pallas,
            "uplink_bits_per_node_round":
                trainer.engine.uplink_bits_per_round(n_params),
        }
    elif shape.kind == "prefill":
        batch_sds, bspecs = prefill_input_specs(cfg, shape, mesh)
        # production prefill: last-token logits + per-layer caches out
        fwd = jax.jit(
            model.prefill,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(bspecs, mesh)),
        )
        lowered = fwd.lower(params_shape, batch_sds)
    else:  # decode
        B = shape.global_batch
        state_shape = jax.eval_shape(
            lambda: model.init_decode_state(B, shape.seq_len))
        sspecs = decode_state_specs(state_shape, mesh,
                                    num_layers=cfg.num_layers)
        tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        n = num_nodes(mesh)
        tspec = jax.tree.map(
            lambda _: jax.sharding.PartitionSpec(
                data_axes_of(mesh)[0] if len(data_axes_of(mesh)) == 1
                else tuple(data_axes_of(mesh)), None)
            if B % n == 0 else jax.sharding.PartitionSpec(None, None),
            tok_sds)
        step = jax.jit(
            model.serve_step,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(tspec, mesh),
                          to_shardings(sspecs, mesh)),
            # donate the decode state: in-place cache update instead of a
            # full cache copy per token (§Perf iteration Q2)
            donate_argnums=(2,),
        )
        lowered = step.lower(params_shape, tok_sds, state_shape)
    rec["lower_s"] = round(time.time() - t0, 2)

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    rec["flops_per_device"] = float(cost.get("flops", 0.0))
    rec["bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    rec["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single input shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--dasha-ratio", type=float, default=None)
    ap.add_argument("--dasha-aggregation", default=None)
    ap.add_argument("--dasha-variant", default=None,
                    choices=["mvr", "gradient", "page"])
    ap.add_argument("--dasha-pallas", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    overrides = {}
    if args.dasha_ratio is not None:
        overrides["compression_ratio"] = args.dasha_ratio
    if args.dasha_aggregation:
        overrides["aggregation"] = args.dasha_aggregation
    if args.dasha_variant:
        overrides["variant"] = args.dasha_variant
        if args.dasha_variant == "page":
            overrides["p_page"] = 1 / 8
    if args.dasha_pallas:
        overrides["use_pallas"] = True

    n_fail = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                name = f"{args.tag}__{arch}__{shp}__{mesh_tag}.json"
                path = os.path.join(args.out, name)
                print(f"=== {arch} x {shp} x {mesh_tag} ===", flush=True)
                try:
                    rec = lower_pair(arch, shp, multi_pod=mp,
                                     dasha_overrides=overrides or None)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shp, "mesh": mesh_tag,
                           "status": "error", "error": repr(e)}
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec.get("status")
                if status == "ok":
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll={rec['collectives']}", flush=True)
                    mem = rec["memory"]
                    print(f"  memory/device: args={mem['argument_bytes']/2**30:.2f}GiB "
                          f"temp={mem['temp_bytes']/2**30:.2f}GiB", flush=True)
                elif status == "skipped":
                    print(f"  skipped: {rec['reason']}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} pair(s) failed")


if __name__ == "__main__":
    main()
