"""Serving entrypoint:

    python -m repro.launch.serve --arch granite-3-2b [--smoke] \
        [--batch 8] [--max-seq 256] [--requests 16]

``--smoke`` (CPU) uses the reduced config on a host mesh; on TPU the
production mesh and full config are used, with decode-state shardings
from launch/specs.decode_state_specs.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.smoke and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np
    from repro.models import Model, get_config, get_smoke_config
    from repro.serving.decode import DecodeServer, Request

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    server = DecodeServer(model, params, batch_size=args.batch,
                          max_seq_len=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    import time
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    tot = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {tot} tokens, "
          f"{tot/dt:.1f} tok/s (batch={args.batch})")


if __name__ == "__main__":
    main()
