"""Serving entrypoint:

    python -m repro.launch.serve --arch granite-3-2b \
        [--engine {dense,paged}] [--smoke/--no-smoke] [--batch 8] \
        [--max-seq 256] [--requests 16] [--page-size 16] [--pages N]

``--smoke`` (the default; disable with ``--no-smoke``) uses the reduced
config on a forced host platform.  ``--no-smoke`` routes through the
production path: the 16x16 v5e mesh from launch/mesh.py, params
initialized directly into their param_specs_like shardings, and decode
state placed via launch/specs (``decode_state_specs`` for the dense
engine, ``paged_state_specs`` for the page pool — pages replicate over
'data', heads shard over 'model').

``--engine paged`` serves through the PagedEngine (chunked/bucketed
prefill + continuous batching + preemption, DESIGN.md §11); ``dense``
keeps the ring-cache DecodeServer parity anchor.
``--prefill-chunk-tokens`` and ``--bucket-sizes`` expose the chunked-
prefill budget and the bulk-prefill prompt-length buckets.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced config on the host platform (default); "
                         "--no-smoke uses the production mesh + full config")
    ap.add_argument("--engine", choices=("dense", "paged"), default="dense")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged engine: tokens per page")
    ap.add_argument("--pages", type=int, default=0,
                    help="paged engine: pool pages (0 = dense-equivalent)")
    ap.add_argument("--no-kernel", action="store_true",
                    help="paged engine: force the jnp gather read")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=None,
                    help="paged engine: fold prompt prefill into the fused "
                         "decode pass, this many prompt tokens per pass "
                         "(0 = bulk prefill; default: auto, 16 for "
                         "attention-only archs)")
    ap.add_argument("--bucket-sizes", type=str, default=None,
                    help="paged engine: comma-separated prompt-length "
                         "buckets for bulk prefill, e.g. 8,16,32 "
                         "('' = exact-length, one compile per length; "
                         "default: auto powers of two)")
    from repro.obs import add_cli_flags
    add_cli_flags(ap)
    args = ap.parse_args()

    if args.smoke and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np
    from repro.models import Model, get_config, get_smoke_config
    from repro.obs import start_run
    from repro.serving import DecodeServer, PagedEngine, Request

    obsrun = start_run(trace_out=args.trace_out,
                       metrics_out=args.metrics_out,
                       meta={"cli": "serve", "engine": args.engine,
                             "arch": args.arch})

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    model = Model(cfg)

    if args.smoke:
        params = model.init_params(jax.random.key(0))
    else:
        # production path: params born sharded on the v5e pod mesh
        from repro.launch.mesh import make_production_mesh
        from repro.launch.specs import (decode_state_specs,
                                        paged_state_specs, to_shardings)
        from repro.models import param_specs_like
        mesh = make_production_mesh()
        shapes = jax.eval_shape(model.init_params, jax.random.key(0))
        shardings = to_shardings(param_specs_like(shapes, mesh), mesh)
        params = jax.jit(model.init_params,
                         out_shardings=shardings)(jax.random.key(0))

    if args.engine == "dense":
        server = DecodeServer(model, params, batch_size=args.batch,
                              max_seq_len=args.max_seq)
    else:
        buckets = None
        if args.bucket_sizes is not None:
            buckets = [int(b) for b in args.bucket_sizes.split(",") if b]
        server = PagedEngine(model, params, batch_size=args.batch,
                             max_seq_len=args.max_seq,
                             page_size=args.page_size,
                             num_pages=args.pages or None,
                             use_kernel=not args.no_kernel and
                             jax.default_backend() == "tpu",
                             prefill_chunk_tokens=args.prefill_chunk_tokens,
                             bucket_sizes=buckets)

    if not args.smoke:
        # place the decode state on the mesh; the jitted serve steps
        # keep the placement through every subsequent step
        if args.engine == "dense":
            server.place_state(to_shardings(decode_state_specs(
                server.state, mesh, num_layers=cfg.num_layers), mesh))
        else:
            server.place_caches(to_shardings(paged_state_specs(
                server._caches, mesh, num_layers=cfg.num_layers), mesh))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    import time

    from repro.obs import profiler_trace
    t0 = time.time()
    with profiler_trace(args.profile_dir):
        done = server.run(reqs)
    dt = time.time() - t0
    tot = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {tot} tokens, "
          f"{tot/dt:.1f} tok/s (engine={args.engine}, batch={args.batch})")
    if args.engine == "paged":
        m = server.metrics()
        print(f"  prefill_forwards={m['prefill_forwards']} "
              f"decode_steps={m['decode_steps']} "
              f"pool_util={m['pool_utilization']:.2f} "
              f"cache_hbm_bytes={m['cache_hbm_bytes']}")
        if m["latency_p50"] is not None:
            print(f"  latency p50={m['latency_p50']:.0f} "
                  f"p95={m['latency_p95']:.0f} serve-passes; "
                  f"ttft p50={m['ttft_p50']:.0f} p95={m['ttft_p95']:.0f}")
    obsrun.finish()


if __name__ == "__main__":
    main()
