"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
shardable, zero-allocation input descriptions — plus spec builders for
params / decode caches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes_of, num_nodes
from repro.models.common import ArchConfig
from repro.models.registry import InputShape

Array = jax.Array


def _lead(data_axes) -> Any:
    return data_axes[0] if len(data_axes) == 1 else tuple(data_axes)


def train_input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh
                      ) -> Tuple[Dict[str, jax.ShapeDtypeStruct],
                                 Dict[str, P]]:
    """Node-major training batch {tokens/embeds/targets}: leaves
    (n_nodes, per_node, ...)."""
    data_axes = data_axes_of(mesh)
    n = num_nodes(mesh)
    if shape.global_batch % n:
        raise ValueError(f"batch {shape.global_batch} % nodes {n} != 0")
    b = shape.global_batch // n
    t = shape.seq_len
    dt = cfg.param_dtype
    lead = _lead(data_axes)
    sds, specs = {}, {}
    if cfg.frontend == "audio":
        sds["embeds"] = jax.ShapeDtypeStruct((n, b, t, cfg.d_model), dt)
        sds["targets"] = jax.ShapeDtypeStruct((n, b, t), jnp.int32)
        specs["embeds"] = P(lead, None, None, None)
        specs["targets"] = P(lead, None, None)
    elif cfg.frontend == "vision":
        sds["embeds"] = jax.ShapeDtypeStruct(
            (n, b, cfg.frontend_tokens, cfg.d_model), dt)
        sds["tokens"] = jax.ShapeDtypeStruct((n, b, t), jnp.int32)
        specs["embeds"] = P(lead, None, None, None)
        specs["tokens"] = P(lead, None, None)
    else:
        sds["tokens"] = jax.ShapeDtypeStruct((n, b, t), jnp.int32)
        specs["tokens"] = P(lead, None, None)
    return sds, specs


def prefill_input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh
                        ) -> Tuple[Dict[str, jax.ShapeDtypeStruct],
                                   Dict[str, P]]:
    """Inference prefill batch: global (B, T) sharded over the data axes."""
    data_axes = data_axes_of(mesh)
    lead = _lead(data_axes)
    B, t = shape.global_batch, shape.seq_len
    n = num_nodes(mesh)
    blead = lead if B % n == 0 else None
    dt = cfg.param_dtype
    sds, specs = {}, {}
    if cfg.frontend == "audio":
        sds["embeds"] = jax.ShapeDtypeStruct((B, t, cfg.d_model), dt)
        sds["targets"] = jax.ShapeDtypeStruct((B, t), jnp.int32)
        specs["embeds"] = P(blead, None, None)
        specs["targets"] = P(blead, None)
    elif cfg.frontend == "vision":
        sds["embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), dt)
        sds["tokens"] = jax.ShapeDtypeStruct((B, t), jnp.int32)
        specs["embeds"] = P(blead, None, None)
        specs["tokens"] = P(blead, None)
    else:
        sds["tokens"] = jax.ShapeDtypeStruct((B, t), jnp.int32)
        specs["tokens"] = P(blead, None)
    return sds, specs


def decode_state_specs(state_shapes: Any, mesh: Mesh,
                       num_layers: Optional[int] = None) -> Any:
    """Heuristic sharding for DecodeState leaves.

    Leaves look like (L, B, S, kvH, hd) (scanned), (B, S, ...) (unrolled),
    or SSM states (B, di, N) / (B, H, hd, hd).  We shard the batch dim
    over 'data' when divisible and the largest remaining dim over 'model'
    when divisible; scalars replicate.

    ``num_layers`` guards the stacked-layer dim: a leading dim equal to
    the layer count is NEVER treated as batch.  (Perf iteration Q1,
    EXPERIMENTS.md §Perf: qwen's 80-layer cache had dim0 % 16 == 0 and
    was mis-sharded over 'data', forcing per-layer cache regathers —
    a 100x collective-term regression the roofline exposed.)
    """
    data_axes = data_axes_of(mesh)
    lead = _lead(data_axes)
    n_data = num_nodes(mesh)
    n_model = mesh.shape["model"]

    def spec_of(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        dims = [None] * leaf.ndim
        batch_dim = None
        for cand in (0, 1):
            if cand >= leaf.ndim:
                continue
            if cand == 0 and num_layers is not None \
                    and leaf.ndim >= 3 and leaf.shape[0] == num_layers:
                continue   # stacked-layer dim, not batch
            if leaf.shape[cand] % n_data == 0 and leaf.shape[cand] >= n_data:
                batch_dim = cand
                break
        if batch_dim is not None:
            dims[batch_dim] = lead
        rest = [i for i in range(leaf.ndim) if i != batch_dim]
        rest.sort(key=lambda i: -leaf.shape[i])
        for i in rest:
            if leaf.shape[i] % n_model == 0 and leaf.shape[i] >= n_model:
                dims[i] = "model"
                break
        return P(*dims)

    return jax.tree.map(spec_of, state_shapes)


def paged_state_specs(state_shapes: Any, mesh: Mesh,
                      num_layers: Optional[int] = None) -> Any:
    """Sharding for PagedDecodeState trees (DESIGN.md §11).  Walks by
    CACHE TYPE, not shape heuristics: pool leaves (KVCache (…, NP, P,
    kvH, hd), MLACache (…, NP, P, r)) replicate their page dims over
    'data' (any slot reads any page — sharding pages over data would
    all-gather the pool every step) and shard only the trailing
    feature dim(s) over 'model'; everything else (recurrent SSM
    states, the (B, M) table, (B,) lens) takes the dense
    :func:`decode_state_specs` batch-over-'data' rule."""
    from repro.models.layers import KVCache
    from repro.models.model import map_cache_tree
    n_model = mesh.shape["model"]

    def pool_spec(leaf, feature_dims: int):
        dims = [None] * leaf.ndim
        cands = sorted(range(leaf.ndim - feature_dims, leaf.ndim),
                       key=lambda i: -leaf.shape[i])
        for i in cands:
            if leaf.shape[i] % n_model == 0 and leaf.shape[i] >= n_model:
                dims[i] = "model"
                break
        return P(*dims)

    def attn_spec(c):
        # KVCache leaves end in (kvH, hd); MLACache latent/rope in one
        # feature dim
        fd = 2 if isinstance(c, KVCache) else 1
        return type(c)(*(pool_spec(leaf, fd) for leaf in c))

    return map_cache_tree(
        state_shapes, on_attention=attn_spec,
        on_leaf=lambda c: decode_state_specs(c, mesh,
                                             num_layers=num_layers))


def to_shardings(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
