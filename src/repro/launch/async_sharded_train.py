"""Gang-scheduled async training entrypoint for the sharded LM trainer
(DESIGN.md §10):

    PYTHONPATH=src python -m repro.launch.async_sharded_train \
        --smoke --arch granite-3-2b --variant mvr --rounds 30 \
        --latency lognormal --sigma 1.0 --buffer 2 \
        [--staleness-policy power|adaptive] [--availability-rate 0.02]

Runs :class:`repro.fl.CohortScheduler` over the production
``Trainer``/``ShardedDasha`` stack: each round gang-schedules one SPMD
cohort, buffers it by virtual arrival time, and commits the first-K
cohorts with staleness weights.  ``--buffer 0`` waits for every
outstanding cohort — the barrier baseline the bench compares against.
``--smoke`` shrinks to the reduced config on an 8-device host mesh
(same code path end to end).  The final line is machine-readable:

    RESULT t_virtual=<s> loss=<f> grad_norm=<f> commits=<n> ...
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--variant", default="mvr",
                    choices=["mvr", "gradient", "page", "finite_mvr"])
    ap.add_argument("--p-a", type=float, default=0.5)
    ap.add_argument("--ratio", type=float, default=1 / 16)
    ap.add_argument("--gamma", type=float, default=1e-3)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--buffer", type=int, default=2,
                    help="cohort flight capacity: up to K cohorts ride "
                         "concurrently, the earliest arrival beyond that "
                         "commits; 0 (or 1) = barrier")
    ap.add_argument("--staleness-policy", default="power",
                    choices=["power", "adaptive"])
    ap.add_argument("--staleness-exponent", type=float, default=0.5)
    ap.add_argument("--max-staleness", type=int, default=None)
    ap.add_argument("--latency", default="lognormal",
                    choices=["constant", "lognormal"])
    ap.add_argument("--sigma", type=float, default=1.0,
                    help="lognormal jitter + persistent fleet spread")
    ap.add_argument("--bandwidth", type=float, default=1e6,
                    help="uplink bits/s (0 = instant network)")
    ap.add_argument("--availability-rate", type=float, default=0.0,
                    help="Poisson outage rate per client per virtual "
                         "second (0 = always available)")
    ap.add_argument("--availability-off-mean", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default=None)
    from repro.obs import add_cli_flags
    add_cli_flags(ap)
    args = ap.parse_args()

    if args.smoke and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np
    from repro.compat import use_mesh
    from repro.core.sharded import ShardedDashaConfig
    from repro.data.synthetic import DataConfig, make_batch
    from repro.fl import (CohortConfig, PoissonAvailability, make_latency,
                          train_async)
    from repro.launch.mesh import (data_axes_of, make_host_mesh,
                                   make_production_mesh, num_nodes)
    from repro.models import Model, get_config, get_smoke_config
    from repro.models.registry import INPUT_SHAPES
    from repro.obs import start_run
    from repro.training.metrics import MetricsLogger
    from repro.training.optim import paper_server
    from repro.training.trainer import Trainer, TrainerConfig

    if args.smoke:
        mesh = make_host_mesh(data=4, model=2)
        cfg = get_smoke_config(args.arch).with_overrides(dtype="float32")
        seq, gbatch = 64, 8
    else:
        mesh = make_production_mesh()
        cfg = get_config(args.arch)
        shp = INPUT_SHAPES["train_4k"]
        seq, gbatch = shp.seq_len, shp.global_batch

    model = Model(cfg)
    axes = data_axes_of(mesh)
    n = num_nodes(mesh)
    omega = 1.0 / args.ratio - 1.0
    dcfg = ShardedDashaConfig(
        gamma=args.gamma,
        a=args.p_a / (2 * omega + 1),
        b=args.p_a / (2 - args.p_a),
        p_a=args.p_a, sampler="independent",
        compression_ratio=args.ratio, data_axes=axes,
        variant=args.variant, use_pallas=args.use_pallas)
    trainer = Trainer(model, mesh, TrainerConfig(
        dasha=dcfg, server=paper_server(args.gamma),
        num_components=(gbatch // n if args.variant == "finite_mvr"
                        else None)))
    state = trainer.init(jax.random.key(0))

    data = DataConfig(seq_len=seq, global_batch=gbatch, num_nodes=n,
                      vocab_size=cfg.vocab_size)

    def batches():
        if args.variant in ("gradient", "finite_mvr"):
            fixed = make_batch(cfg, data, 0, dtype=cfg.dtype)
            while True:
                yield fixed
        i = 0
        while True:
            yield make_batch(cfg, data, i, dtype=cfg.dtype)
            i += 1

    lat_kw = dict(bandwidth_bps=args.bandwidth or None, seed=args.seed)
    if args.latency == "lognormal":
        lat_kw.update(sigma=args.sigma, client_sigma=args.sigma)
    latency = make_latency(args.latency, **lat_kw)
    avail = None
    if args.availability_rate > 0:
        avail = PoissonAvailability(rate=args.availability_rate,
                                    off_mean=args.availability_off_mean,
                                    seed=args.seed)
    ccfg = CohortConfig(buffer_cohorts=args.buffer or None,
                        staleness_policy=args.staleness_policy,
                        staleness_exponent=args.staleness_exponent,
                        max_staleness=args.max_staleness,
                        seed=args.seed)

    obsrun = start_run(trace_out=args.trace_out,
                       metrics_out=args.metrics_out,
                       meta={"cli": "async_sharded_train",
                             "arch": args.arch})
    logger = MetricsLogger(args.log, name="async_sharded_train",
                           print_every=max(1, args.rounds // 10))
    from repro.obs import profiler_trace
    with use_mesh(mesh), profiler_trace(args.profile_dir):
        state, res = train_async(trainer, state, batches(), args.rounds,
                                 latency, config=ccfg, availability=avail,
                                 logger=logger,
                                 log_every=max(1, args.rounds // 10))
    logger.close()
    print(f"\nstaleness hist = {res.staleness_hist}  "
          f"skipped busy/offline = {int(res.skipped_busy.sum())}/"
          f"{int(res.skipped_offline.sum())}  "
          f"discarded = {res.discarded_stale}")
    print(f"RESULT t_virtual={res.total_time:.3f} "
          f"loss={res.loss[-1]:.6f} "
          f"grad_norm={res.grad_norm[-1]:.6f} "
          f"commits={int(res.committed.sum())} "
          f"clients={int(res.committed_clients.sum())} "
          f"mbits={res.bits_cum[-1] / 1e6:.3f} "
          f"s_mean={float(np.sum(res.staleness_mean * res.committed) / max(1, res.committed.sum())):.3f}")
    obsrun.finish()


if __name__ == "__main__":
    main()
