"""Async federated training entrypoint (DESIGN.md §9):

    PYTHONPATH=src python -m repro.launch.async_train \
        --variant mvr --latency lognormal --sigma 0.8 --buffer 5 \
        [--compressor randk|topk|dithering|identity] [--rounds N]

Runs :class:`repro.fl.AsyncDashaServer` on the paper's synthetic
federated problem with a heterogeneous virtual-time fleet and logs
per-server-step metrics (virtual wall-clock, loss, ||∇f||², staleness,
bits on wire) through the training MetricsLogger (JSONL with --log).
``--buffer 0`` means full barrier — the sync-equivalent baseline.
"""
import argparse
import math


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="mvr",
                    choices=["mvr", "gradient", "page", "finite_mvr"])
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--n", type=int, default=50, help="clients")
    ap.add_argument("--m", type=int, default=24, help="examples/client")
    ap.add_argument("--d", type=int, default=120)
    ap.add_argument("--cohort", type=int, default=10,
                    help="s-nice cohort size per round")
    ap.add_argument("--buffer", type=int, default=5,
                    help="first-K arrivals per server step; 0 = barrier")
    ap.add_argument("--staleness-exponent", type=float, default=0.5)
    ap.add_argument("--max-staleness", type=int, default=None)
    ap.add_argument("--latency", default="lognormal",
                    choices=["constant", "lognormal"])
    ap.add_argument("--sigma", type=float, default=0.8,
                    help="lognormal jitter + fleet spread")
    ap.add_argument("--bandwidth", type=float, default=2e5,
                    help="uplink bits/s (0 = instant network)")
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--compressor", default="randk",
                    choices=["randk", "topk", "dithering", "identity"])
    ap.add_argument("--ratio", type=float, default=0.05,
                    help="K/d of randk/topk")
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--a", type=float, default=0.1)
    ap.add_argument("--b", type=float, default=0.3)
    ap.add_argument("--p-page", type=float, default=0.25)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused dispatch + buffered-commit kernels")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default=None)
    from repro.obs import add_cli_flags
    add_cli_flags(ap)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (Identity, LogisticSigmoidProblem, RandK,
                            RandomDithering, SNice, TopK,
                            make_synthetic_classification)
    from repro.core.dasha_pp import DashaPPConfig
    from repro.fl import AsyncConfig, AsyncDashaServer, make_latency
    from repro.obs import start_run
    from repro.training.metrics import MetricsLogger

    obsrun = start_run(trace_out=args.trace_out,
                       metrics_out=args.metrics_out,
                       meta={"cli": "async_train",
                             "variant": args.variant})

    feats, y = make_synthetic_classification(
        jax.random.key(args.seed), args.n, args.m, args.d)
    prob = LogisticSigmoidProblem(feats, y)
    k = max(1, math.ceil(args.ratio * args.d))
    comp = {"randk": RandK(k=k), "topk": TopK(k=k),
            "dithering": RandomDithering(s=4),
            "identity": Identity()}[args.compressor]
    samp = SNice(n=args.n, s=args.cohort)
    cfg = DashaPPConfig(args.variant, gamma=args.gamma, a=args.a,
                        b=args.b, p_page=args.p_page,
                        batch_size=args.batch_size,
                        use_pallas=args.use_pallas)
    lat_kw = dict(bandwidth_bps=args.bandwidth or None,
                  dropout=args.dropout, seed=args.seed)
    if args.latency == "lognormal":
        lat_kw.update(sigma=args.sigma, client_sigma=args.sigma)
    latency = make_latency(args.latency, **lat_kw)
    srv = AsyncDashaServer(
        prob, comp, samp, cfg,
        AsyncConfig(buffer_size=args.buffer or None,
                    staleness_exponent=args.staleness_exponent,
                    max_staleness=args.max_staleness,
                    use_pallas=args.use_pallas),
        latency)

    from repro.obs import profiler_trace
    with profiler_trace(args.profile_dir):
        state, res = srv.run(jax.random.key(args.seed + 1),
                             jnp.zeros(args.d), args.rounds)

    logger = MetricsLogger(args.log, name="async_train",
                           print_every=max(1, len(res.time) // 20))
    for i in range(len(res.time)):
        logger.log(i, t_virtual=res.time[i], loss=res.loss[i],
                   grad_norm_sq=res.grad_norm_sq[i],
                   committed=int(res.committed[i]),
                   staleness_mean=res.staleness_mean[i],
                   mbits=res.bits_cum[i] / 1e6)
    logger.close()
    print(f"\nfinal ||grad f||^2 = {res.grad_norm_sq[-1]:.3e}  "
          f"t_virtual = {res.total_time:.1f}s  "
          f"util = {float(np.mean(res.utilization)):.2f}  "
          f"dropped = {res.dropped}  "
          f"staleness hist = {res.staleness_hist}")
    obsrun.finish()


if __name__ == "__main__":
    main()
