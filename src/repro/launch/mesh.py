"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

Mesh construction goes through :mod:`repro.compat` so the same code
runs on jax versions with and without ``jax.sharding.AxisType``.
"""
from __future__ import annotations

from typing import Tuple

from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_nodes(mesh: Mesh) -> int:
    n = 1
    for a in data_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def make_host_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for CPU tests/examples (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=data*model)."""
    return make_mesh((data, model), ("data", "model"))
