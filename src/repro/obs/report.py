"""Trace-analytics report CLI (DESIGN.md §15).

Turns the obs artifacts into answers: which client/hop bounded each
fleet round and where its virtual time and bits went (critical path),
where a trace's wall time went (span rollup), and whether the bench
trajectories drifted (all-entries regression detection).  Emits a
markdown summary (stdout or ``--md``) plus a JSON artifact
(``--json``) whose schema ``repro.obs.validate`` checks
(``tool == "repro.obs.report"``).

Usage::

    python -m repro.obs.report \
        --trace results/traces/fleet.trace.json \
        --metrics results/traces/fleet.metrics.json \
        --trajectory results/BENCH_serving.json \
        --json results/traces/report.json

    python -m repro.obs.report --self-test   # analyzer self-check

Exit codes: 0 clean; 1 when any trajectory regression/changepoint is
found or the critical-path bits fail to reconcile with the metrics
ledger; 2 on usage errors.  ``--self-test`` injects a synthetic 2x
decode slowdown and exits 0 only if the analyzer flags it (CI runs
this so a silently-broken analyzer cannot keep gating green).
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

from repro.obs.analyze import (analyze_critical_path, analyze_trajectory,
                               reconcile_bits, span_rollup)
from repro.obs.analyze.trajectory import load_trajectory_entries

__all__ = ["build_report", "render_markdown", "self_test", "main"]

REPORT_VERSION = 1


def _load(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def build_report(traces: List[str], metrics: List[str],
                 trajectories: List[str]) -> Dict[str, Any]:
    """Analyze the given artifacts into the report JSON document."""
    report: Dict[str, Any] = {
        "tool": "repro.obs.report",
        "version": REPORT_VERSION,
        "ts": time.time(),
        "inputs": {"traces": traces, "metrics": metrics,
                   "trajectories": trajectories},
    }
    rollup_rows: List[Dict[str, Any]] = []
    cp_out: Optional[Dict[str, Any]] = None
    metric_docs = [(p, _load(p)) for p in metrics]
    for path in traces:
        doc = _load(path)
        for row in span_rollup(doc):
            row = dict(row)
            row["trace"] = os.path.basename(path)
            rollup_rows.append(row)
        for flow_name, prefix in (("fleet.contrib", "fleet"),
                                  ("async.contrib", "fleet"),
                                  ("train.cohort", "train")):
            cp = analyze_critical_path(doc, flow_name=flow_name,
                                       span_prefix=prefix)
            if cp is None or not cp.rounds:
                continue
            if cp_out is not None:
                break    # first flow-bearing trace wins
            rec = None
            for mpath, mdoc in metric_docs:
                r = reconcile_bits(cp, mdoc)
                if r["ledger_found"]:
                    rec = {"ledger_ok": r["ledger_ok"],
                           "hops": r["hops"], "metrics": mpath}
                    break
            cp_out = {
                "trace": os.path.basename(path),
                "flow": cp.flow_name,
                "rounds": [{
                    "round": rp.round_idx,
                    "commit_ts_us": rp.commit_ts_us,
                    "total_us": rp.total_us,
                    "bound_client": rp.bound_client,
                    "bound_dispatch_round": rp.bound_dispatch_round,
                    "chain": rp.chain,
                    "units": rp.units,
                    "path_bits": rp.path_bits,
                    "residual_us": rp.residual_us(),
                    "segments": rp.segments(),
                } for rp in cp.rounds],
                "totals": cp.totals(),
                "bits_by_hop": {str(k): v
                                for k, v in sorted(cp.bits_by_hop.items())},
            }
            if rec is not None:
                cp_out["reconciliation"] = rec
            break
    if cp_out is not None:
        report["critical_path"] = cp_out
    report["span_rollup"] = rollup_rows

    traj_out: List[Dict[str, Any]] = []
    n_flagged = 0
    for path in trajectories:
        entries = load_trajectory_entries(path)
        findings = analyze_trajectory(entries)
        rows = [f.as_dict() for f in findings]
        n_flagged += sum(1 for f in findings
                         if f.kind != "improvement")
        traj_out.append({"path": path, "entries": len(entries),
                         "findings": rows})
    if trajectories:
        report["trajectory"] = {"files": traj_out}

    rec_ok = True
    if cp_out is not None and "reconciliation" in cp_out:
        rec_ok = cp_out["reconciliation"]["ledger_ok"]
    report["summary"] = {
        "regressions": n_flagged,
        "rounds": len(cp_out["rounds"]) if cp_out else 0,
        "reconciled": bool(rec_ok),
    }
    return report


def _us(v: float) -> str:
    return f"{v / 1e6:.4f}s" if abs(v) >= 1e6 else f"{v:.1f}us"


def render_markdown(report: Dict[str, Any]) -> str:
    out: List[str] = ["# obs report", ""]
    s = report["summary"]
    out.append(f"- regressions/changepoints: **{s['regressions']}**")
    out.append(f"- fleet rounds analyzed: {s['rounds']}")
    out.append(f"- bits ledger reconciled: {s['reconciled']}")
    out.append("")
    cp = report.get("critical_path")
    if cp:
        out.append(f"## Critical path — `{cp['trace']}` "
                   f"({cp['flow']})")
        out.append("")
        out.append("| round | total | bound client | compute | network "
                   "| buffer wait | forced flush | root wait | "
                   "path bits |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for rp in cp["rounds"]:
            seg = rp["segments"]
            out.append(
                f"| {rp['round']} | {_us(rp['total_us'])} "
                f"| {rp['bound_client']} "
                f"| {_us(seg['compute_us'])} "
                f"| {_us(seg['network_us'])} "
                f"| {_us(seg['buffer_wait_us'])} "
                f"| {_us(seg['forced_flush_us'])} "
                f"| {_us(seg['root_wait_us'])} "
                f"| {rp['path_bits']:.0f} |")
        out.append("")
        tot = cp["totals"]
        denom = sum(tot.values()) or 1.0
        out.append("Aggregate attribution: " + ", ".join(
            f"{k[:-3]} {100.0 * v / denom:.1f}%"
            for k, v in tot.items()))
        out.append("")
        rec = cp.get("reconciliation")
        if rec:
            verdict = "exact" if rec["ledger_ok"] else "**MISMATCH**"
            out.append(f"Per-hop bits vs `fleet.tier_bits` ledger "
                       f"(`{rec['metrics']}`): {verdict}")
            out.append("")
            out.append("| hop | trace bits | ledger bits | match |")
            out.append("|---|---|---|---|")
            for hop, row in rec["hops"].items():
                out.append(f"| {hop} | {row['trace_bits']:.0f} | "
                           f"{row['ledger_bits']} | {row['match']} |")
            out.append("")
    rollup = report.get("span_rollup") or []
    if rollup:
        out.append("## Span rollup (wall clock, self-time order)")
        out.append("")
        out.append("| span | trace | count | total | self | child |")
        out.append("|---|---|---|---|---|---|")
        for row in rollup[:20]:
            out.append(f"| {row['name']} | {row.get('trace', '-')} "
                       f"| {row['count']} | {_us(row['total_us'])} "
                       f"| {_us(row['self_us'])} "
                       f"| {_us(row['child_us'])} |")
        out.append("")
    traj = report.get("trajectory")
    if traj:
        out.append("## Bench trajectories")
        out.append("")
        for f in traj["files"]:
            out.append(f"- `{f['path']}`: {f['entries']} entries, "
                       f"{len(f['findings'])} finding(s)")
            for fd in f["findings"]:
                out.append(
                    f"  - {fd['kind']} ({fd['detector']}): "
                    f"[{fd['mode']}] {fd['metric']} "
                    f"{fd['baseline']:.4g} -> {fd['latest']:.4g} "
                    f"(x{fd['ratio']:.3f}) cell={fd['cell']}")
        out.append("")
    return "\n".join(out)


def self_test() -> int:
    """Analyzer self-check: inject a synthetic 2x decode-tok/s slowdown
    into a fabricated serving trajectory and require the analyzer to
    flag it, and a clean copy to stay quiet."""
    base_entry = {
        "ts": 1.0, "mode": "smoke", "backend": "cpu",
        "cells": [],
        "decode": [{"n": 4, "max_seq": 64,
                    "paged_decode_tok_s": 6000.0,
                    "dense_decode_tok_s": 3600.0,
                    "decode_ratio": 1.66}],
    }
    clean = []
    for i in range(4):
        e = copy.deepcopy(base_entry)
        e["ts"] = float(i + 1)
        # realistic ~10% run-to-run noise, inside the 0.6 band
        jitter = 1.0 + 0.1 * ((-1) ** i)
        e["decode"][0]["paged_decode_tok_s"] *= jitter
        e["decode"][0]["dense_decode_tok_s"] *= jitter
        clean.append(e)
    quiet = analyze_trajectory(clean)
    bad_quiet = [f for f in quiet if f.kind != "improvement"]
    if bad_quiet:
        print("SELF-TEST FAIL: analyzer flagged a clean trajectory:",
              [f.as_dict() for f in bad_quiet])
        return 1
    regressed = copy.deepcopy(clean)
    last = copy.deepcopy(base_entry)
    last["ts"] = 5.0
    last["decode"][0]["paged_decode_tok_s"] = 3000.0   # 2x slowdown
    last["decode"][0]["decode_ratio"] = 0.83
    regressed.append(last)
    findings = analyze_trajectory(regressed)
    hits = [f for f in findings
            if f.kind == "regression"
            and f.metric == "paged_decode_tok_s"]
    if not hits:
        print("SELF-TEST FAIL: 2x decode slowdown not flagged; got:",
              [f.as_dict() for f in findings])
        return 1
    print("self-test ok: clean trajectory quiet, 2x decode slowdown "
          f"flagged (ratio x{hits[0].ratio:.3f})")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="analytics report over obs trace/metrics/trajectory "
                    "artifacts")
    ap.add_argument("--trace", action="append", default=[],
                    help="Chrome trace JSON (repeatable)")
    ap.add_argument("--metrics", action="append", default=[],
                    help="metrics snapshot JSON (repeatable)")
    ap.add_argument("--trajectory", action="append", default=[],
                    help="bench trajectory JSON (repeatable)")
    ap.add_argument("--json", dest="json_out",
                    help="write the report artifact here")
    ap.add_argument("--md", dest="md_out",
                    help="write the markdown summary here "
                         "(default: stdout)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the analyzer's injected-regression "
                         "self-check and exit")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    if not (args.trace or args.metrics or args.trajectory):
        ap.print_usage(sys.stderr)
        print("error: nothing to analyze (pass --trace/--metrics/"
              "--trajectory or --self-test)", file=sys.stderr)
        return 2
    report = build_report(args.trace, args.metrics, args.trajectory)
    md = render_markdown(report)
    if args.json_out:
        d = os.path.dirname(args.json_out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=1)
    if args.md_out:
        d = os.path.dirname(args.md_out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.md_out, "w") as f:
            f.write(md + "\n")
    else:
        print(md)
    bad = report["summary"]["regressions"] > 0 \
        or not report["summary"]["reconciled"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
