"""Unified observability layer (DESIGN.md §13).

Three pillars, all stdlib-only (jax imported lazily where needed):

- ``obs.trace``    — nestable spans, dual wall/virtual clocks, Chrome
  trace-event export (loadable in Perfetto).
- ``obs.metrics``  — typed counter/gauge/histogram registry, jsonl
  sink, Prometheus text exposition; the engines publish their ledgers
  into it.
- ``obs.monitors`` — live invariant checks (wire-bits reconciliation,
  pool refcount conservation, staleness-hop monotonicity) firing as
  structured warnings in traced runs.

:func:`start_run` is the one-call entrypoint the launch CLIs and
benches use to honor ``--trace-out`` / ``--metrics-out``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.obs import metrics, monitors, provenance, trace
from repro.obs.metrics import (Counter, Gauge, Histogram, JsonlSink,
                               Registry, get_registry)
from repro.obs.monitors import MonitorResult, ObsWarning
from repro.obs.trace import (Tracer, active, counter, instant,
                             kernel_scope, set_virtual_time, span, traced)

__all__ = [
    "metrics", "monitors", "provenance", "trace",
    "Counter", "Gauge", "Histogram", "JsonlSink", "Registry",
    "get_registry", "MonitorResult", "ObsWarning", "Tracer", "active",
    "counter", "instant", "kernel_scope", "set_virtual_time", "span",
    "traced", "ObsRun", "start_run", "add_cli_flags", "profiler_trace",
]


class ObsRun:
    """Handle for one observed run; ``finish()`` writes the artifacts."""

    def __init__(self, trace_out: Optional[str] = None,
                 metrics_out: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.trace_out = trace_out
        self.metrics_out = metrics_out
        self._finished = False
        self.provenance = provenance.collect()
        if meta:
            self.provenance.update(meta)
        self.tracer: Optional[Tracer] = None
        if trace_out:
            self.tracer = trace.configure(meta=self.provenance)

    def finish(self, registry: Optional[Registry] = None,
               quiet: bool = False) -> "ObsRun":
        """Export trace + metrics snapshot; idempotent."""
        if self._finished:
            return self
        self._finished = True
        if self.tracer is not None:
            if trace.get_tracer() is self.tracer:
                trace.uninstall()
            self.tracer.export_chrome(self.trace_out)
            if not quiet:
                print(f"[obs] trace -> {self.trace_out} "
                      f"({len(self.tracer.events)} events)")
        if self.metrics_out:
            reg = registry or get_registry()
            reg.write_snapshot(self.metrics_out,
                               extra={"provenance": self.provenance})
            if not quiet:
                print(f"[obs] metrics -> {self.metrics_out} "
                      f"({len(reg.names())} metrics)")
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.finish()
        return False


def start_run(trace_out: Optional[str] = None,
              metrics_out: Optional[str] = None,
              meta: Optional[Dict[str, Any]] = None) -> ObsRun:
    """Begin an observed run (no-op handle when both outputs are None)."""
    return ObsRun(trace_out=trace_out, metrics_out=metrics_out, meta=meta)


def add_cli_flags(ap) -> None:
    """Attach the standard ``--trace-out`` / ``--metrics-out`` /
    ``--profile-dir`` flags."""
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (open in "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics registry snapshot JSON")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture the hot section with jax.profiler "
                         "(TensorBoard/Perfetto-loadable; the "
                         "repro.kernel.* named scopes appear in the "
                         "device trace)")


def profiler_trace(profile_dir: Optional[str]):
    """``jax.profiler.trace(profile_dir)`` as a context manager, or a
    no-op context when ``profile_dir`` is None (or jax is absent — the
    obs core stays stdlib-only).  The launch CLIs wrap their hot
    section in this so ``--profile-dir`` captures the 14
    ``kernel_scope`` names in a real device profile alongside our
    spans."""
    if not profile_dir:
        return trace._NULL_SPAN
    import jax
    return jax.profiler.trace(profile_dir)
