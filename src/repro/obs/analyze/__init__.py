"""Trace analytics over the obs artifacts (DESIGN.md §15).

Three engines, one per artifact family:

- :mod:`critical_path` — walks a fleet/async trace's flow links
  backward from each committed round and decomposes the bounding chain
  into compute / network / buffer-wait / forced-flush / root-wait
  segments, with exact per-hop bit reconciliation against the
  ``fleet.tier_bits`` metrics ledger.
- :mod:`rollup` — flamegraph-style span-tree aggregation (self-time vs
  child-time per span name) for any Chrome trace, serving traces
  included.
- :mod:`trajectory` — drift/changepoint detection across *all* entries
  of the append-per-run ``results/BENCH_*.json`` trajectory files (CI's
  pairwise baseline gate only sees the last committed entry).

Surfaced by ``python -m repro.obs.report``; artifact schema checked by
``repro.obs.validate`` (``tool == "repro.obs.report"``).
"""
from repro.obs.analyze.critical_path import (   # noqa: F401
    CriticalPathResult, RoundPath, analyze_critical_path,
    reconcile_bits,
)
from repro.obs.analyze.rollup import span_rollup        # noqa: F401
from repro.obs.analyze.trajectory import (      # noqa: F401
    TrajectoryFinding, analyze_trajectory,
)

__all__ = [
    "CriticalPathResult", "RoundPath", "analyze_critical_path",
    "reconcile_bits", "span_rollup", "TrajectoryFinding",
    "analyze_trajectory",
]
