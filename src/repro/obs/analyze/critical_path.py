"""Per-round critical-path attribution over the fleet trace flow graph.

The fleet runtime (``fl/tree.py``) emits, on the virtual-clock pid:

- ``fleet.contrib`` flow starts (ph "s") at dispatch, whose args carry
  the latency-model pricing of that client's leg (``compute_s``,
  ``network_s``) and its uplink ``bits``;
- ``fleet.flush`` spans whose args carry the causal edge set
  (``inputs`` — the cids/mids merged — and the created ``mid``) plus
  the link pricing (``link_compute_s``/``link_network_s``) and the
  merged message ``bits``;
- ``fleet.commit`` spans whose args carry ``unit_ids`` — the root-buffer
  items the commit consumed.

That is a complete event graph: every committed unit can be walked back
to the client dispatch that originated its bounding chain, and because
each edge is priced by the same latency models the simulator ran, the
walk decomposes the round's virtual time *exactly* (telescoping sum)
into

    client compute + network (uplink + per-hop links)
    + buffer wait (time a contribution sat in an under-full buffer)
    + forced-flush wait (same, when the flush was the timeout path)
    + root wait (arrival at the root buffer -> commit instant)

On a zero-jitter barrier run every wait is zero and each round's total
collapses to the slowest participating client's compute + uplink chain
— the paper's per-round cost model, now machine-checked
(tests/test_trace_analytics.py).

Bit reconciliation: summing ``bits`` over the contrib flow starts
(hop 0) and over the flush spans of tier k (hop k+1) must reproduce the
``fleet.tier_bits.hop<k>`` gauges of the metrics snapshot *exactly* —
the trace and the ledger are two exports of the same accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional

from repro.obs.trace import VIRTUAL_PID

__all__ = ["RoundPath", "CriticalPathResult", "analyze_critical_path",
           "reconcile_bits"]

_S = 1e6    # seconds -> trace microseconds


@dataclasses.dataclass
class RoundPath:
    """The bounding chain of one committed round."""
    round_idx: int
    commit_ts_us: float
    dispatch_ts_us: float
    total_us: float
    bound_client: int
    bound_dispatch_round: int
    # unit ids along the chain, client contribution first
    chain: List[int]
    compute_us: float
    network_us: float
    buffer_wait_us: float
    forced_flush_us: float
    root_wait_us: float
    path_bits: float
    units: int

    def segments(self) -> Dict[str, float]:
        return {"compute_us": self.compute_us,
                "network_us": self.network_us,
                "buffer_wait_us": self.buffer_wait_us,
                "forced_flush_us": self.forced_flush_us,
                "root_wait_us": self.root_wait_us}

    def residual_us(self) -> float:
        """Decomposition error (fp rounding only; ~0 by construction)."""
        return self.total_us - sum(self.segments().values())


@dataclasses.dataclass
class CriticalPathResult:
    rounds: List[RoundPath]
    bits_by_hop: Dict[int, float]       # hop index -> total bits seen
    flow_name: str                      # "fleet.contrib" etc.

    def totals(self) -> Dict[str, float]:
        keys = ("compute_us", "network_us", "buffer_wait_us",
                "forced_flush_us", "root_wait_us")
        return {k: sum(getattr(r, k) for r in self.rounds) for k in keys}


def _virtual_events(doc: Mapping[str, Any]) -> List[Dict[str, Any]]:
    evs = doc.get("traceEvents", [])
    return [e for e in evs if e.get("pid") == VIRTUAL_PID]


def analyze_critical_path(doc: Mapping[str, Any],
                          flow_name: str = "fleet.contrib",
                          span_prefix: str = "fleet"
                          ) -> Optional[CriticalPathResult]:
    """Attribute each committed round of a fleet trace to its bounding
    chain.  Returns ``None`` when the trace carries no virtual-clock
    flow graph (serving traces, untraced runs)."""
    vevs = _virtual_events(doc)
    contribs: Dict[int, Dict[str, Any]] = {}
    flushes: Dict[int, Dict[str, Any]] = {}     # keyed by created mid
    commits: List[Dict[str, Any]] = []
    for e in vevs:
        name = e.get("name")
        if e.get("ph") == "s" and name == flow_name:
            contribs[e["id"]] = e
        elif e.get("ph") == "X" and name == f"{span_prefix}.flush":
            args = e.get("args", {})
            if "mid" in args:
                flushes[args["mid"]] = e
        elif e.get("ph") == "X" and name == f"{span_prefix}.commit":
            commits.append(e)
    if not contribs:
        return None

    def arrival_us(uid: int) -> float:
        """Virtual instant unit ``uid`` reached its parent buffer."""
        if uid in flushes:
            f = flushes[uid]
            a = f.get("args", {})
            return f["ts"] + (a.get("link_compute_s", 0.0)
                              + a.get("link_network_s", 0.0)) * _S
        c = contribs[uid]
        a = c.get("args", {})
        return c["ts"] + (a.get("compute_s", 0.0)
                          + a.get("network_s", 0.0)) * _S

    def known(uid: int) -> bool:
        return uid in flushes or uid in contribs

    rounds: List[RoundPath] = []
    for ce in commits:
        cargs = ce.get("args", {})
        units = [u for u in cargs.get("unit_ids", []) if known(u)]
        if not units:
            continue
        commit_ts = ce["ts"]
        bound = max(units, key=arrival_us)
        chain: List[int] = []
        comp = net = bwait = fwait = 0.0
        path_bits = 0.0
        uid = bound
        # walk down: message -> bounding input -> ... -> contribution
        while uid in flushes:
            f = flushes[uid]
            fa = f.get("args", {})
            chain.append(uid)
            comp += fa.get("link_compute_s", 0.0) * _S
            net += fa.get("link_network_s", 0.0) * _S
            path_bits += fa.get("bits", 0.0)
            inputs = [i for i in fa.get("inputs", []) if known(i)]
            if not inputs:
                break
            binput = max(inputs, key=arrival_us)
            wait = max(f["ts"] - arrival_us(binput), 0.0)
            if fa.get("forced"):
                fwait += wait
            else:
                bwait += wait
            uid = binput
        if uid not in contribs:
            continue     # chain truncated (dropped buffer prefix)
        chain.append(uid)
        ca = contribs[uid].get("args", {})
        comp += ca.get("compute_s", 0.0) * _S
        net += ca.get("network_s", 0.0) * _S
        path_bits += ca.get("bits", 0.0)
        dispatch_ts = contribs[uid]["ts"]
        total = commit_ts - dispatch_ts
        root_wait = max(commit_ts - arrival_us(bound), 0.0)
        rounds.append(RoundPath(
            round_idx=int(cargs.get("round", -1)),
            commit_ts_us=commit_ts, dispatch_ts_us=dispatch_ts,
            total_us=total,
            bound_client=int(ca.get("client", -1)),
            bound_dispatch_round=int(ca.get("round", -1)),
            chain=list(reversed(chain)),
            compute_us=comp, network_us=net, buffer_wait_us=bwait,
            forced_flush_us=fwait, root_wait_us=root_wait,
            path_bits=path_bits, units=len(units)))

    bits_by_hop: Dict[int, float] = {0: 0.0}
    for c in contribs.values():
        bits_by_hop[0] += c.get("args", {}).get("bits", 0.0)
    for f in flushes.values():
        fa = f.get("args", {})
        hop = int(fa.get("tier", 0)) + 1
        bits_by_hop[hop] = bits_by_hop.get(hop, 0.0) \
            + fa.get("bits", 0.0)
    return CriticalPathResult(rounds=rounds, bits_by_hop=bits_by_hop,
                              flow_name=flow_name)


def reconcile_bits(cp: CriticalPathResult,
                   metrics_doc: Mapping[str, Any],
                   atol: float = 0.0) -> Dict[str, Any]:
    """Check the trace-derived per-hop bit totals against the
    ``fleet.tier_bits.hop<k>`` gauges of a metrics snapshot.  Exact by
    default (``atol=0``): both sides are sums of the same per-message
    floats."""
    metrics = metrics_doc.get("metrics", {})
    hops: Dict[str, Dict[str, Any]] = {}
    ok = True
    found_any = False
    for k in sorted(cp.bits_by_hop):
        gauge = metrics.get(f"fleet.tier_bits.hop{k}")
        if gauge is None:
            hops[str(k)] = {"trace_bits": cp.bits_by_hop[k],
                            "ledger_bits": None, "match": None}
            continue
        found_any = True
        ledger = float(gauge.get("value", float("nan")))
        match = abs(cp.bits_by_hop[k] - ledger) <= atol
        ok = ok and match
        hops[str(k)] = {"trace_bits": cp.bits_by_hop[k],
                        "ledger_bits": ledger, "match": match}
    total_gauge = metrics.get("fleet.tier_bits")
    if total_gauge is not None:
        found_any = True
        ledger_total = float(total_gauge.get("value", float("nan")))
        trace_total = sum(cp.bits_by_hop.values())
        match = abs(trace_total - ledger_total) <= atol
        ok = ok and match
        hops["total"] = {"trace_bits": trace_total,
                         "ledger_bits": ledger_total, "match": match}
    return {"ledger_ok": bool(ok and found_any), "hops": hops,
            "ledger_found": found_any}
