"""Drift / changepoint detection over bench trajectory files.

The bench suites append one provenance-stamped entry per run to
``results/BENCH_serving.json`` / ``results/BENCH_fleet.json`` /
``results/bench/kernels.json``.  CI's ``--check-baseline`` gate is
pairwise — latest run vs the last committed same-mode entry — so a slow
regression that stays inside the pairwise noise band every run walks
the baseline down unchallenged.  This analyzer reads *all* entries.

Series extraction.  Every entry contributes its ``cells`` (plus
``decode`` cells for the serving bench).  Within a cell, int/str/bool
items are the cell *identity* (dims: ``n``, ``name``, ``mode``, ...)
and float items are *metrics*; one series per
(mode, cell-identity, metric), in timestamp order, restricted to the
same backend family as the latest entry.

Detectors, per series:

- **Drift**: latest value vs the median of all prior values.  The
  threshold is direction- and class-aware: wall-clock-ish metrics
  (tok/s, wall seconds, microseconds, speedups) are noisy — the flag
  fires when the latest is worse than ``NOISY_RATIO`` (0.6, matching
  the serving bench's ``DECODE_RATIO_NOISE``) of baseline — while
  deterministic counters (bytes, bits, byte ratios) must not move at
  all (``EXACT_RTOL``).  A drift in the *good* direction is reported as
  an ``improvement`` (informational, never counted as a failure);
  metrics with unknown direction flag symmetrically as ``changepoint``.
- **Level shift**: for series of >= 4 points, the best split into a
  left/right half (>= 2 points each) whose medians differ beyond the
  class threshold — catches a sustained step that predates the latest
  run, which the pairwise gate has long since accepted.
"""
from __future__ import annotations

import dataclasses
import json
from statistics import median
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = ["TrajectoryFinding", "analyze_trajectory",
           "load_trajectory_entries", "NOISY_RATIO", "EXACT_RTOL"]

# Worse-than ratio that flags a noisy (wall-clock) metric; matches the
# serving bench's pairwise DECODE_RATIO_NOISE so the two gates agree on
# what "noise" is.  A 2x slowdown (ratio 0.5) always fires.
NOISY_RATIO = 0.6
# Deterministic counters (byte/bit accounting) must reproduce exactly
# modulo fp printing; anything beyond this is a real change.
EXACT_RTOL = 1e-6

# Metric-name direction/class table.  Substring match, first hit wins.
# (+1: higher is better, -1: lower is better, 0: unknown direction.)
_NOISY = [("tok_s", +1), ("speedup", +1), ("decode_ratio", +1),
          ("wall_s", -1), ("_us", -1), ("us_", -1), ("latency", -1),
          ("ttft", -1), ("p50", -1), ("p95", -1), ("time", -1)]
_EXACT = [("bytes", -1), ("bits", -1), ("ratio", -1), ("max_err", -1),
          ("count", 0), ("pages", -1)]


def _classify(metric: str) -> Tuple[str, int]:
    low = metric.lower()
    for sub, direction in _NOISY:
        if sub in low:
            return "noisy", direction
    for sub, direction in _EXACT:
        if sub in low:
            return "exact", direction
    return "unknown", 0


@dataclasses.dataclass
class TrajectoryFinding:
    kind: str          # "regression" | "improvement" | "changepoint"
    detector: str      # "drift" | "level_shift"
    mode: str
    cell: str          # rendered cell identity
    metric: str
    baseline: float
    latest: float
    ratio: float       # latest / baseline
    n_points: int

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def load_trajectory_entries(path: str) -> List[Dict[str, Any]]:
    """Read a trajectory file; a legacy bare list of cells is absorbed
    as a single ``mode="legacy"`` entry (same rule as the benches)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, list):
        raise ValueError(f"{path}: trajectory must be a JSON list")
    if doc and not (isinstance(doc[0], dict) and "cells" in doc[0]):
        return [{"ts": 0.0, "mode": "legacy", "cells": _flatten(doc)}]
    return doc


def _flatten(rows: Any) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    for r in rows:
        if isinstance(r, list):
            out.extend(_flatten(r))
        elif isinstance(r, dict):
            out.append(r)
    return out


def _cells(entry: Mapping[str, Any]) -> List[Dict[str, Any]]:
    cells = list(entry.get("cells") or [])
    cells.extend(entry.get("decode") or [])
    return [c for c in cells if isinstance(c, dict)]


def _cell_key(cell: Mapping[str, Any]) -> str:
    dims = {k: v for k, v in cell.items()
            if isinstance(v, (str, bool)) or
            (isinstance(v, int) and not isinstance(v, bool))}
    return json.dumps(dims, sort_keys=True)


def _series(entries: Sequence[Mapping[str, Any]]
            ) -> Dict[Tuple[str, str, str], List[float]]:
    out: Dict[Tuple[str, str, str], List[float]] = {}
    # ts is a strftime string on real entries and a 0.0/absent sentinel
    # on absorbed legacy ones; stringified, sentinels sort first
    for entry in sorted(entries, key=lambda e: str(e.get("ts", ""))):
        mode = str(entry.get("mode", "unknown"))
        for cell in _cells(entry):
            ck = _cell_key(cell)
            for k, v in cell.items():
                if isinstance(v, float) and not isinstance(v, bool):
                    out.setdefault((mode, ck, k), []).append(v)
    return out


def _ratio(latest: float, base: float) -> float:
    if base == 0.0:
        return float("inf") if latest != 0.0 else 1.0
    return latest / base


def _is_bad(ratio: float, direction: int, klass: str) -> Optional[str]:
    """None = within noise; else the finding kind."""
    if klass == "noisy":
        worse = ratio < NOISY_RATIO if direction >= 0 \
            else ratio > 1.0 / NOISY_RATIO
        better = ratio > 1.0 / NOISY_RATIO if direction >= 0 \
            else ratio < NOISY_RATIO
        if direction == 0:
            return "changepoint" if (worse or better) else None
        if worse:
            return "regression"
        if better:
            return "improvement"
        return None
    rtol = EXACT_RTOL
    if abs(ratio - 1.0) <= rtol:
        return None
    if direction == 0:
        return "changepoint"
    bad = ratio < 1.0 if direction > 0 else ratio > 1.0
    return "regression" if bad else "improvement"


def analyze_trajectory(entries: Sequence[Mapping[str, Any]]
                       ) -> List[TrajectoryFinding]:
    findings: List[TrajectoryFinding] = []
    for (mode, cell, metric), vals in _series(entries).items():
        if len(vals) < 2:
            continue
        klass, direction = _classify(metric)
        if klass == "unknown":
            # no safe threshold for an unknown metric: treat like a
            # noisy symmetric changepoint detector
            klass, direction = "noisy", 0
        # -- drift: latest vs median of priors ----------------------
        base = median(vals[:-1])
        latest = vals[-1]
        r = _ratio(latest, base)
        kind = _is_bad(r, direction, klass)
        if kind is not None:
            findings.append(TrajectoryFinding(
                kind=kind, detector="drift", mode=mode, cell=cell,
                metric=metric, baseline=float(base),
                latest=float(latest), ratio=float(r),
                n_points=len(vals)))
            continue   # one finding per series is enough signal
        # -- level shift across the whole series --------------------
        if len(vals) >= 4:
            for split in range(2, len(vals) - 1):
                left = median(vals[:split])
                right = median(vals[split:])
                r = _ratio(right, left)
                kind = _is_bad(r, direction, klass)
                if kind is not None:
                    findings.append(TrajectoryFinding(
                        kind=kind, detector="level_shift", mode=mode,
                        cell=cell, metric=metric, baseline=float(left),
                        latest=float(right), ratio=float(r),
                        n_points=len(vals)))
                    break
    return findings
