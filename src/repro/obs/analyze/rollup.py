"""Flamegraph-style span-tree rollups for Chrome traces.

Nesting is recovered per (pid, tid) lane from interval containment —
the exporter writes complete events (ph "X"), so after sorting a lane
by (start, -duration) a span's direct parent is the innermost still-open
interval that contains it.  Self time is a span's duration minus that
of its direct children; aggregating (count, total, self) by span name
yields the flamegraph view of where a trace's time actually went
(serve passes vs admission vs prefill, commit vs dispatch, ...).
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.obs.trace import WALL_PID

__all__ = ["span_rollup"]


def span_rollup(doc: Mapping[str, Any], pid: Optional[int] = WALL_PID
                ) -> List[Dict[str, Any]]:
    """Aggregate ph-"X" spans by name: per-name call count, inclusive
    (total), exclusive (self) and direct-child time in microseconds,
    sorted by self time descending.  ``pid=None`` rolls up every
    process."""
    by_lane: Dict[Tuple[Any, Any], List[Dict[str, Any]]] = {}
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "X" and (pid is None or e.get("pid") == pid):
            by_lane.setdefault((e.get("pid"), e.get("tid")),
                               []).append(e)
    agg: Dict[str, Dict[str, Any]] = {}
    for lane in by_lane.values():
        # parents sort before their children: earlier start, then
        # longer duration (events are appended at finish time, so the
        # raw order is close-order, not open-order)
        lane.sort(key=lambda e: (e["ts"], -float(e.get("dur", 0.0))))
        stack: List[Tuple[float, Dict[str, Any]]] = []  # (end, name row)
        for e in lane:
            ts, dur = float(e["ts"]), float(e.get("dur", 0.0))
            while stack and ts >= stack[-1][0]:
                stack.pop()
            row = agg.setdefault(e["name"], {"name": e["name"],
                                             "count": 0,
                                             "total_us": 0.0,
                                             "child_us": 0.0})
            row["count"] += 1
            row["total_us"] += dur
            if stack:
                stack[-1][1]["child_us"] += dur
            stack.append((ts + dur, row))
    for row in agg.values():
        row["self_us"] = row["total_us"] - row["child_us"]
    return sorted(agg.values(), key=lambda r: -r["self_us"])
