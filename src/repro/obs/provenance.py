"""Run provenance: who/what/where produced an artifact.

Stamped into bench trajectory entries (results/BENCH_*.json), trace
metadata, and metrics snapshots so `--check-baseline` comparisons are
attributable to a commit + backend + host.  Everything degrades to
``None`` rather than raising — provenance must never fail a run.
"""
from __future__ import annotations

import os
import platform
import socket
import subprocess
import sys
from typing import Any, Dict, Optional

__all__ = ["git_sha", "collect"]


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return None


def collect(cwd: Optional[str] = None) -> Dict[str, Any]:
    backend = jax_version = None
    try:
        import jax
        jax_version = jax.__version__
        backend = jax.default_backend()
    except Exception:
        pass
    return {
        "git_sha": git_sha(cwd),
        "backend": backend,
        "jax_version": jax_version,
        "hostname": socket.gethostname(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
    }
