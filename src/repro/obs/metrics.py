"""Typed metrics registry + jsonl sink + Prometheus text exposition.

Naming scheme (DESIGN.md §13): dotted, ``<subsystem>.<metric>[.<tag>]``

- ``train.*``   — sync/cohort trainer (``train.bits_sent``,
  ``train.oracle_calls``, ``train.steps``, ``train.loss``)
- ``fleet.*``   — hierarchical tree + async server
  (``fleet.tier_bits``, ``fleet.tier_bits.hop<k>``, ``fleet.committed``)
- ``serving.*`` — decode engines (``serving.decode_tokens``,
  ``serving.ttft_p50`` in serve-pass ticks, ``serving.latency_p95``)
- ``pool.*``    — KV page pool (``pool.pages_live``, ``pool.cow_copies``)
- ``obs.*``     — the observability layer itself
  (``obs.monitor_checks``, ``obs.monitor_failures``)

All metric types are float-valued.  Counters only accumulate
(``inc``), gauges hold the latest value (``set``), histograms record
observations and expose count/sum/min/max/percentiles.  The registry
is get-or-create by name with a kind check, so publishing sites never
coordinate.  ``snapshot()``/``write_snapshot()`` produce the JSON
artifact validated by obs/validate.py; ``to_prometheus()`` renders the
text exposition format.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Any, Dict, IO, List, Mapping, Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "JsonlSink",
    "get_registry", "set_registry", "counter", "gauge", "histogram",
    "publish_serving", "publish_fleet",
]

_HIST_CAP = 100_000    # raw observations kept for exact percentiles


class Counter:
    """Monotonically accumulating value."""
    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    """Latest-value metric."""
    kind = "gauge"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": self.value}


class Histogram:
    """Observation histogram with exact percentiles (capped reservoir)."""
    kind = "histogram"
    __slots__ = ("name", "count", "sum", "min", "max", "_values")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._values: List[float] = []

    def observe(self, value: float, n: int = 1) -> None:
        v = float(value)
        self.count += n
        self.sum += v * n
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        room = _HIST_CAP - len(self._values)
        if room > 0:
            self._values.extend([v] * min(n, room))

    def percentile(self, q: float) -> Optional[float]:
        if not self._values:
            return None
        vals = sorted(self._values)
        idx = min(int(round(q / 100.0 * (len(vals) - 1))), len(vals) - 1)
        return vals[idx]

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p95": self.percentile(95)}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Get-or-create metric store; kind mismatches are errors."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, kind: str):
        m = self._metrics.get(name)
        if m is None:
            m = _KINDS[kind](name)
            self._metrics[name] = m
        elif m.kind != kind:
            raise TypeError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram")

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        self._metrics.clear()

    # -- export -----------------------------------------------------
    def snapshot(self, extra: Optional[Mapping[str, Any]] = None
                 ) -> Dict[str, Any]:
        doc: Dict[str, Any] = {
            "ts": time.time(),
            "metrics": {name: self._metrics[name].as_dict()
                        for name in self.names()},
        }
        if extra:
            doc.update(extra)
        return doc

    def write_snapshot(self, path: str,
                       extra: Optional[Mapping[str, Any]] = None) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.snapshot(extra), f, indent=1)
        return path

    def to_prometheus(self) -> str:
        """Prometheus text exposition (dots -> underscores)."""
        lines: List[str] = []
        for name in self.names():
            m = self._metrics[name]
            pname = "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)
            if m.kind == "histogram":
                lines.append(f"# TYPE {pname} summary")
                lines.append(f"{pname}_count {m.count}")
                lines.append(f"{pname}_sum {m.sum}")
                for q in (50, 95):
                    p = m.percentile(q)
                    if p is not None:
                        lines.append(
                            f'{pname}{{quantile="0.{q}"}} {p}')
            else:
                lines.append(f"# TYPE {pname} {m.kind}")
                lines.append(f"{pname} {m.value}")
        return "\n".join(lines) + "\n"


_registry = Registry()


def get_registry() -> Registry:
    return _registry


def set_registry(reg: Registry) -> Registry:
    global _registry
    _registry = reg
    return reg


def counter(name: str) -> Counter:
    return _registry.counter(name)


def gauge(name: str) -> Gauge:
    return _registry.gauge(name)


def histogram(name: str) -> Histogram:
    return _registry.histogram(name)


class JsonlSink:
    """Append-mode jsonl writer with an idempotent ``close()``."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._file: Optional[IO[str]] = open(path, "a")

    def write(self, record: Mapping[str, Any]) -> None:
        if self._file is None:
            raise ValueError(f"JsonlSink({self.path!r}) is closed")
        self._file.write(json.dumps(record) + "\n")
        self._file.flush()

    def close(self) -> None:
        f, self._file = self._file, None
        if f is not None:
            f.close()

    @property
    def closed(self) -> bool:
        return self._file is None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------
# publish helpers: existing engine ledgers -> registry
# ---------------------------------------------------------------------
def publish_serving(engine_metrics: Mapping[str, Any],
                    registry: Optional[Registry] = None) -> None:
    """Publish ``PagedEngine.metrics()`` into ``serving.*`` / ``pool.*``."""
    reg = registry or _registry
    serving_keys = ("clock", "decode_steps", "prefill_forwards",
                    "mixed_passes", "mid_prefill_preemptions",
                    "decode_tokens", "decode_tok_per_s", "requests",
                    "latency_p50", "latency_p95", "ttft_p50", "ttft_p95",
                    "cache_hbm_bytes")
    for k in serving_keys:
        v = engine_metrics.get(k)
        if v is not None:
            reg.gauge(f"serving.{k}").set(float(v))
    for k, v in engine_metrics.items():
        if k.startswith("pool_") and isinstance(v, (int, float)):
            reg.gauge("pool." + k[len("pool_"):]).set(float(v))
    pool = engine_metrics.get("pool")
    if isinstance(pool, Mapping):
        for k, v in pool.items():
            if isinstance(v, (int, float)):
                reg.gauge(f"pool.{k}").set(float(v))


def publish_fleet(result: Any, registry: Optional[Registry] = None) -> None:
    """Publish a ``FleetRunResult``'s ledgers into ``fleet.*``.

    ``fleet.tier_bits`` is the total wire bits summed over every hop —
    by the §12 ledger invariant it equals ``bits_cum[-1]``, which the
    ledger monitor (obs/monitors.py) re-checks at runtime.
    """
    reg = registry or _registry
    tier_bits = [float(b) for b in result.tier_bits]
    reg.gauge("fleet.tier_bits").set(sum(tier_bits))
    for k, b in enumerate(tier_bits):
        reg.gauge(f"fleet.tier_bits.hop{k}").set(b)
    if len(result.bits_cum):
        reg.gauge("fleet.bits_cum").set(float(result.bits_cum[-1]))
        reg.gauge("fleet.root_bits_cum").set(float(result.root_bits_cum[-1]))
        reg.gauge("fleet.virtual_time").set(float(result.time[-1]))
    reg.gauge("fleet.committed").set(float(sum(result.committed)))
    reg.gauge("fleet.dropped").set(float(result.dropped))
    reg.gauge("fleet.discarded_stale").set(float(result.discarded_stale))
    reg.gauge("fleet.forced_flushes").set(float(result.forced_flushes))
    h = reg.histogram("fleet.staleness")
    for s, c in sorted(result.staleness_hist.items()):
        h.observe(float(s), n=int(c))
