"""Schema checker for obs artifacts (CI `obs-smoke` gate).

Validates the JSON artifact shapes this repo's tooling emits:

- **Chrome trace** (``obs/trace.py::Tracer.export_chrome``): top-level
  object with a ``traceEvents`` list; every event needs ``ph``/``pid``/
  ``name``, phase-specific fields (``ts``+``dur`` for X, ``ts`` for
  i/C, ``args.value`` numeric for C, known names for M), and
  non-negative microsecond timestamps.
- **Metrics snapshot** (``obs/metrics.py::Registry.write_snapshot``):
  ``{"ts": ..., "metrics": {name: {"kind": ...}}}`` with per-kind
  required numeric fields.
- **Analysis findings** (``python -m repro.analysis --json``): the
  contract linter's artifact — ``tool == "repro.analysis"``, numeric
  ``ts``, a findings list whose entries carry
  ``checker``/``path``/``line``/``severity``/``message``/``status``,
  and a summary consistent with the list.  Auto-detected via the
  ``tool`` field, or forced with ``--analysis``.
- **Analytics report** (``python -m repro.obs.report --json``):
  ``tool == "repro.obs.report"`` — per-round critical-path
  decompositions (numeric segment times, exact bits reconciliation
  verdict), span-tree rollups, and trajectory findings, with a summary
  consistent with the sections.  Auto-detected via the ``tool`` field.

CLI (exit 1 on any invalid file)::

    python -m repro.obs.validate trace.json metrics.json ...
    python -m repro.obs.validate --analysis findings.json
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Tuple

__all__ = ["validate_trace", "validate_metrics", "validate_analysis",
           "validate_report", "validate_file", "main"]

# s/t/f are Chrome flow events (causality arrows between slices).
_PHASES = {"X", "i", "I", "C", "M", "s", "t", "f"}
_FLOW_PHASES = {"s", "t", "f"}
_META_NAMES = {"process_name", "thread_name", "process_sort_index",
               "thread_sort_index", "process_labels"}
_KINDS = {"counter", "gauge", "histogram"}


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_trace(doc: Any) -> List[str]:
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["trace: top level must be an object"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["trace: missing 'traceEvents' list"]
    if not evs:
        errors.append("trace: 'traceEvents' is empty")
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int):
            errors.append(f"{where}: missing pid")
        if ph == "M":
            if ev.get("name") not in _META_NAMES:
                errors.append(f"{where}: unknown metadata {ev.get('name')!r}")
            continue
        if not isinstance(ev.get("tid"), int):
            errors.append(f"{where}: missing tid")
        if not _num(ev.get("ts")) or ev["ts"] < 0:
            errors.append(f"{where}: bad ts {ev.get('ts')!r}")
        if ph == "X" and (not _num(ev.get("dur")) or ev["dur"] < 0):
            errors.append(f"{where}: bad dur {ev.get('dur')!r}")
        if ph in _FLOW_PHASES:
            if not isinstance(ev.get("id"), int):
                errors.append(f"{where}: flow event needs integer 'id'")
            if ph == "f" and ev.get("bp") not in (None, "e"):
                errors.append(f"{where}: bad bp {ev.get('bp')!r}")
        if ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(_num(v) for v in args.values())):
                errors.append(f"{where}: counter needs numeric args")
    return errors


def validate_metrics(doc: Any) -> List[str]:
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["metrics: top level must be an object"]
    if not _num(doc.get("ts")):
        errors.append("metrics: missing numeric 'ts'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return errors + ["metrics: missing 'metrics' object"]
    for name, m in metrics.items():
        where = f"metrics[{name!r}]"
        if not isinstance(m, dict):
            errors.append(f"{where}: not an object")
            continue
        kind = m.get("kind")
        if kind not in _KINDS:
            errors.append(f"{where}: bad kind {kind!r}")
            continue
        if kind in ("counter", "gauge"):
            if not _num(m.get("value")):
                errors.append(f"{where}: missing numeric 'value'")
        else:
            if not isinstance(m.get("count"), int) or m["count"] < 0:
                errors.append(f"{where}: bad histogram count")
            if not _num(m.get("sum")):
                errors.append(f"{where}: bad histogram sum")
    return errors


_ANALYSIS_TOOL = "repro.analysis"
_SEVERITIES = {"error", "warn"}
_STATUSES = {"open", "suppressed", "baselined"}


def validate_analysis(doc: Any) -> List[str]:
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["analysis: top level must be an object"]
    if doc.get("tool") != _ANALYSIS_TOOL:
        errors.append(f"analysis: 'tool' must be {_ANALYSIS_TOOL!r}, "
                      f"got {doc.get('tool')!r}")
    if not _num(doc.get("ts")):
        errors.append("analysis: missing numeric 'ts'")
    if not isinstance(doc.get("version"), int):
        errors.append("analysis: missing integer 'version'")
    if not isinstance(doc.get("paths"), list):
        errors.append("analysis: missing 'paths' list")
    findings = doc.get("findings")
    if not isinstance(findings, list):
        return errors + ["analysis: missing 'findings' list"]
    by_status = {s: 0 for s in _STATUSES}
    for i, f in enumerate(findings):
        where = f"findings[{i}]"
        if not isinstance(f, dict):
            errors.append(f"{where}: not an object")
            continue
        for field in ("checker", "path", "message"):
            if not isinstance(f.get(field), str) or not f[field]:
                errors.append(f"{where}: missing '{field}'")
        if not isinstance(f.get("line"), int) or f["line"] < 1:
            errors.append(f"{where}: bad line {f.get('line')!r}")
        if f.get("severity") not in _SEVERITIES:
            errors.append(f"{where}: bad severity {f.get('severity')!r}")
        status = f.get("status")
        if status not in _STATUSES:
            errors.append(f"{where}: bad status {status!r}")
        else:
            by_status[status] += 1
    summary = doc.get("summary")
    if not isinstance(summary, dict):
        errors.append("analysis: missing 'summary' object")
    else:
        for field in ("files", "open", "errors", "warnings",
                      "suppressed", "baselined"):
            if not isinstance(summary.get(field), int) \
                    or summary[field] < 0:
                errors.append(f"analysis: summary.{field} must be a "
                              "non-negative integer")
        if isinstance(summary.get("open"), int) \
                and summary["open"] != by_status["open"]:
            errors.append(
                f"analysis: summary.open={summary['open']} but "
                f"{by_status['open']} open finding(s) listed")
    return errors


_REPORT_TOOL = "repro.obs.report"
_SEGMENTS = ("compute_us", "network_us", "buffer_wait_us",
             "forced_flush_us", "root_wait_us")
_FINDING_KINDS = {"regression", "improvement", "changepoint"}


def validate_report(doc: Any) -> List[str]:
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["report: top level must be an object"]
    if doc.get("tool") != _REPORT_TOOL:
        errors.append(f"report: 'tool' must be {_REPORT_TOOL!r}, "
                      f"got {doc.get('tool')!r}")
    if not _num(doc.get("ts")):
        errors.append("report: missing numeric 'ts'")
    if not isinstance(doc.get("version"), int):
        errors.append("report: missing integer 'version'")
    cp = doc.get("critical_path")
    if cp is not None:
        if not isinstance(cp, dict) \
                or not isinstance(cp.get("rounds"), list):
            errors.append("report: critical_path needs a 'rounds' list")
        else:
            for i, r in enumerate(cp["rounds"]):
                where = f"critical_path.rounds[{i}]"
                if not isinstance(r, dict):
                    errors.append(f"{where}: not an object")
                    continue
                if not isinstance(r.get("round"), int):
                    errors.append(f"{where}: missing integer 'round'")
                if not _num(r.get("total_us")) or r["total_us"] < 0:
                    errors.append(f"{where}: bad total_us")
                segs = r.get("segments")
                if not isinstance(segs, dict) \
                        or not all(_num(segs.get(k)) for k in _SEGMENTS):
                    errors.append(
                        f"{where}: segments must carry numeric "
                        + "/".join(_SEGMENTS))
            rec = cp.get("reconciliation")
            if rec is not None and (not isinstance(rec, dict)
                                    or not isinstance(
                                        rec.get("ledger_ok"), bool)):
                errors.append("report: reconciliation needs boolean "
                              "'ledger_ok'")
    rollup = doc.get("span_rollup")
    if rollup is not None:
        if not isinstance(rollup, list):
            errors.append("report: span_rollup must be a list")
        else:
            for i, row in enumerate(rollup):
                where = f"span_rollup[{i}]"
                if not isinstance(row, dict) \
                        or not isinstance(row.get("name"), str) \
                        or not isinstance(row.get("count"), int) \
                        or not _num(row.get("total_us")) \
                        or not _num(row.get("self_us")):
                    errors.append(f"{where}: needs name/count/total_us/"
                                  "self_us")
    traj = doc.get("trajectory")
    n_findings = 0
    if traj is not None:
        if not isinstance(traj, dict) \
                or not isinstance(traj.get("files"), list):
            errors.append("report: trajectory needs a 'files' list")
        else:
            for i, f in enumerate(traj["files"]):
                where = f"trajectory.files[{i}]"
                if not isinstance(f, dict) \
                        or not isinstance(f.get("path"), str) \
                        or not isinstance(f.get("entries"), int):
                    errors.append(f"{where}: needs path/entries")
                    continue
                findings = f.get("findings")
                if not isinstance(findings, list):
                    errors.append(f"{where}: missing 'findings' list")
                    continue
                for j, fd in enumerate(findings):
                    fwhere = f"{where}.findings[{j}]"
                    if not isinstance(fd, dict) \
                            or fd.get("kind") not in _FINDING_KINDS \
                            or not isinstance(fd.get("metric"), str) \
                            or not _num(fd.get("ratio")):
                        errors.append(f"{fwhere}: needs kind/metric/ratio")
                        continue
                    if fd["kind"] != "improvement":
                        n_findings += 1
    summary = doc.get("summary")
    if not isinstance(summary, dict):
        errors.append("report: missing 'summary' object")
    else:
        if not isinstance(summary.get("regressions"), int) \
                or summary["regressions"] < 0:
            errors.append("report: summary.regressions must be a "
                          "non-negative integer")
        elif traj is not None and isinstance(traj, dict) \
                and isinstance(traj.get("files"), list) \
                and summary["regressions"] != n_findings:
            errors.append(
                f"report: summary.regressions={summary['regressions']} "
                f"but {n_findings} regression/changepoint finding(s) "
                "listed")
    return errors


def validate_file(path: str, kind: str = "auto"
                  ) -> Tuple[str, List[str]]:
    """Auto-detect artifact kind (or force one); returns
    (kind, errors)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return "unknown", [f"{path}: unreadable: {e}"]
    if kind == "analysis":
        return "analysis", validate_analysis(doc)
    if isinstance(doc, dict) and "traceEvents" in doc:
        return "trace", validate_trace(doc)
    if isinstance(doc, dict) and doc.get("tool") == _ANALYSIS_TOOL:
        return "analysis", validate_analysis(doc)
    if isinstance(doc, dict) and doc.get("tool") == _REPORT_TOOL:
        return "report", validate_report(doc)
    return "metrics", validate_metrics(doc)


def main(argv: List[str]) -> int:
    kind = "auto"
    if "--analysis" in argv:
        argv = [a for a in argv if a != "--analysis"]
        kind = "analysis"
    if not argv:
        print("usage: python -m repro.obs.validate [--analysis] "
              "FILE [FILE ...]", file=sys.stderr)
        return 2
    failed = False
    for path in argv:
        kind, errors = validate_file(path, kind)
        if errors:
            failed = True
            print(f"INVALID {kind} {path}")
            for e in errors[:20]:
                print(f"  - {e}")
        else:
            print(f"ok {kind} {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
