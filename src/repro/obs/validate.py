"""Schema checker for obs artifacts (CI `obs-smoke` gate).

Validates the two JSON artifact shapes this package emits:

- **Chrome trace** (``obs/trace.py::Tracer.export_chrome``): top-level
  object with a ``traceEvents`` list; every event needs ``ph``/``pid``/
  ``name``, phase-specific fields (``ts``+``dur`` for X, ``ts`` for
  i/C, ``args.value`` numeric for C, known names for M), and
  non-negative microsecond timestamps.
- **Metrics snapshot** (``obs/metrics.py::Registry.write_snapshot``):
  ``{"ts": ..., "metrics": {name: {"kind": ...}}}`` with per-kind
  required numeric fields.

CLI (exit 1 on any invalid file)::

    python -m repro.obs.validate trace.json metrics.json ...
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Tuple

__all__ = ["validate_trace", "validate_metrics", "validate_file", "main"]

_PHASES = {"X", "i", "I", "C", "M"}
_META_NAMES = {"process_name", "thread_name", "process_sort_index",
               "thread_sort_index", "process_labels"}
_KINDS = {"counter", "gauge", "histogram"}


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_trace(doc: Any) -> List[str]:
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["trace: top level must be an object"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["trace: missing 'traceEvents' list"]
    if not evs:
        errors.append("trace: 'traceEvents' is empty")
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int):
            errors.append(f"{where}: missing pid")
        if ph == "M":
            if ev.get("name") not in _META_NAMES:
                errors.append(f"{where}: unknown metadata {ev.get('name')!r}")
            continue
        if not isinstance(ev.get("tid"), int):
            errors.append(f"{where}: missing tid")
        if not _num(ev.get("ts")) or ev["ts"] < 0:
            errors.append(f"{where}: bad ts {ev.get('ts')!r}")
        if ph == "X" and (not _num(ev.get("dur")) or ev["dur"] < 0):
            errors.append(f"{where}: bad dur {ev.get('dur')!r}")
        if ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(_num(v) for v in args.values())):
                errors.append(f"{where}: counter needs numeric args")
    return errors


def validate_metrics(doc: Any) -> List[str]:
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["metrics: top level must be an object"]
    if not _num(doc.get("ts")):
        errors.append("metrics: missing numeric 'ts'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return errors + ["metrics: missing 'metrics' object"]
    for name, m in metrics.items():
        where = f"metrics[{name!r}]"
        if not isinstance(m, dict):
            errors.append(f"{where}: not an object")
            continue
        kind = m.get("kind")
        if kind not in _KINDS:
            errors.append(f"{where}: bad kind {kind!r}")
            continue
        if kind in ("counter", "gauge"):
            if not _num(m.get("value")):
                errors.append(f"{where}: missing numeric 'value'")
        else:
            if not isinstance(m.get("count"), int) or m["count"] < 0:
                errors.append(f"{where}: bad histogram count")
            if not _num(m.get("sum")):
                errors.append(f"{where}: bad histogram sum")
    return errors


def validate_file(path: str) -> Tuple[str, List[str]]:
    """Auto-detect artifact kind; returns (kind, errors)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return "unknown", [f"{path}: unreadable: {e}"]
    if isinstance(doc, dict) and "traceEvents" in doc:
        return "trace", validate_trace(doc)
    return "metrics", validate_metrics(doc)


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python -m repro.obs.validate FILE [FILE ...]",
              file=sys.stderr)
        return 2
    failed = False
    for path in argv:
        kind, errors = validate_file(path)
        if errors:
            failed = True
            print(f"INVALID {kind} {path}")
            for e in errors[:20]:
                print(f"  - {e}")
        else:
            print(f"ok {kind} {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
