"""Span tracing with dual clocks and a Chrome trace-event exporter.

Design (DESIGN.md §13):

- **Spans** are nestable timed regions opened with :func:`span` (a
  context manager) or the :func:`traced` decorator.  Each span records
  wall time from ``time.perf_counter`` relative to the tracer's origin.
- **Dual clocks.**  The FL runtimes are event-driven simulations with a
  *virtual* clock (seconds of simulated time).  A runtime publishes its
  clock via :func:`set_virtual_time`; while a virtual time is known,
  every span/instant/counter is emitted twice — once on the wall-clock
  process (pid 1) and once on the virtual-clock process (pid 2) with
  ``ts = virtual_seconds * 1e6``.  Virtual-clock events are
  replay-deterministic: the same seed produces byte-identical virtual
  tracks, whatever the host machine is doing.
- **Flow links.**  :func:`flow_start` / :func:`flow_step` /
  :func:`flow_end` emit Chrome flow events (``ph`` s/t/f sharing an
  ``id``), which Perfetto renders as causality arrows between the
  enclosing slices.  The FL runtimes thread a flow id per contribution
  (client dispatch → edge flush → root commit) so a committed round can
  be walked back to the exact client/hop chain that bounded it — the
  input the critical-path engine in ``obs/analyze`` consumes.
- **Disabled fast path.**  With no tracer installed the module-level
  helpers return a shared no-op span / return immediately — no
  allocation, no branching beyond one global load — so instrumentation
  can stay unconditional on hot paths (benchmarks/bench_obs.py asserts
  the cost is < 3% of a fused serve pass).
- **Bounded memory.**  The event buffer is capped (``max_events``,
  default 1e6).  Once full, *new* events are dropped — drop-newest, so
  the retained prefix stays a consistent trace with no dangling flow
  arrows into the void of evicted history — and counted in
  ``Tracer.dropped``, mirrored to the ``obs.dropped_events`` registry
  counter and the export metadata.  Multi-hour fleet runs therefore
  plateau at the cap instead of growing without bound.
- **Export** is the Chrome trace-event JSON format (``"traceEvents"``
  list of ``ph`` X/i/C/M/s/t/f events, microsecond timestamps),
  loadable in Perfetto (https://ui.perfetto.dev) or
  ``chrome://tracing``.

The module is stdlib-only.  :func:`kernel_scope` lazily imports jax to
wrap Pallas kernel launch sites in ``jax.named_scope`` so kernels show
up named in ``jax.profiler`` device traces; it degrades to a no-op
when jax is absent.

Event appends are plain list appends (atomic under CPython); the
runtimes instrumented here are single-threaded per process.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Tracer", "configure", "install", "uninstall", "get_tracer",
    "active", "span", "instant", "counter", "set_virtual_time",
    "clear_virtual_time", "flow_start", "flow_step", "flow_end",
    "traced", "kernel_scope", "export",
]

WALL_PID = 1      # wall-clock process in the exported trace
VIRTUAL_PID = 2   # virtual-clock (simulator) process

# Event-buffer cap (satellite: bounded tracer memory).  Generous — a
# traced fleet smoke is ~1e3 events — but finite: at ~200 bytes/event
# the worst case is ~200 MB, not an unbounded multi-hour leak.
DEFAULT_MAX_EVENTS = 1_000_000


class _NullSpan:
    """Shared no-op span returned when tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        pass


_NULL_SPAN = _NullSpan()


class Span:
    """A single open span; created via :meth:`Tracer.span`."""
    __slots__ = ("_tracer", "name", "cat", "track", "args", "_t0", "_v0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 track: Optional[str], args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args
        self._t0 = 0.0
        self._v0: Optional[float] = None

    def set(self, **args):
        """Attach/overwrite span args (shown in the trace viewer)."""
        self.args.update(args)

    def __enter__(self):
        self._t0 = time.perf_counter()
        self._v0 = self._tracer.virtual_now
        return self

    def __exit__(self, *exc):
        self._tracer._finish_span(self)
        return False


class Tracer:
    """Collects trace events; export with :meth:`export_chrome`."""

    def __init__(self, meta: Optional[Dict[str, Any]] = None,
                 max_events: int = DEFAULT_MAX_EVENTS):
        self._origin = time.perf_counter()
        self.virtual_now: Optional[float] = None
        self.events: List[Dict[str, Any]] = []
        self.meta: Dict[str, Any] = dict(meta or {})
        self.max_events = int(max_events)
        self.dropped = 0
        self._drop_counter: Optional[Any] = None
        self._tids: Dict[str, int] = {}

    # -- clocks -----------------------------------------------------
    def wall_us(self) -> float:
        return (time.perf_counter() - self._origin) * 1e6

    def set_virtual_time(self, t: float) -> None:
        self.virtual_now = float(t)

    def clear_virtual_time(self) -> None:
        """Forget the virtual clock: subsequent events (and spans that
        *close* after this) emit on the wall pid only.  Runtimes call
        this on exit so a later run on the same tracer cannot inherit a
        stale simulated clock."""
        self.virtual_now = None

    # -- tracks -----------------------------------------------------
    def _tid(self, track: Optional[str]) -> int:
        name = track or "main"
        tid = self._tids.get(name)
        if tid is None:
            tid = len(self._tids)
            self._tids[name] = tid
        return tid

    # -- emit -------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        """Append one event, honoring the buffer cap (drop-newest)."""
        if len(self.events) >= self.max_events:
            if self.dropped == 0:
                # lazy: keep the hot no-drop path free of the import
                from repro.obs import metrics as _metrics
                self._drop_counter = _metrics.counter("obs.dropped_events")
            self.dropped += 1
            self._drop_counter.inc()
            return
        self.events.append(ev)

    def span(self, name: str, cat: str = "", track: Optional[str] = None,
             **args) -> Span:
        return Span(self, name, cat, track, args)

    def _finish_span(self, sp: Span) -> None:
        t1 = time.perf_counter()
        ts = (sp._t0 - self._origin) * 1e6
        dur = (t1 - sp._t0) * 1e6
        tid = self._tid(sp.track)
        ev: Dict[str, Any] = {"ph": "X", "pid": WALL_PID, "tid": tid,
                              "name": sp.name, "ts": ts, "dur": dur}
        if sp.cat:
            ev["cat"] = sp.cat
        if sp.args:
            ev["args"] = sp.args
        self._emit(ev)
        if sp._v0 is not None and self.virtual_now is not None:
            vts = sp._v0 * 1e6
            # clamp: zero-width virtual spans would be invisible
            vdur = max((self.virtual_now - sp._v0) * 1e6, 1.0)
            vev = dict(ev)
            vev["pid"] = VIRTUAL_PID
            vev["ts"] = vts
            vev["dur"] = vdur
            self._emit(vev)

    def instant(self, name: str, track: Optional[str] = None, **args):
        tid = self._tid(track)
        ev: Dict[str, Any] = {"ph": "i", "pid": WALL_PID, "tid": tid,
                              "name": name, "ts": self.wall_us(), "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)
        if self.virtual_now is not None:
            vev = dict(ev)
            vev["pid"] = VIRTUAL_PID
            vev["ts"] = self.virtual_now * 1e6
            self._emit(vev)

    def counter(self, name: str, value: float, track: Optional[str] = None):
        ev: Dict[str, Any] = {"ph": "C", "pid": WALL_PID,
                              "tid": self._tid(track), "name": name,
                              "ts": self.wall_us(),
                              "args": {"value": float(value)}}
        self._emit(ev)
        if self.virtual_now is not None:
            vev = dict(ev)
            vev["pid"] = VIRTUAL_PID
            vev["ts"] = self.virtual_now * 1e6
            self._emit(vev)

    def _flow(self, ph: str, name: str, flow_id: int,
              track: Optional[str], args: Dict[str, Any]) -> None:
        tid = self._tid(track)
        ev: Dict[str, Any] = {"ph": ph, "pid": WALL_PID, "tid": tid,
                              "name": name, "cat": "flow",
                              "id": int(flow_id), "ts": self.wall_us()}
        if ph == "f":
            ev["bp"] = "e"   # bind to enclosing slice, not the next one
        if args:
            ev["args"] = args
        self._emit(ev)
        if self.virtual_now is not None:
            vev = dict(ev)
            vev["pid"] = VIRTUAL_PID
            vev["ts"] = self.virtual_now * 1e6
            self._emit(vev)

    def flow_start(self, name: str, flow_id: int,
                   track: Optional[str] = None, **args) -> None:
        """Open a flow arrow (ph "s") anchored at the current clocks."""
        self._flow("s", name, flow_id, track, args)

    def flow_step(self, name: str, flow_id: int,
                  track: Optional[str] = None, **args) -> None:
        """Continue a flow (ph "t") through an intermediate hop."""
        self._flow("t", name, flow_id, track, args)

    def flow_end(self, name: str, flow_id: int,
                 track: Optional[str] = None, **args) -> None:
        """Terminate a flow (ph "f", bp "e") at its consuming slice."""
        self._flow("f", name, flow_id, track, args)

    # -- export -----------------------------------------------------
    def _metadata_events(self) -> List[Dict[str, Any]]:
        evs: List[Dict[str, Any]] = [
            {"ph": "M", "pid": WALL_PID, "tid": 0, "name": "process_name",
             "args": {"name": "wall"}},
            {"ph": "M", "pid": VIRTUAL_PID, "tid": 0, "name": "process_name",
             "args": {"name": "virtual"}},
        ]
        for track, tid in self._tids.items():
            for pid in (WALL_PID, VIRTUAL_PID):
                evs.append({"ph": "M", "pid": pid, "tid": tid,
                            "name": "thread_name", "args": {"name": track}})
        return evs

    def to_chrome(self) -> Dict[str, Any]:
        meta = dict(self.meta)
        if self.dropped:
            meta["dropped_events"] = self.dropped
        return {"traceEvents": self._metadata_events() + self.events,
                "displayTimeUnit": "ms",
                "metadata": meta}

    def export_chrome(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# ---------------------------------------------------------------------
# module-level API (the instrumented code uses only these)
# ---------------------------------------------------------------------
_tracer: Optional[Tracer] = None


def install(tracer: Tracer) -> Tracer:
    global _tracer
    _tracer = tracer
    return tracer


def uninstall() -> Optional[Tracer]:
    global _tracer
    t, _tracer = _tracer, None
    return t


def configure(meta: Optional[Dict[str, Any]] = None,
              max_events: int = DEFAULT_MAX_EVENTS) -> Tracer:
    """Create and install a fresh global tracer."""
    return install(Tracer(meta=meta, max_events=max_events))


def get_tracer() -> Optional[Tracer]:
    return _tracer


def active() -> bool:
    return _tracer is not None


def span(name: str, cat: str = "", track: Optional[str] = None, **args):
    """Open a span on the installed tracer (no-op span when disabled)."""
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, cat, track, **args)


def instant(name: str, track: Optional[str] = None, **args) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, track, **args)


def counter(name: str, value: float, track: Optional[str] = None) -> None:
    t = _tracer
    if t is not None:
        t.counter(name, value, track)


def set_virtual_time(t_virtual: float) -> None:
    t = _tracer
    if t is not None:
        t.set_virtual_time(t_virtual)


def clear_virtual_time() -> None:
    t = _tracer
    if t is not None:
        t.clear_virtual_time()


def flow_start(name: str, flow_id: int, track: Optional[str] = None,
               **args) -> None:
    t = _tracer
    if t is not None:
        t.flow_start(name, flow_id, track, **args)


def flow_step(name: str, flow_id: int, track: Optional[str] = None,
              **args) -> None:
    t = _tracer
    if t is not None:
        t.flow_step(name, flow_id, track, **args)


def flow_end(name: str, flow_id: int, track: Optional[str] = None,
             **args) -> None:
    t = _tracer
    if t is not None:
        t.flow_end(name, flow_id, track, **args)


def traced(name: Optional[str] = None, cat: str = "",
           track: Optional[str] = None):
    """Decorator form of :func:`span`."""
    def deco(fn):
        label = name or fn.__qualname__

        def wrapper(*a, **kw):
            with span(label, cat, track):
                return fn(*a, **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__wrapped__ = fn
        return wrapper
    return deco


def kernel_scope(name: str):
    """Annotate a Pallas kernel launch site.

    Returns ``jax.named_scope("repro.kernel.<name>")`` so the kernel is
    attributable in ``jax.profiler`` device traces (named_scope works
    under jit tracing, unlike runtime TraceAnnotation).  Degrades to a
    no-op context when jax is unavailable, keeping the obs core
    stdlib-only.
    """
    try:
        import jax
    except Exception:      # pragma: no cover - jax is present in CI
        return _NULL_SPAN
    return jax.named_scope(f"repro.kernel.{name}")


def export(path: str) -> Optional[str]:
    """Export the installed tracer's events to ``path`` (Chrome JSON)."""
    t = _tracer
    if t is None:
        return None
    return t.export_chrome(path)
