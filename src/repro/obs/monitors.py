"""Live invariant monitors — test-suite invariants promoted to runtime.

Each monitor is a pure check returning a :class:`MonitorResult`;
:func:`emit` turns failures into structured :class:`ObsWarning`
warnings plus trace instants and ``obs.monitor_*`` counters.  The
instrumented runtimes run monitors only in traced runs (tracing off =
zero cost), but the checks are also importable directly by tests and
benches.

Monitors (DESIGN.md §13):

- ``fleet_ledger``      — wire-bits reconciliation:
  ``bits_cum[-1] == tier_bits.sum()`` and the edge/root hops equal the
  message-log total (tests/test_tree_invariants.py property a).
- ``pool_conservation`` — page refcount conservation: held + free ==
  num_pages and no page is both free and referenced (the non-asserting
  twin of ``PagePool.check_invariants``).
- ``hops_monotone``     — every ``CommitRecord``'s hop stamps are
  non-decreasing and ``compose_hops`` telescopes to the stamped
  staleness (tests/test_tree_invariants.py property b).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = [
    "ObsWarning", "MonitorResult", "check_fleet_ledger",
    "check_pool_conservation", "check_hops_monotone", "emit",
    "run_fleet_monitors",
]


class ObsWarning(UserWarning):
    """A live monitor found an invariant violation in a traced run."""


@dataclasses.dataclass(frozen=True)
class MonitorResult:
    monitor: str
    ok: bool
    detail: Dict[str, Any]

    def message(self) -> str:
        kv = " ".join(f"{k}={v}" for k, v in self.detail.items())
        state = "ok" if self.ok else "VIOLATED"
        return f"monitor[{self.monitor}] {state}: {kv}"


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-6)


def check_fleet_ledger(result: Any) -> MonitorResult:
    """``bits_cum[-1] == tier_bits.sum()`` and message log reconciles."""
    tier_total = float(np.sum(np.asarray(result.tier_bits)))
    bits_final = float(result.bits_cum[-1]) if len(result.bits_cum) else 0.0
    # every flushed message eventually arrives (the drain loop runs the
    # heap dry), and each arrival at tier k prices hop k+1 — so hops
    # 1.. must equal the message-log total
    msg_total = sum(float(m.bits) for m in result.message_log)
    upper_total = float(np.sum(np.asarray(result.tier_bits)[1:]))
    ok = _close(tier_total, bits_final) and _close(upper_total, msg_total)
    return MonitorResult("fleet_ledger", ok, {
        "tier_bits_sum": tier_total, "bits_cum_final": bits_final,
        "upper_hops": upper_total, "message_log_bits": msg_total})


def check_pool_conservation(pool: Any) -> MonitorResult:
    """held + free == num_pages; no page both free and referenced."""
    free = set(pool._free)
    held = sum(1 for r in pool._ref if r > 0)
    referenced_free = sorted(p for p in free if pool._ref[p] > 0)
    ok = (held + len(free) == pool.num_pages) and not referenced_free
    return MonitorResult("pool_conservation", ok, {
        "held": held, "free": len(free), "num_pages": pool.num_pages,
        "referenced_free": referenced_free[:10]})


def check_hops_monotone(commit_log: Iterable[Any]) -> MonitorResult:
    """Hop stamps non-decreasing; composed staleness == stamped."""
    from repro.fl.staleness import compose_hops
    checked = 0
    bad: List[Dict[str, Any]] = []
    for rec in commit_log:
        checked += 1
        try:
            total, _ = compose_hops(rec.dispatch_round,
                                    [r for _, r in rec.hops],
                                    rec.commit_round)
        except ValueError as e:
            bad.append({"client": rec.client, "error": str(e)})
            continue
        if total != rec.staleness:
            bad.append({"client": rec.client, "composed": total,
                        "stamped": rec.staleness})
    return MonitorResult("hops_monotone", not bad,
                         {"checked": checked, "violations": bad[:10],
                          "n_violations": len(bad)})


def emit(results: Iterable[MonitorResult],
         registry: Optional[_metrics.Registry] = None,
         warn: bool = True) -> List[MonitorResult]:
    """Record monitor outcomes: counters always, warnings + trace
    instants on violation.  Returns the results for callers to inspect."""
    reg = registry or _metrics.get_registry()
    out = []
    for res in results:
        out.append(res)
        reg.counter("obs.monitor_checks").inc()
        if not res.ok:
            reg.counter("obs.monitor_failures").inc()
            _trace.instant(f"monitor.{res.monitor}", track="monitors",
                           **{k: repr(v) for k, v in res.detail.items()})
            if warn:
                warnings.warn(ObsWarning(res.message()), stacklevel=2)
    return out


def run_fleet_monitors(result: Any,
                       registry: Optional[_metrics.Registry] = None
                       ) -> List[MonitorResult]:
    """The end-of-run monitor set for a ``FleetRunResult``."""
    return emit([check_fleet_ledger(result),
                 check_hops_monotone(result.commit_log)],
                registry=registry)
