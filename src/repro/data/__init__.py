"""repro.data substrate."""
