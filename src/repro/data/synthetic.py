"""Synthetic data pipeline.

Deterministic, seeded, infinite streams shaped for the DASHA-PP node
layout: every batch leaf carries a leading ``num_nodes`` dimension
(one node = one data-mesh slice; see DESIGN.md §5), i.e. tokens are
``(num_nodes, per_node_batch, seq_len)``.

Heterogeneity knob: each node draws from its own unigram distribution
(Zipf with node-specific permutation), giving genuinely different
``f_i`` across nodes — the regime the paper targets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    num_nodes: int
    vocab_size: int
    zipf_a: float = 1.2
    heterogeneous: bool = True
    seed: int = 0

    @property
    def per_node(self) -> int:
        if self.global_batch % self.num_nodes:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"num_nodes {self.num_nodes}")
        return self.global_batch // self.num_nodes


def _node_unigrams(cfg: DataConfig) -> np.ndarray:
    """(num_nodes, vocab) sampling probabilities."""
    rng = np.random.default_rng(cfg.seed)
    base = 1.0 / np.arange(1, cfg.vocab_size + 1) ** cfg.zipf_a
    base /= base.sum()
    if not cfg.heterogeneous:
        return np.tile(base, (cfg.num_nodes, 1))
    probs = np.empty((cfg.num_nodes, cfg.vocab_size))
    for i in range(cfg.num_nodes):
        probs[i] = base[rng.permutation(cfg.vocab_size)]
    return probs


def token_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": (n, per_node, T) int32} forever."""
    probs = _node_unigrams(cfg)
    cum = np.cumsum(probs, axis=1)
    rng = np.random.default_rng(cfg.seed + 1)
    n, b, t = cfg.num_nodes, cfg.per_node, cfg.seq_len
    while True:
        u = rng.random((n, b, t))
        toks = np.empty((n, b, t), np.int32)
        for i in range(n):
            toks[i] = np.searchsorted(cum[i], u[i]).astype(np.int32)
        yield {"tokens": toks}


def make_batch(arch: ArchConfig, data: DataConfig, step: int = 0,
               dtype=None) -> Dict[str, np.ndarray]:
    """One batch with the right modality fields for ``arch`` (node-major
    layout).  Cheap and deterministic — used by tests, examples, and the
    sharded trainer."""
    rng = np.random.default_rng(data.seed + 7919 * step)
    n, b, t = data.num_nodes, data.per_node, data.seq_len
    dt = np.dtype(dtype or arch.dtype)
    batch: Dict[str, np.ndarray] = {}
    if arch.frontend == "audio":
        batch["embeds"] = rng.standard_normal(
            (n, b, t, arch.d_model)).astype(dt)
        batch["targets"] = rng.integers(
            0, arch.vocab_size, (n, b, t)).astype(np.int32)
    elif arch.frontend == "vision":
        batch["embeds"] = rng.standard_normal(
            (n, b, arch.frontend_tokens, arch.d_model)).astype(dt)
        batch["tokens"] = rng.integers(
            0, arch.vocab_size, (n, b, t)).astype(np.int32)
    else:
        batch["tokens"] = rng.integers(
            0, arch.vocab_size, (n, b, t)).astype(np.int32)
    return batch
