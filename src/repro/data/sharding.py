"""Batch placement helpers: node-major batches onto the mesh."""
from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def batch_specs(batch: Any, data_axes: Sequence[str]) -> Any:
    """Leading node dim over the data axes, rest replicated/model-free."""
    lead = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)

    def spec(leaf):
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def place_batch(batch: Any, mesh: Mesh, data_axes: Sequence[str]) -> Any:
    specs = batch_specs(batch, data_axes)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, specs)
