"""Batched decode serving: the ``serve_step`` the decode input-shapes
lower, plus a small request-batching driver for the serving example.

``serve_step(params, tokens, state)`` advances EVERY sequence in the
batch by one token against its KV cache (or SSM state), the standard
continuous-batching inner loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import DecodeState, Model

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class DecodeServer:
    """Greedy batched decoding with static batch slots (padding with an
    idle request keeps shapes static)."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_seq_len: int):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq_len
        self.state = model.init_decode_state(batch_size, max_seq_len,
                                             position=0)
        self._step = jax.jit(model.serve_step)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._next_tok = np.zeros((batch_size, 1), np.int32)

    def prefill(self, slot: int, req: Request) -> None:
        """Token-by-token prefill (teacher-forcing the prompt).  A bulk
        prefill path exists via Model.forward; this keeps the example
        dependency-free."""
        self.slots[slot] = req
        for t in req.prompt:
            self._next_tok[slot, 0] = t
            logits, self.state = self._step(
                self.params, jnp.asarray(self._next_tok), self.state)
        self._next_tok[slot, 0] = int(np.argmax(
            np.asarray(logits[slot])))

    def step(self) -> None:
        logits, self.state = self._step(
            self.params, jnp.asarray(self._next_tok), self.state)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.slots):
            if req is not None and not req.done:
                req.generated.append(int(self._next_tok[i, 0]))
                self._next_tok[i, 0] = nxt[i]

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        for i in range(min(self.batch, len(pending))):
            self.prefill(i, pending.pop(0))
        while any(r is not None and not r.done for r in self.slots):
            self.step()
            for i, r in enumerate(self.slots):
                if r is not None and r.done and pending:
                    self.prefill(i, pending.pop(0))
        return requests
