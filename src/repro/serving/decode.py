"""Batched decode serving: the ``serve_step`` the decode input-shapes
lower, plus a small request-batching driver for the serving example.

``serve_step(params, tokens, state, update)`` advances the unmasked
sequences in the batch by one token against their KV caches (or SSM
states), the standard continuous-batching inner loop.  The server keeps
PER-SLOT cache positions (``DecodeState.position`` as a (B,) vector)
so that

* prefilling a freed slot touches ONLY that slot — in-flight decodes on
  other slots keep their caches byte-identical (the ``update`` mask
  routes masked slots' cache writes to a dropped row);
* a reused slot restarts its ring position at 0 instead of inheriting
  the previous occupant's offset (which would burn cache capacity and
  eventually wrap mid-sequence), and its cache rows — attention KV AND
  recurrent (SSM/xLSTM) states, which have no positions to mask — are
  restored to their initial values, so nothing of the old sequence
  leaks into the new request;
* an empty prompt is decoded from a BOS-0 seed token instead of
  reading logits that were never produced.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import DecodeState, Model
from repro.obs import trace as obs_trace

Array = jax.Array

BOS_TOKEN = 0   # seed for empty prompts


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class DecodeServer:
    """Greedy batched decoding with static batch slots (padding with an
    idle request keeps shapes static)."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_seq_len: int):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq_len
        self.state = model.init_decode_state(
            batch_size, max_seq_len, position=0)._replace(
            position=jnp.zeros((batch_size,), jnp.int32))
        # pristine copy of the initial caches: slot reuse restores its
        # rows from here — the ring's wrap accounting hides old KV, but
        # recurrent (SSM) states have no positions and would otherwise
        # leak the previous occupant's hidden state into the new request
        self._init_caches = self.state.caches
        self._step = jax.jit(model.serve_step)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._next_tok = np.zeros((batch_size, 1), np.int32)
        self.decode_seconds = 0.0   # wall time of decode step() passes
        self.decode_tokens = 0      # tokens generated in those passes

    def reset_perf_counters(self) -> None:
        """Zero the decode-throughput counters: benches warm the jit
        cache with a throwaway run, then reset and measure."""
        self.decode_seconds = 0.0
        self.decode_tokens = 0

    def place_state(self, shardings) -> None:
        """Move the decode state onto mesh shardings
        (launch/specs.decode_state_specs) — keeps the pristine
        reset-copy alias pointing at the placed caches, which slot
        reuse depends on."""
        self.state = jax.device_put(self.state, shardings)
        self._init_caches = self.state.caches

    def _slot_positions(self) -> np.ndarray:
        return np.array(self.state.position)   # owned, writable copy

    def _reset_slot(self, slot: int) -> None:
        """Restore one slot's cache rows to their initial (empty) state
        and restart its ring position at 0."""
        axis = 1 if self.model.scan else 0   # scan stacks a layer dim

        def reset(cur, init):
            idx = [slice(None)] * cur.ndim
            idx[axis] = slot
            return cur.at[tuple(idx)].set(init[tuple(idx)])

        caches = jax.tree.map(reset, self.state.caches, self._init_caches)
        pos = self._slot_positions()
        pos[slot] = 0
        self.state = DecodeState(caches=caches, position=jnp.asarray(pos))

    def prefill(self, slot: int, req: Request) -> None:
        """Token-by-token prefill (teacher-forcing the prompt) MASKED to
        ``slot`` — other slots' caches, recurrent states, and positions
        are untouched, so calling this mid-decode (the continuous-
        batching refill) cannot corrupt in-flight sequences.  A bulk
        prefill path exists via Model.forward; this keeps the example
        dependency-free."""
        with obs_trace.span("serve.dense.prefill", track="serve",
                            uid=req.uid, slot=slot,
                            tokens=len(req.prompt) or 1):
            self.slots[slot] = req
            # reuse: ring position restarts at 0 AND the slot's cache
            # rows (attention KV and recurrent states alike) return to
            # their initial values — nothing of the previous occupant
            # survives
            self._reset_slot(slot)
            upd = np.zeros((self.batch,), bool)
            upd[slot] = True
            upd = jnp.asarray(upd)
            prompt = req.prompt if req.prompt else [BOS_TOKEN]
            for t in prompt:
                self._next_tok[slot, 0] = t
                # snapshot with a SYNCHRONOUS numpy copy before handing
                # the buffer to jax: jnp.array's copy is part of the
                # async dispatch, so mutating _next_tok on the next
                # iteration could still race with it (observed as
                # run-to-run decode divergence on the CPU backend; the
                # jnp.asarray aliasing was only the larger half of the
                # same bug)
                logits, self.state = self._step(
                    self.params, jnp.asarray(self._next_tok.copy()),
                    self.state, upd)
            self._next_tok[slot, 0] = int(np.argmax(
                np.asarray(logits[slot])))

    def step(self) -> None:
        active = np.asarray([r is not None and not r.done
                             for r in self.slots])
        if not active.any():
            return
        t0 = time.perf_counter()
        with obs_trace.span("serve.dense.pass", track="serve",
                            active=int(active.sum())):
            logits, self.state = self._step(
                self.params, jnp.asarray(self._next_tok.copy()),
                self.state,
                jnp.asarray(active))  # synchronous host copy, see prefill
            # repro: ignore[host-sync] -- greedy decode IS the sync
            # point: the argmax token feeds the next step's inputs
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.decode_seconds += time.perf_counter() - t0
        for i, req in enumerate(self.slots):
            if active[i]:
                req.generated.append(int(self._next_tok[i, 0]))
                self._next_tok[i, 0] = nxt[i]
                self.decode_tokens += 1

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        for i in range(min(self.batch, len(pending))):
            self.prefill(i, pending.pop(0))
        while any(r is not None and not r.done for r in self.slots):
            self.step()
            for i, r in enumerate(self.slots):
                if r is not None and r.done and pending:
                    self.prefill(i, pending.pop(0))
        return requests
