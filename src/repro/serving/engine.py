"""PagedEngine: continuous-batching serving over the page pool
(DESIGN.md §11).

The dense :class:`~repro.serving.decode.DecodeServer` pre-allocates a
``(B, max_seq)`` ring cache per slot and teacher-forces prompts
token-by-token — memory scales with the worst-case sequence and prompt
ingestion costs O(prompt) serve passes.  The paged engine replaces both:

* **memory** — attention KV lives in a shared :class:`PagePool`; a
  request holds exactly ``ceil(tokens / page_size)`` pages, prompt
  prefixes shared copy-on-write across requests;
* **prefill** — chunked (attention-only archs): prompt tokens ride the
  fused multi-query decode launch a few at a time, so several waiting
  prompts fold into the SAME pass that advances live decodes — no
  dedicated prefill forward at all.  Bulk (recurrent archs, or
  ``prefill_chunk_tokens=0``): ONE ``Model.prefill`` forward per
  prompt, padded to a length bucket so jit compiles once per bucket,
  scattered into the request's pages;
* **decode** — every pass runs ONE fused launch over all active slots
  against a page table sliced to the smallest power-of-two width
  covering the pages actually in use, so attention work scales with
  live context instead of ``max_seq`` (the dense server always pays
  worst case);
* **capacity** — admission queues until pages are available, and a
  pass that cannot grow preempts the lowest-priority (latest admitted)
  request — even mid-chunked-prefill: its pages return to the pool and
  it re-queues with ``prompt + generated`` as the new prompt, which
  under greedy decoding reproduces the evicted trajectory exactly.

Parity anchor: with ``page_size >= max_seq`` (one page per request),
``num_pages = batch`` and greedy sampling, the decode read degenerates
to the dense masked attention over a contiguous cache row, and
:meth:`run` reproduces ``DecodeServer.run`` token-for-token on the same
requests, in every mode (tests/test_paged_engine.py,
tests/test_chunked_prefill.py).  SSM/hybrid archs keep their recurrent
state dense in the engine — only attention caches page — and serve via
bulk admission (a recurrent scan cannot mask a mid-chunk tail).

TTFT accounting: ``first_token_at`` is stamped at the pass that EMITS
the request's first logit — the bulk-prefill forward, or the chunked
pass that feeds the prompt's last token — never at admission.

Scheduling is host-side Python (like the pool): the device sees one
jitted fused pass per clock tick (plus one ``prefill`` + page-scatter
per bulk admission).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, PagedDecodeState, map_cache_tree
from repro.obs import metrics as obs_metrics
from repro.obs import monitors as obs_monitors
from repro.obs import trace as obs_trace
from repro.serving.decode import BOS_TOKEN, Request
from repro.serving.pages import PagePool, PrefixCache

Array = jax.Array

DEFAULT_CHUNK_TOKENS = 16


def attention_cache_bytes(caches) -> int:
    """Bytes held by every attention-cache leaf (KVCache/MLACache) of a
    decode-state tree — the one cache-accounting rule, shared by the
    engine metrics and bench_serving's dense baseline."""
    total = 0

    def count(c):
        nonlocal total
        total += sum(int(x.nbytes) for x in c)
        return c

    map_cache_tree(caches, on_attention=count, on_leaf=lambda c: c)
    return total


def default_buckets(max_seq: int) -> List[int]:
    """Powers of two up to ``max_seq`` (inclusive of max_seq itself):
    one jit compile per bucket instead of one per distinct length."""
    out = []
    b = 8
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return out


def _pow2_at_least(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


@dataclasses.dataclass
class RequestStats:
    """Per-request lifecycle in serve-pass clock ticks (one tick = one
    model pass: a bulk prefill or a fused batched pass)."""
    uid: int
    enqueued_at: int
    admitted_at: Optional[int] = None
    first_token_at: Optional[int] = None
    finished_at: Optional[int] = None
    prefill_calls: int = 0
    prefill_tokens: int = 0
    shared_tokens: int = 0
    preemptions: int = 0

    @property
    def ttft(self) -> Optional[int]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.enqueued_at

    @property
    def latency(self) -> Optional[int]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.enqueued_at


class PagedEngine:
    """Continuous-batching scheduler over a paged KV cache."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_seq_len: int, page_size: Optional[int] = None,
                 num_pages: Optional[int] = None, use_kernel: bool = False,
                 share_prefixes: bool = True, trace_logits: bool = False,
                 prefill_chunk_tokens: Optional[int] = None,
                 bucket_sizes: Optional[Sequence[int]] = None):
        cfg = model.cfg
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode")
        if cfg.frontend is not None:
            raise ValueError("paged engine serves token-frontend archs; "
                             f"{cfg.name} needs stub embeds (use the dense "
                             "DecodeServer)")
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq_len
        self.page_size = page_size or min(16, max_seq_len)
        self.max_pages = -(-max_seq_len // self.page_size)
        # default pool = dense-equivalent capacity; callers shrink it to
        # the workload to realize the memory win (bench_serving does)
        self.num_pages = num_pages or batch_size * self.max_pages
        self.pool = PagePool(self.num_pages, self.page_size)
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(self.pool) if share_prefixes else None)

        # chunked prefill and padded-bucket prefill both require every
        # layer's decode state to be attention-only: tail padding and
        # per-slot variable chunk lengths hide behind the causal mask,
        # which recurrent scans don't have
        if prefill_chunk_tokens is None:
            self.chunk = (DEFAULT_CHUNK_TOKENS if model.attention_only
                          else 0)
        else:
            if prefill_chunk_tokens > 0 and not model.attention_only:
                raise ValueError(
                    f"chunked prefill needs an attention-only arch; "
                    f"{cfg.name} ({cfg.arch_type}) carries recurrent "
                    "state — use prefill_chunk_tokens=0 (bulk)")
            self.chunk = int(prefill_chunk_tokens)
        if bucket_sizes is not None:
            if bucket_sizes and not model.attention_only:
                raise ValueError(
                    "prompt-length bucketing pads prompts, which corrupts "
                    f"recurrent state; {cfg.name} must prefill unpadded")
            # an explicit empty sequence disables bucketing entirely
            # (exact-length prefill, one compile per distinct length)
            self.bucket_sizes = sorted(int(b) for b in bucket_sizes)
            if self.bucket_sizes and self.bucket_sizes[-1] < max_seq_len:
                self.bucket_sizes.append(max_seq_len)
        elif model.attention_only:
            self.bucket_sizes = default_buckets(max_seq_len)
        else:
            self.bucket_sizes = []      # exact-length prefill

        state = model.init_paged_state(batch_size, self.num_pages,
                                       self.page_size, self.max_pages)
        self._caches = state.caches
        self._table = np.zeros((batch_size, self.max_pages), np.int32)
        self._lens = np.zeros((batch_size,), np.int32)
        self._next_tok = np.zeros((batch_size, 1), np.int32)

        # donate the cache operand so XLA updates the pool in place —
        # without it every step/scatter/COW doubles the pool's HBM with
        # a full copy.  CPU ignores donation with a warning, so only
        # request it where it does something.
        donate = jax.default_backend() != "cpu"
        self._step_fn = jax.jit(
            functools.partial(model.paged_serve_step, use_kernel=use_kernel),
            donate_argnums=(2,) if donate else ())
        self._fused_fn = jax.jit(
            functools.partial(model.paged_fused_step, use_kernel=use_kernel),
            donate_argnums=(2,) if donate else ())
        self._prefill_fn = jax.jit(model.prefill)
        self._write_fn = jax.jit(
            functools.partial(model.write_prefill_to_pages,
                              page_size=self.page_size),
            donate_argnums=(0,) if donate else ())
        self._copy_fn = jax.jit(model.copy_cache_page,
                                donate_argnums=(0,) if donate else ())

        self.slots: List[Optional[Request]] = [None] * batch_size
        self._slot_pages: List[List[int]] = [[] for _ in range(batch_size)]
        # ownership per table entry: a request appends freely into pages
        # it allocated or COW'd itself even when the prefix cache (or a
        # prefix-sharing reader) also holds them — sharers only ever
        # read slots written before they matched, and writes are
        # strictly append-only past that watermark.  Only pages BORROWED
        # via a prefix match go through the COW gate before a write.
        self._slot_owned: List[List[bool]] = [[] for _ in range(batch_size)]
        # chunked prefill: the full token list still being fed (None =
        # slot is decoding); the next token to feed is toks[_lens[slot]]
        self._pending: List[Optional[List[int]]] = [None] * batch_size
        self._admit_seq = [-1] * batch_size
        self._seq_counter = 0
        self.queue: "deque[Request]" = deque()
        self.stats: Dict[int, RequestStats] = {}
        self.logit_trace: Dict[int, List[np.ndarray]] = {}
        self._trace = trace_logits

        self.clock = 0              # serve passes (prefills + fused passes)
        self.decode_steps = 0
        self.prefill_forwards = 0   # passes that ingested prompt tokens
        self.mixed_passes = 0       # fused passes mixing prefill + decode
        self.mid_prefill_preemptions = 0
        self.wall_seconds = 0.0
        self.decode_seconds = 0.0   # wall time of PURE decode passes
        self.decode_tokens = 0      # tokens generated in those passes

    def place_caches(self, shardings) -> None:
        """Move the page pool onto mesh shardings
        (launch/specs.paged_state_specs); the jitted steps keep the
        placement from there on."""
        self._caches = jax.device_put(self._caches, shardings)

    # -- accounting -------------------------------------------------------
    def cache_hbm_bytes(self) -> int:
        """Static pool footprint: every attention-cache byte the engine
        holds (the number the bench compares to the dense server's
        ``(B, max_seq)`` caches)."""
        return attention_cache_bytes(self._caches)

    def cache_page_bytes(self) -> int:
        return self.cache_hbm_bytes() // max(self.num_pages, 1)

    def cache_in_use_bytes(self) -> int:
        return self.pool.in_use * self.cache_page_bytes()

    def prefill_cache_size(self) -> int:
        """Jit compile-cache entries of the bulk-prefill fn — with
        bucketing this stays at the number of distinct buckets touched,
        not the number of distinct prompt lengths (tests assert it)."""
        return int(self._prefill_fn._cache_size())

    def reset_perf_counters(self) -> None:
        """Zero the wall-clock/throughput counters (NOT the request
        stats): benches warm the jit caches with a throwaway run, then
        reset and measure."""
        self.clock = 0
        self.decode_steps = 0
        self.prefill_forwards = 0
        self.mixed_passes = 0
        self.mid_prefill_preemptions = 0
        self.wall_seconds = 0.0
        self.decode_seconds = 0.0
        self.decode_tokens = 0

    def latency_summary(self) -> dict:
        """Latency/TTFT percentiles in serve-pass ticks.  The keys are
        always present; fields whose source list is empty (no request
        completed / no first token emitted yet) are ``None`` rather
        than feeding ``np.percentile`` an empty array."""
        lats = [s.latency for s in self.stats.values()
                if s.latency is not None]
        ttfts = [s.ttft for s in self.stats.values() if s.ttft is not None]

        def pct(vals, q):
            return float(np.percentile(vals, q)) if vals else None

        return {
            "requests": len(lats),
            "latency_p50": pct(lats, 50),
            "latency_p95": pct(lats, 95),
            "ttft_p50": pct(ttfts, 50),
            "ttft_p95": pct(ttfts, 95),
        }

    def metrics(self) -> dict:
        return {
            "clock": self.clock,
            "decode_steps": self.decode_steps,
            "prefill_forwards": self.prefill_forwards,
            "mixed_passes": self.mixed_passes,
            "mid_prefill_preemptions": self.mid_prefill_preemptions,
            "decode_seconds": self.decode_seconds,
            "decode_tokens": self.decode_tokens,
            "pool": self.pool.metrics.as_dict(),
            "pool_utilization": self.pool.utilization(),
            "cache_hbm_bytes": self.cache_hbm_bytes(),
            "cache_in_use_bytes": self.cache_in_use_bytes(),
            **self.latency_summary(),
        }

    # -- admission --------------------------------------------------------
    def enqueue(self, req: Request) -> None:
        total = (len(req.prompt) or 1) + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(f"request {req.uid}: {total} tokens exceeds "
                             f"max_seq_len={self.max_seq}")
        if -(-total // self.page_size) > self.num_pages:
            raise ValueError(f"request {req.uid} alone needs more pages "
                             f"than the pool holds ({self.num_pages})")
        self.stats.setdefault(req.uid, RequestStats(uid=req.uid,
                                                    enqueued_at=self.clock))
        self.queue.append(req)

    def _restart_tokens(self, req: Request) -> List[int]:
        toks = list(req.prompt) + list(req.generated)
        return toks if toks else [BOS_TOKEN]

    def _alloc_or_evict(self) -> Optional[int]:
        pid = self.pool.alloc()
        while pid is None and self.prefix is not None and len(self.prefix):
            if self.prefix.evict(1) == 0:
                continue            # entry dropped but page still held
            pid = self.pool.alloc()
        return pid

    def _bucket_len(self, T: int) -> int:
        for b in self.bucket_sizes:
            if b >= T:
                return b
        return T

    def _acquire_pages(self, toks: List[int]):
        """Prefix-match ``toks`` and secure every page the prompt needs:
        borrowed prefix pages first, fresh pages for the rest, COW on
        the trailing partially-shared page.  Returns ``(pages, owned,
        shared_len)`` or None (with all side effects rolled back) when
        the pool cannot hold the prompt."""
        T = len(toks)
        P = self.page_size
        hits_before = self.pool.metrics.prefix_hits
        if self.prefix is not None:
            shared, shared_len = self.prefix.match(toks)
        else:
            shared, shared_len = [], 0
        pages = [pid for pid, _ in shared]
        owned = [False] * len(pages)

        # chunked mode feeds ``toks[shared_len:]`` through the fused
        # pass and needs at least the LAST prompt token to produce the
        # first logit: trim a whole-prompt match by one token (and drop
        # the final matched page if that token was all it covered)
        if self.chunk and shared_len == T:
            shared_len = T - 1
            if shared_len % P == 0 and pages:
                self.pool.release(pages.pop())
                owned.pop()

        n_shared = len(pages)

        def rollback():
            # a failed attempt must not leave traces in the accounting
            # the benchmarks report: the match above was undone, so its
            # hit counts are too (a re-queued request retries every
            # run-loop iteration while the pool stays dry)
            for pid in pages:
                self.pool.release(pid)
            self.pool.metrics.prefix_hits = hits_before

        # fresh pages FIRST: if the pool cannot hold the prompt there
        # is nothing to admit, and failing here keeps the rollback free
        # of side effects (no COW bytes were moved yet)
        for _ in range(-(-T // P) - len(pages)):
            pid = self._alloc_or_evict()
            if pid is None:
                rollback()
                return None
            pages.append(pid)
            owned.append(True)

        # then COW the trailing shared partial page before later writes
        # fill the rest of its slots
        if n_shared and shared_len < T and shared_len % P != 0:
            new_pid, copied = self.pool.writable(pages[n_shared - 1])
            if new_pid is None:
                rollback()
                return None
            if copied:
                self._caches = self._copy_fn(self._caches,
                                             pages[n_shared - 1], new_pid)
                pages[n_shared - 1] = new_pid
            owned[n_shared - 1] = True
        return pages, owned, shared_len

    def _try_admit(self, slot: int, req: Request) -> bool:
        with obs_trace.span("serve.admit", track="serve",
                            uid=req.uid, slot=slot) as sp:
            ok = self._try_admit_impl(slot, req)
            sp.set(admitted=ok)
        return ok

    def _try_admit_impl(self, slot: int, req: Request) -> bool:
        toks = self._restart_tokens(req)
        T = len(toks)
        got = self._acquire_pages(toks)
        if got is None:
            return False
        pages, owned, shared_len = got

        self.slots[slot] = req
        self._slot_pages[slot] = pages
        self._slot_owned[slot] = owned
        self._admit_seq[slot] = self._seq_counter
        self._seq_counter += 1
        self._table[slot, :] = 0
        self._table[slot, :len(pages)] = pages
        st = self.stats[req.uid]

        if self.chunk:
            # chunked admission: every prompt page is secured up front,
            # but the tokens themselves ride the next fused passes.  No
            # forward here — and no first_token stamp: the first logit
            # hasn't been computed (the TTFT contract).  The prefix is
            # registered only when the prompt is fully written, so
            # sharers can never read unwritten bytes.
            self._lens[slot] = shared_len
            self._pending[slot] = toks
            st.admitted_at = self.clock if st.admitted_at is None \
                else st.admitted_at
            st.shared_tokens += shared_len
            return True

        # bulk prefill: ONE forward for the whole prompt — padded to a
        # length bucket when the arch allows it, so jit compiles once
        # per bucket — then scatter the resulting KV into this
        # request's pages (shared-prefix positions drop-routed: their
        # pages already hold those bytes; bucket padding drop-routed
        # behind ``true_len``)
        self._lens[slot] = 0
        Tb = self._bucket_len(T) if self.bucket_sizes else T
        padded = toks + [BOS_TOKEN] * (Tb - T)
        with obs_trace.span("serve.prefill.bulk", track="serve",
                            uid=req.uid, tokens=T, bucket=Tb):
            if Tb != T:
                logits, dstate = self._prefill_fn(
                    self.params,
                    {"tokens": jnp.asarray([padded], jnp.int32)},
                    true_len=jnp.asarray(T, jnp.int32))
            else:
                logits, dstate = self._prefill_fn(
                    self.params, {"tokens": jnp.asarray([padded], jnp.int32)})
            self._caches = self._write_fn(
                self._caches, dstate.caches,
                jnp.asarray(self._table[slot].copy()),
                jnp.asarray(shared_len), slot,
                true_len=jnp.asarray(T, jnp.int32))
            self._next_tok[slot, 0] = int(np.argmax(np.asarray(logits[0])))
        self._lens[slot] = T
        self.clock += 1
        self.prefill_forwards += 1

        st.admitted_at = self.clock if st.admitted_at is None \
            else st.admitted_at
        st.prefill_calls += 1
        st.prefill_tokens += T
        st.shared_tokens += shared_len
        if st.first_token_at is None:
            st.first_token_at = self.clock   # this pass emitted the logit
        if self.prefix is not None:
            self.prefix.register(toks, pages)
        return True

    def _blocked_by_inflight_prefix(self, toks: List[int]) -> bool:
        """Chunked admission is cheap enough that several prompts enter
        in one pass — but a prefix is only registered once fully
        written, so a request sharing at least one page with a prompt
        STILL BEING FED waits for it (a couple of passes) instead of
        allocating duplicate pages it could have borrowed."""
        if self.prefix is None or not self.chunk:
            return False
        for s in range(self.batch):
            pend = self._pending[s]
            if pend is None:
                continue
            n = 0
            for a, b in zip(pend, toks):
                if a != b:
                    break
                n += 1
            if n >= self.page_size:
                return True
        return False

    def _admit_pending(self) -> None:
        for slot in range(self.batch):
            if self.slots[slot] is not None:
                continue
            if not self.queue:
                return
            if self._blocked_by_inflight_prefix(
                    self._restart_tokens(self.queue[0])):
                return              # FIFO: no head-of-line skipping
            req = self.queue.popleft()
            if not self._try_admit(slot, req):
                self.queue.appendleft(req)
                return

    # -- preemption -------------------------------------------------------
    def _free_slot(self, slot: int) -> None:
        for pid in self._slot_pages[slot]:
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self._slot_owned[slot] = []
        self._table[slot, :] = 0
        self._lens[slot] = 0
        self.slots[slot] = None
        self._pending[slot] = None
        self._admit_seq[slot] = -1

    def _preempt(self, slot: int) -> None:
        req = self.slots[slot]
        obs_trace.instant("serve.preempt", track="serve", uid=req.uid,
                          slot=slot,
                          mid_prefill=self._pending[slot] is not None)
        self.stats[req.uid].preemptions += 1
        self.pool.metrics.preemptions += 1
        if self._pending[slot] is not None:
            # mid-chunked-prefill: the prefix was never registered, so
            # the partially-written pages vanish with the release
            self.mid_prefill_preemptions += 1
        self._free_slot(slot)
        # re-queue at the front with everything decoded so far as the
        # prompt: greedy re-prefill reproduces the pending token exactly
        self.queue.appendleft(req)

    def _victim(self) -> Optional[int]:
        """Lowest-priority active slot = latest admitted."""
        cands = [s for s in range(self.batch) if self.slots[s] is not None]
        if not cands:
            return None
        return max(cands, key=lambda s: self._admit_seq[s])

    def _ensure_capacity(self, slot: int) -> bool:
        """Make the page the next write lands in writable by this slot:
        allocate when the sequence crosses a page boundary, COW when the
        page was borrowed from a prefix match.  Pages this request
        allocated or COW'd itself are append-writable regardless of how
        many prefix readers hold them."""
        pos = int(self._lens[slot])
        idx = pos // self.page_size
        pages = self._slot_pages[slot]
        owned = self._slot_owned[slot]
        if idx == len(pages):
            pid = self._alloc_or_evict()
            if pid is None:
                return False
            pages.append(pid)
            owned.append(True)
            self._table[slot, idx] = pid
            return True
        if not owned[idx]:
            pid = pages[idx]
            new_pid, copied = self.pool.writable(pid)
            if new_pid is None:
                return False
            if copied:
                self._caches = self._copy_fn(self._caches, pid, new_pid)
                pages[idx] = new_pid
                self._table[slot, idx] = new_pid
            owned[idx] = True
        return True

    # -- the fused batched pass ------------------------------------------
    def _active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots)
                if r is not None and not r.done]

    def _table_width(self) -> int:
        """Power-of-two page-table slice covering every active slot's
        pages: the fused pass attends ``width * page_size`` positions
        instead of ``max_seq``, which is THE decode wall-clock lever —
        work scales with live context (compiles are bounded by the
        log2-many widths)."""
        widest = max((len(self._slot_pages[s])
                      for s in range(self.batch)
                      if self.slots[s] is not None), default=1)
        return _pow2_at_least(max(widest, 1), self.max_pages)

    def step(self) -> bool:
        """One fused pass over the active slots: single-token decode for
        slots past their prompt, up to ``prefill_chunk_tokens`` prompt
        tokens spread over the slots still ingesting (chunked mode).
        Returns False when nothing was active (after capacity
        preemptions)."""
        with obs_trace.span("serve.pass", track="serve") as sp:
            return self._step_impl(sp)

    def _step_impl(self, sp) -> bool:
        # capacity pass for decoding slots (prefilling slots secured
        # every prompt page at admission), oldest admissions first so
        # they steal from the youngest (the preemption priority order)
        for slot in sorted(self._active_slots(),
                           key=lambda s: self._admit_seq[s]):
            if self.slots[slot] is None or self._pending[slot] is not None:
                continue            # preempted earlier / still prefilling
            while not self._ensure_capacity(slot):
                victim = self._victim()
                self._preempt(victim)
                if victim == slot:
                    break

        active_idx = self._active_slots()
        if not active_idx:
            return False

        # plan the pass: decode slots feed their pending token; chunked
        # prompt tokens fill a shared budget FIFO over prefilling slots
        q_lens = np.zeros((self.batch,), np.int32)
        budget = self.chunk
        any_prefill = False
        for i in sorted(active_idx, key=lambda s: self._admit_seq[s]):
            if self._pending[i] is None:
                q_lens[i] = 1
            elif budget > 0:
                remaining = len(self._pending[i]) - int(self._lens[i])
                take = min(remaining, budget)
                q_lens[i] = take
                budget -= take
                any_prefill = take > 0

        C = self.chunk if any_prefill else 1
        tokens = np.zeros((self.batch, C), np.int32)
        for i in active_idx:
            n = int(q_lens[i])
            if n == 0:
                continue
            if self._pending[i] is None:
                tokens[i, 0] = self._next_tok[i, 0]
            else:
                lo = int(self._lens[i])
                tokens[i, :n] = self._pending[i][lo:lo + n]

        W = self._table_width()
        # snapshot the live numpy buffers: asarray may alias them while
        # the dispatch is in flight, and _ensure_capacity / the per-slot
        # length bumps below mutate both before it resolves
        state = PagedDecodeState(
            caches=self._caches,
            page_table=jnp.asarray(self._table[:, :W].copy()),
            seq_lens=jnp.asarray(self._lens.copy()))
        pure_decode = not any_prefill
        t0 = time.perf_counter() if pure_decode else 0.0
        # tokens is a fresh numpy buffer (no host-buffer race: nothing
        # mutates it before the synchronous asarray conversion)
        logits, new_state = self._fused_fn(
            self.params, jnp.asarray(tokens), state, jnp.asarray(q_lens))
        self._caches = new_state.caches
        # repro: ignore[host-sync] -- greedy decode IS the sync point:
        # the sampled token must land on host to extend each sequence
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.clock += 1
        if pure_decode:
            self.decode_steps += 1
            self.decode_seconds += time.perf_counter() - t0
        else:
            self.mixed_passes += 1
            self.prefill_forwards += 1
        sp.set(clock=self.clock, width=W, active=len(active_idx),
               tokens=int(q_lens.sum()), pure_decode=pure_decode)
        obs_trace.counter("pool.pages_live", self.pool.in_use,
                          track="serve")

        if self._trace:
            # repro: ignore[host-sync] -- opt-in trace mode only; full
            # logits are materialized for logprob inspection by request
            logits_np = np.asarray(logits)
        for i in active_idx:
            n = int(q_lens[i])
            if n == 0:
                continue
            req = self.slots[i]
            st = self.stats[req.uid]
            if self._pending[i] is None:
                # decode slot: the fed token materializes, the new
                # argmax becomes next pass's feed
                if self._trace:
                    self.logit_trace.setdefault(req.uid, []).append(
                        logits_np[i].copy())
                req.generated.append(int(tokens[i, 0]))
                self._next_tok[i, 0] = int(nxt[i])
                self._lens[i] += 1
                if pure_decode:
                    self.decode_tokens += 1
                if req.done:
                    st.finished_at = self.clock
                    self._free_slot(i)
            else:
                # prefilling slot: advance the prompt watermark
                self._lens[i] += n
                st.prefill_calls += 1
                st.prefill_tokens += n
                if int(self._lens[i]) >= len(self._pending[i]):
                    # prompt complete — THIS pass emitted the first
                    # logit (the TTFT stamp), and only now is the
                    # prefix safe for sharers to read
                    toks = self._pending[i]
                    self._pending[i] = None
                    self._next_tok[i, 0] = int(nxt[i])
                    if st.first_token_at is None:
                        st.first_token_at = self.clock
                    if self.prefix is not None:
                        self.prefix.register(toks, self._slot_pages[i])
        return True

    # -- driver -----------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Request]:
        with obs_trace.span("serve.run", track="serve",
                            requests=len(requests)):
            out = self._run_impl(requests)
        obs_metrics.publish_serving(self.metrics())
        if obs_trace.active():
            obs_monitors.emit(
                [obs_monitors.check_pool_conservation(self.pool)])
        return out

    def _run_impl(self, requests: List[Request]) -> List[Request]:
        t0 = time.perf_counter()
        for r in requests:
            self.enqueue(r)
        while True:
            self._admit_pending()
            if not self._active_slots():
                if not self.queue:
                    break
                # queued work but nothing admissible: spill the prefix
                # cache back to the pool and retry; a request the empty
                # pool still cannot hold was rejected at enqueue
                if self.prefix is not None and len(self.prefix):
                    self.prefix.drop_all()
                    continue
                raise RuntimeError("admission stuck with an empty pool")
            self.step()
        self.wall_seconds += time.perf_counter() - t0
        return requests
