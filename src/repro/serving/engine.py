"""PagedEngine: continuous-batching serving over the page pool
(DESIGN.md §11).

The dense :class:`~repro.serving.decode.DecodeServer` pre-allocates a
``(B, max_seq)`` ring cache per slot and teacher-forces prompts
token-by-token — memory scales with the worst-case sequence and prompt
ingestion costs O(prompt) serve passes.  The paged engine replaces both:

* **memory** — attention KV lives in a shared :class:`PagePool`; a
  request holds exactly ``ceil(tokens / page_size)`` pages, prompt
  prefixes shared copy-on-write across requests;
* **prefill** — ONE ``Model.prefill`` forward per prompt, scattered
  into the request's pages (``Model.write_prefill_to_pages``);
* **capacity** — admission queues until pages are available, and a
  decode step that cannot grow preempts the lowest-priority (latest
  admitted) request: its pages return to the pool and it re-queues with
  ``prompt + generated`` as the new prompt, which under greedy decoding
  reproduces the evicted trajectory exactly (the re-prefill's last-token
  argmax IS the pending token).

Parity anchor: with ``page_size >= max_seq`` (one page per request),
``num_pages = batch`` and greedy sampling, the decode read degenerates
to the dense masked attention over a contiguous cache row, and
:meth:`run` reproduces ``DecodeServer.run`` token-for-token on the same
requests (tests/test_paged_engine.py).  SSM/hybrid archs keep their
recurrent state dense in the engine — only attention caches page.

Scheduling is host-side Python (like the pool): the device sees one
jitted ``paged_serve_step`` per decode step and one ``prefill`` +
page-scatter per admission.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, PagedDecodeState, map_cache_tree
from repro.serving.decode import BOS_TOKEN, Request
from repro.serving.pages import PagePool, PrefixCache

Array = jax.Array


def attention_cache_bytes(caches) -> int:
    """Bytes held by every attention-cache leaf (KVCache/MLACache) of a
    decode-state tree — the one cache-accounting rule, shared by the
    engine metrics and bench_serving's dense baseline."""
    total = 0

    def count(c):
        nonlocal total
        total += sum(int(x.nbytes) for x in c)
        return c

    map_cache_tree(caches, on_attention=count, on_leaf=lambda c: c)
    return total


@dataclasses.dataclass
class RequestStats:
    """Per-request lifecycle in serve-pass clock ticks (one tick = one
    model pass: a bulk prefill or a batched decode step)."""
    uid: int
    enqueued_at: int
    admitted_at: Optional[int] = None
    first_token_at: Optional[int] = None
    finished_at: Optional[int] = None
    prefill_calls: int = 0
    prefill_tokens: int = 0
    shared_tokens: int = 0
    preemptions: int = 0

    @property
    def ttft(self) -> Optional[int]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.enqueued_at

    @property
    def latency(self) -> Optional[int]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.enqueued_at


class PagedEngine:
    """Continuous-batching scheduler over a paged KV cache."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_seq_len: int, page_size: Optional[int] = None,
                 num_pages: Optional[int] = None, use_kernel: bool = False,
                 share_prefixes: bool = True, trace_logits: bool = False):
        cfg = model.cfg
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode")
        if cfg.frontend is not None:
            raise ValueError("paged engine serves token-frontend archs; "
                             f"{cfg.name} needs stub embeds (use the dense "
                             "DecodeServer)")
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq_len
        self.page_size = page_size or min(16, max_seq_len)
        self.max_pages = -(-max_seq_len // self.page_size)
        # default pool = dense-equivalent capacity; callers shrink it to
        # the workload to realize the memory win (bench_serving does)
        self.num_pages = num_pages or batch_size * self.max_pages
        self.pool = PagePool(self.num_pages, self.page_size)
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(self.pool) if share_prefixes else None)

        state = model.init_paged_state(batch_size, self.num_pages,
                                       self.page_size, self.max_pages)
        self._caches = state.caches
        self._table = np.zeros((batch_size, self.max_pages), np.int32)
        self._lens = np.zeros((batch_size,), np.int32)
        self._next_tok = np.zeros((batch_size, 1), np.int32)

        # donate the cache operand so XLA updates the pool in place —
        # without it every step/scatter/COW doubles the pool's HBM with
        # a full copy.  CPU ignores donation with a warning, so only
        # request it where it does something.
        donate = jax.default_backend() != "cpu"
        self._step_fn = jax.jit(
            functools.partial(model.paged_serve_step, use_kernel=use_kernel),
            donate_argnums=(2,) if donate else ())
        self._prefill_fn = jax.jit(model.prefill)
        self._write_fn = jax.jit(
            functools.partial(model.write_prefill_to_pages,
                              page_size=self.page_size),
            donate_argnums=(0,) if donate else ())
        self._copy_fn = jax.jit(model.copy_cache_page,
                                donate_argnums=(0,) if donate else ())

        self.slots: List[Optional[Request]] = [None] * batch_size
        self._slot_pages: List[List[int]] = [[] for _ in range(batch_size)]
        # ownership per table entry: a request appends freely into pages
        # it allocated or COW'd itself even when the prefix cache (or a
        # prefix-sharing reader) also holds them — sharers only ever
        # read slots written before they matched, and writes are
        # strictly append-only past that watermark.  Only pages BORROWED
        # via a prefix match go through the COW gate before a write.
        self._slot_owned: List[List[bool]] = [[] for _ in range(batch_size)]
        self._admit_seq = [-1] * batch_size
        self._seq_counter = 0
        self.queue: "deque[Request]" = deque()
        self.stats: Dict[int, RequestStats] = {}
        self.logit_trace: Dict[int, List[np.ndarray]] = {}
        self._trace = trace_logits

        self.clock = 0              # serve passes (prefills + decode steps)
        self.decode_steps = 0
        self.prefill_forwards = 0
        self.wall_seconds = 0.0

    def place_caches(self, shardings) -> None:
        """Move the page pool onto mesh shardings
        (launch/specs.paged_state_specs); the jitted steps keep the
        placement from there on."""
        self._caches = jax.device_put(self._caches, shardings)

    # -- accounting -------------------------------------------------------
    def cache_hbm_bytes(self) -> int:
        """Static pool footprint: every attention-cache byte the engine
        holds (the number the bench compares to the dense server's
        ``(B, max_seq)`` caches)."""
        return attention_cache_bytes(self._caches)

    def cache_page_bytes(self) -> int:
        return self.cache_hbm_bytes() // max(self.num_pages, 1)

    def cache_in_use_bytes(self) -> int:
        return self.pool.in_use * self.cache_page_bytes()

    def latency_summary(self) -> dict:
        lats = [s.latency for s in self.stats.values()
                if s.latency is not None]
        ttfts = [s.ttft for s in self.stats.values() if s.ttft is not None]
        if not lats:
            return {}
        return {
            "requests": len(lats),
            "latency_p50": float(np.percentile(lats, 50)),
            "latency_p95": float(np.percentile(lats, 95)),
            "ttft_p50": float(np.percentile(ttfts, 50)),
            "ttft_p95": float(np.percentile(ttfts, 95)),
        }

    def metrics(self) -> dict:
        return {
            "clock": self.clock,
            "decode_steps": self.decode_steps,
            "prefill_forwards": self.prefill_forwards,
            "pool": self.pool.metrics.as_dict(),
            "pool_utilization": self.pool.utilization(),
            "cache_hbm_bytes": self.cache_hbm_bytes(),
            "cache_in_use_bytes": self.cache_in_use_bytes(),
            **self.latency_summary(),
        }

    # -- admission --------------------------------------------------------
    def enqueue(self, req: Request) -> None:
        total = (len(req.prompt) or 1) + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(f"request {req.uid}: {total} tokens exceeds "
                             f"max_seq_len={self.max_seq}")
        if -(-total // self.page_size) > self.num_pages:
            raise ValueError(f"request {req.uid} alone needs more pages "
                             f"than the pool holds ({self.num_pages})")
        self.stats.setdefault(req.uid, RequestStats(uid=req.uid,
                                                    enqueued_at=self.clock))
        self.queue.append(req)

    def _restart_tokens(self, req: Request) -> List[int]:
        toks = list(req.prompt) + list(req.generated)
        return toks if toks else [BOS_TOKEN]

    def _alloc_or_evict(self) -> Optional[int]:
        pid = self.pool.alloc()
        while pid is None and self.prefix is not None and len(self.prefix):
            if self.prefix.evict(1) == 0:
                continue            # entry dropped but page still held
            pid = self.pool.alloc()
        return pid

    def _try_admit(self, slot: int, req: Request) -> bool:
        toks = self._restart_tokens(req)
        T = len(toks)
        P = self.page_size
        hits_before = self.pool.metrics.prefix_hits
        if self.prefix is not None:
            shared, shared_len = self.prefix.match(toks)
        else:
            shared, shared_len = [], 0
        pages = [pid for pid, _ in shared]
        owned = [False] * len(pages)
        n_shared = len(pages)

        def rollback():
            # a failed attempt must not leave traces in the accounting
            # the benchmarks report: the match above was undone, so its
            # hit counts are too (a re-queued request retries every
            # run-loop iteration while the pool stays dry)
            for pid in pages:
                self.pool.release(pid)
            self.pool.metrics.prefix_hits = hits_before

        # fresh pages FIRST: if the pool cannot hold the prompt there
        # is nothing to admit, and failing here keeps the rollback free
        # of side effects (no COW bytes were moved yet)
        for _ in range(-(-T // P) - len(pages)):
            pid = self._alloc_or_evict()
            if pid is None:
                rollback()
                return False
            pages.append(pid)
            owned.append(True)

        # then COW the trailing shared partial page before the prefill
        # writes the rest of its slots
        if shared and shared_len < T and shared_len % P != 0:
            new_pid, copied = self.pool.writable(pages[n_shared - 1])
            if new_pid is None:
                rollback()
                return False
            if copied:
                self._caches = self._copy_fn(self._caches,
                                             pages[n_shared - 1], new_pid)
                pages[n_shared - 1] = new_pid
            owned[n_shared - 1] = True

        self.slots[slot] = req
        self._slot_pages[slot] = pages
        self._slot_owned[slot] = owned
        self._admit_seq[slot] = self._seq_counter
        self._seq_counter += 1
        self._table[slot, :] = 0
        self._table[slot, :len(pages)] = pages
        self._lens[slot] = 0

        # bulk prefill: ONE forward for the whole prompt, then scatter
        # the resulting KV into this request's pages (shared-prefix
        # positions drop-routed — their pages already hold those bytes)
        logits, dstate = self._prefill_fn(
            self.params, {"tokens": jnp.asarray([toks], jnp.int32)})
        self._caches = self._write_fn(
            self._caches, dstate.caches, jnp.asarray(self._table[slot]),
            jnp.asarray(shared_len), slot)
        self._next_tok[slot, 0] = int(np.argmax(np.asarray(logits[0])))
        self._lens[slot] = T
        self.clock += 1
        self.prefill_forwards += 1

        st = self.stats[req.uid]
        st.admitted_at = self.clock if st.admitted_at is None \
            else st.admitted_at
        st.prefill_calls += 1
        st.prefill_tokens += T
        st.shared_tokens += shared_len
        if st.first_token_at is None:
            st.first_token_at = self.clock
        if self.prefix is not None:
            self.prefix.register(toks, pages)
        return True

    def _admit_pending(self) -> None:
        for slot in range(self.batch):
            if self.slots[slot] is not None:
                continue
            if not self.queue:
                return
            req = self.queue.popleft()
            if not self._try_admit(slot, req):
                self.queue.appendleft(req)
                return              # FIFO: no head-of-line skipping

    # -- preemption -------------------------------------------------------
    def _free_slot(self, slot: int) -> None:
        for pid in self._slot_pages[slot]:
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self._slot_owned[slot] = []
        self._table[slot, :] = 0
        self._lens[slot] = 0
        self.slots[slot] = None
        self._admit_seq[slot] = -1

    def _preempt(self, slot: int) -> None:
        req = self.slots[slot]
        self.stats[req.uid].preemptions += 1
        self.pool.metrics.preemptions += 1
        self._free_slot(slot)
        # re-queue at the front with everything decoded so far as the
        # prompt: greedy re-prefill reproduces the pending token exactly
        self.queue.appendleft(req)

    def _victim(self) -> Optional[int]:
        """Lowest-priority active slot = latest admitted."""
        cands = [s for s in range(self.batch) if self.slots[s] is not None]
        if not cands:
            return None
        return max(cands, key=lambda s: self._admit_seq[s])

    def _ensure_capacity(self, slot: int) -> bool:
        """Make the page the next write lands in writable by this slot:
        allocate when the sequence crosses a page boundary, COW when the
        page was borrowed from a prefix match.  Pages this request
        allocated or COW'd itself are append-writable regardless of how
        many prefix readers hold them."""
        pos = int(self._lens[slot])
        idx = pos // self.page_size
        pages = self._slot_pages[slot]
        owned = self._slot_owned[slot]
        if idx == len(pages):
            pid = self._alloc_or_evict()
            if pid is None:
                return False
            pages.append(pid)
            owned.append(True)
            self._table[slot, idx] = pid
            return True
        if not owned[idx]:
            pid = pages[idx]
            new_pid, copied = self.pool.writable(pid)
            if new_pid is None:
                return False
            if copied:
                self._caches = self._copy_fn(self._caches, pid, new_pid)
                pages[idx] = new_pid
                self._table[slot, idx] = new_pid
            owned[idx] = True
        return True

    # -- the batched decode step -----------------------------------------
    def _active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots)
                if r is not None and not r.done]

    def step(self) -> bool:
        """One batched decode pass over the active slots.  Returns False
        when nothing was active (after capacity preemptions)."""
        # capacity pass, oldest admissions first so they steal from the
        # youngest (the preemption priority order)
        for slot in sorted(self._active_slots(),
                           key=lambda s: self._admit_seq[s]):
            if self.slots[slot] is None:
                continue            # preempted earlier in this pass
            while not self._ensure_capacity(slot):
                victim = self._victim()
                self._preempt(victim)
                if victim == slot:
                    break

        active_idx = self._active_slots()
        if not active_idx:
            return False
        active = np.zeros((self.batch,), bool)
        active[active_idx] = True

        state = PagedDecodeState(caches=self._caches,
                                 page_table=jnp.asarray(self._table),
                                 seq_lens=jnp.asarray(self._lens))
        # synchronous numpy snapshot of the host token buffer: jax's own
        # copy is async and the mutation below could race it (the
        # decode.py host-buffer race)
        logits, new_state = self._step_fn(
            self.params, jnp.asarray(self._next_tok.copy()), state,
            jnp.asarray(active))
        self._caches = new_state.caches
        self.clock += 1
        self.decode_steps += 1

        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        if self._trace:
            logits_np = np.asarray(logits)
        for i in active_idx:
            req = self.slots[i]
            if self._trace:
                self.logit_trace.setdefault(req.uid, []).append(
                    logits_np[i].copy())
            req.generated.append(int(self._next_tok[i, 0]))
            self._next_tok[i, 0] = int(nxt[i])
            self._lens[i] += 1
            if req.done:
                self.stats[req.uid].finished_at = self.clock
                self._free_slot(i)
        return True

    # -- driver -----------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Request]:
        t0 = time.perf_counter()
        for r in requests:
            self.enqueue(r)
        while True:
            self._admit_pending()
            if not self._active_slots():
                if not self.queue:
                    break
                # queued work but nothing admissible: spill the prefix
                # cache back to the pool and retry; a request the empty
                # pool still cannot hold was rejected at enqueue
                if self.prefix is not None and len(self.prefix):
                    self.prefix.drop_all()
                    continue
                raise RuntimeError("admission stuck with an empty pool")
            self.step()
        self.wall_seconds += time.perf_counter() - t0
        return requests
