"""repro.serving substrate."""
