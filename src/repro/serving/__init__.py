"""repro.serving — batched decode serving.

Two engines over one model decode contract (DESIGN.md §11):

* :class:`~repro.serving.decode.DecodeServer` — dense per-slot ring
  caches, token-by-token prefill; the simple parity anchor.
* :class:`~repro.serving.engine.PagedEngine` — paged KV-cache pool
  (:mod:`repro.serving.pages`), bulk prefill, continuous batching with
  preemption; the production path.
"""
from repro.serving.decode import BOS_TOKEN, DecodeServer, Request
from repro.serving.engine import PagedEngine, RequestStats
from repro.serving.pages import PagePool, PoolMetrics, PrefixCache

__all__ = [
    "BOS_TOKEN", "DecodeServer", "Request", "PagedEngine", "RequestStats",
    "PagePool", "PoolMetrics", "PrefixCache",
]
