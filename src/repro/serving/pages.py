"""Block-pool allocator for the paged KV cache (DESIGN.md §11).

The pool divides the physical cache (``num_pages`` fixed-size token
pages per layer, allocated once at engine start) into reference-counted
blocks.  Host-side and pure Python by design: allocation decisions are
control flow, not compute — the device only ever sees the resulting
page-table rows.

Three cooperating pieces:

* :class:`PagePool` — free list + per-page refcounts.  ``alloc`` hands
  out an exclusively-owned page; ``retain`` adds a sharer; ``release``
  returns the page to the free list when the last reference drops;
  ``writable`` is the copy-on-write gate: a page with one reference is
  returned as-is, a shared page is swapped for a fresh copy target (the
  caller copies the bytes — :meth:`repro.models.Model.copy_cache_page`).
* :class:`PoolMetrics` — allocation/COW/preemption accounting in the
  same spirit as the engines' ``wire_bits`` counters: every byte of
  cache HBM the serving path holds is derivable from these numbers.
* :class:`PrefixCache` — the shared-prompt-prefix index.  Prefilled
  prompt pages are registered under the token prefix they cover; a new
  request walks its prompt page-by-page and shares every registered
  page it matches (full pages, plus at most one trailing partial page)
  instead of allocating fresh ones.  Cache entries hold their own
  reference, so shared pages survive their original request; entries
  are evicted LRU under pool pressure.

Invariants (property-tested in tests/test_pages.py):

* ``len(free) + |{p : ref[p] > 0}| == num_pages`` — pages are never
  lost or duplicated;
* a page is never simultaneously free and referenced;
* ``writable`` returns a page with refcount 1 that the caller may
  mutate; the shared original keeps its remaining references.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class PoolMetrics:
    """Cumulative pool accounting (wire_bits-style: everything the §11
    benchmark reports is computed from these counters)."""
    num_pages: int
    page_size: int
    allocs: int = 0
    frees: int = 0
    cow_copies: int = 0
    prefix_hits: int = 0           # pages shared instead of allocated
    prefix_evictions: int = 0
    preemptions: int = 0
    alloc_failures: int = 0
    peak_in_use: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PagePool:
    """Fixed-size page pool with refcounted sharing."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError("pool needs >= 1 page of >= 1 token")
        self.num_pages = num_pages
        self.page_size = page_size
        # pop() takes from the end: keep ascending ids at the tail so
        # fresh allocations walk the pool front to back (deterministic,
        # and the parity anchor maps slot i -> page i on first fill).
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: List[int] = [0] * num_pages
        # bumped on every alloc: (pid, generation) names one *lifetime*
        # of a page, so stale prefix-chain links to a freed-and-reused
        # page can never resolve (PrefixCache key safety)
        self._gen: List[int] = [0] * num_pages
        self.metrics = PoolMetrics(num_pages=num_pages, page_size=page_size)

    # -- core ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.in_use / self.num_pages

    def refcount(self, pid: int) -> int:
        return self._ref[pid]

    def generation(self, pid: int) -> int:
        return self._gen[pid]

    def alloc(self) -> Optional[int]:
        """Exclusively-owned fresh page, or None when exhausted."""
        if not self._free:
            self.metrics.alloc_failures += 1
            return None
        pid = self._free.pop()
        assert self._ref[pid] == 0
        self._ref[pid] = 1
        self._gen[pid] += 1
        self.metrics.allocs += 1
        self.metrics.peak_in_use = max(self.metrics.peak_in_use, self.in_use)
        return pid

    def alloc_n(self, n: int) -> Optional[List[int]]:
        """All-or-nothing batch allocation."""
        if n > len(self._free):
            self.metrics.alloc_failures += 1
            return None
        return [self.alloc() for _ in range(n)]

    def retain(self, pid: int) -> int:
        if self._ref[pid] <= 0:
            raise ValueError(f"retain of unreferenced page {pid}")
        self._ref[pid] += 1
        return pid

    def release(self, pid: int) -> None:
        if self._ref[pid] <= 0:
            raise ValueError(f"release of unreferenced page {pid}")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)
            self.metrics.frees += 1

    def writable(self, pid: int) -> Tuple[Optional[int], bool]:
        """Copy-on-write gate before mutating ``pid``.  Returns
        ``(page_to_write, copied)``: the same page when exclusively
        owned, otherwise a fresh page (caller must copy the bytes and
        swap its table entry; the original keeps its other holders).
        ``(None, False)`` when a copy is needed but the pool is dry."""
        if self._ref[pid] == 1:
            return pid, False
        fresh = self.alloc()
        if fresh is None:
            return None, False
        self.release(pid)
        self.metrics.cow_copies += 1
        return fresh, True

    def check_invariants(self) -> None:
        held = sum(1 for r in self._ref if r > 0)
        assert held + len(self._free) == self.num_pages, \
            (held, len(self._free), self.num_pages)
        assert all(r >= 0 for r in self._ref)
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate free entries"
        assert all(self._ref[p] == 0 for p in free_set), \
            "page simultaneously free and referenced"


@dataclasses.dataclass
class _PrefixEntry:
    pid: int
    covered: int        # tokens of the page actually filled (<= page_size)
    link: Tuple[int, int]   # (pid, generation) — this entry's chain id


class PrefixCache:
    """Content-addressed page chain for shared-prompt page reuse.

    A page's KV depends on the whole causal prefix, not just its own
    tokens, so entries are keyed ``(parent_link, page_tokens)``: the
    page's own token span plus the chain link of the page holding the
    preceding prefix (``None`` at the root).  A match therefore walks
    page-by-page, each hop O(page_size) to build and hash — O(T * P)
    per admission instead of hashing the full prefix per candidate.
    Links are ``(pid, allocation generation)``: a freed-and-reused page
    gets a new generation, so stale children of a dead chain can never
    resolve against the reincarnated page id.

    Entries hold one pool reference each; registered pages are
    therefore immutable for everyone but their original writer — any
    other holder goes through the pool's COW gate before writing.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: "OrderedDict[tuple, _PrefixEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def register(self, tokens: Sequence[int], pages: Sequence[int]) -> None:
        """Index a freshly prefilled prompt: every full page under its
        (parent, span) key, and the trailing partial page under every
        sub-length it holds (so a prompt diverging mid-page can still
        share the page up to the divergence point and COW from there)."""
        P = self.pool.page_size
        T = len(tokens)
        parent = None
        for i, pid in enumerate(pages):
            start = i * P
            if start >= T:
                break
            covered = min(P, T - start)
            span = tuple(tokens[start:start + covered])
            link = (pid, self.pool.generation(pid))
            if covered == P:
                # chain through the entry that actually owns this key —
                # first registrant wins, so children must hang off it
                parent = self._register_one((parent, span), pid, P,
                                            link).link
            else:
                for c in range(1, covered + 1):
                    self._register_one((parent, span[:c]), pid, c, link)
                break               # a partial page ends the chain

    def _register_one(self, key: tuple, pid: int, covered: int,
                      link: Tuple[int, int]) -> _PrefixEntry:
        e = self._entries.get(key)
        if e is not None:
            return e                # first registrant wins; bytes equal
        self.pool.retain(pid)
        e = _PrefixEntry(pid=pid, covered=covered, link=link)
        self._entries[key] = e
        return e

    def match(self, tokens: Sequence[int]
              ) -> Tuple[List[Tuple[int, int]], int]:
        """Longest shareable prefix of ``tokens``: a list of
        ``(page_id, covered)`` pairs (each RETAINED for the caller) and
        the total shared token count.  All pages but the last are full;
        a partial page ends the walk (the divergence page — the caller
        COWs it before writing its remaining slots)."""
        P = self.pool.page_size
        shared: List[Tuple[int, int]] = []
        parent = None
        pos = 0
        while pos < len(tokens):
            hit = None
            for c in range(min(P, len(tokens) - pos), 0, -1):
                key = (parent, tuple(tokens[pos:pos + c]))
                e = self._entries.get(key)
                if e is not None and e.covered == c:
                    hit, hit_key = e, key
                    break
            if hit is None:
                break
            self._entries.move_to_end(hit_key)
            self.pool.retain(hit.pid)
            self.pool.metrics.prefix_hits += 1
            shared.append((hit.pid, hit.covered))
            parent = hit.link
            pos += hit.covered
            if hit.covered < P:
                break                   # divergence inside this page
        return shared, pos

    def evict(self, want_pages: int = 1) -> int:
        """Drop LRU entries until ``want_pages`` pages returned to the
        free list (entries whose page has other holders free nothing but
        still leave the cache).  Returns pages actually freed."""
        freed = 0
        while self._entries and freed < want_pages:
            _, e = self._entries.popitem(last=False)
            before = self.pool.free_pages
            self.pool.release(e.pid)
            freed += self.pool.free_pages - before
            self.pool.metrics.prefix_evictions += 1
        return freed

    def drop_all(self) -> None:
        while self._entries:
            _, e = self._entries.popitem(last=False)
            self.pool.release(e.pid)
            self.pool.metrics.prefix_evictions += 1
