"""Shared model configuration and sharding helpers.

All ten assigned architectures are expressed through one
:class:`ArchConfig`; the block composition is selected by ``arch_type``
and the optional sub-configs (MoE / MLA / SSM / hybrid).

Layer parameters are stored **stacked**: every per-layer leaf carries a
leading ``num_layers`` dimension so deep models lower through one
``lax.scan`` body (bounded HLO size; llama3-405b has 126 layers).
Mixed-block architectures (xLSTM, Hymba) unroll a Python loop over the
stacked slices instead.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_expert: int                  # per-expert FFN hidden size
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 => dense q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    expand: int = 2                # d_inner = expand * d_model
    conv_width: int = 4
    dt_rank: int = 0               # 0 => ceil(d_model / 16)
    chunk: int = 256               # chunked associative scan length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4           # every k-th block is sLSTM, rest mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    is_encoder: bool = False           # bidirectional, no decode shapes
    frontend: Optional[str] = None     # 'audio' | 'vision' (stubbed embeds)
    frontend_tokens: int = 256         # prefix length provided by the stub
    attention_window: Optional[int] = None   # native sliding-window attn
    # SWA variant used ONLY to build the long_500k config (DESIGN.md §4);
    # decode_32k keeps the full cache.
    long_context_window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    scan_layers: bool = True
    remat: bool = True
    dtype: str = "bfloat16"
    source: str = ""                   # citation bracket from the assignment

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/head
        shard over the 16-way model axis with lane alignment (unpadded
        49155-style vocabs force an unsharded head and a full-logits
        all-reduce — observed 200+ GB/step in the dry-run)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k is natively supported (SSM/hybrid) or via the
        sliding-window variant."""
        return (self.arch_type in ("ssm", "hybrid")
                or self.attention_window is not None
                or self.long_context_window is not None)

    def for_long_context(self) -> "ArchConfig":
        """The variant lowered for long_500k: enable the SWA window for
        full-attention archs (no-op for SSM/hybrid/native-SWA)."""
        if self.attention_window is None and self.long_context_window:
            return self.with_overrides(
                attention_window=self.long_context_window)
        return self

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- reduced variant for CPU smoke tests ---------------------------
    def smoke(self) -> "ArchConfig":
        kw = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=32,
            frontend_tokens=8 if self.frontend else self.frontend_tokens,
            scan_layers=False,
            remat=False,
            dtype="float32",
            attention_window=(16 if self.attention_window else None),
            long_context_window=(16 if self.long_context_window else None),
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4,
                experts_per_token=min(2, self.moe.experts_per_token),
                d_expert=64,
                num_shared_experts=min(1, self.moe.num_shared_experts),
                capacity_factor=4.0)   # dropless in smoke: exact decode
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=32,
                                  qk_rope_head_dim=16, v_head_dim=32)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=8, chunk=16)
        if self.xlstm:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2)
        return self.with_overrides(**kw)


# ----------------------------------------------------------------------
# Sharding helpers
# ----------------------------------------------------------------------

def _axis_size(mesh, axis) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def shard_dim(dim: int, mesh, axis: str = "model"):
    """Return ``axis`` if ``dim`` divides evenly over it, else None
    (replicated) — guarantees every config lowers on every mesh."""
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def param_specs_like(params, mesh, model_axis: str = "model",
                     fsdp_axis: Optional[str] = "data"):
    """Heuristic 2-D sharding.

    Megatron-style: the largest divisible dim of every >=2-D leaf shards
    over the model axis.  FSDP (ZeRO-3 storage): a second divisible dim
    shards over ``fsdp_axis`` so parameters are never replicated across
    the data axis — required for the >=100B configs to fit (DESIGN.md
    §5); XLA all-gathers them per layer during compute.
    """
    def spec_for(path, leaf):
        shape = leaf.shape
        if leaf.ndim == 0:
            return P()
        size = _axis_size(mesh, model_axis)
        fsdp_size = _axis_size(mesh, fsdp_axis) if fsdp_axis else 1
        spec = [None] * leaf.ndim
        # skip the leading stacked-layer dim of stacked leaves
        start = 1 if leaf.ndim >= 2 else 0
        order = sorted(range(start, leaf.ndim), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % size == 0 and shape[i] >= size:
                spec[i] = model_axis
                break
        if fsdp_axis and fsdp_size > 1:
            for i in order:
                if spec[i] is None and shape[i] % fsdp_size == 0 \
                        and shape[i] >= fsdp_size:
                    spec[i] = fsdp_axis
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def count_params(params) -> int:
    return sum(int(math.prod(p.shape)) for p in jax.tree.leaves(params))
