"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV are compressed through a low-rank latent ``c_kv = x W_dkv`` (rank
``kv_lora_rank``); per-head K(nope)/V are up-projected from the latent,
and a single shared rope-carrying key ``k_rope`` is computed directly
from x.  The decode cache stores only ``(c_kv, k_rope)`` —
``kv_lora_rank + qk_rope_head_dim`` floats per token instead of
``2 * kvH * hd`` — MLA's cache saving, which the decode rooflines show.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, attention_weights_mask,
                                 blockwise_gqa_attention,
                                 decode_attention_mask, dense_init,
                                 paged_gather, ring_cache_positions)

Array = jax.Array


def v_pad_to_match(v: Array, q: Array) -> Array:
    """Zero-pad v's head_dim to q's (blockwise core assumes equal dims)."""
    pad = q.shape[-1] - v.shape[-1]
    if pad <= 0:
        return v
    return jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, pad),))


class MLACache(NamedTuple):
    c_kv: Array     # (B, S, r)
    k_rope: Array   # (B, S, rope_hd)


def init_mla(key: Array, cfg) -> dict:
    a = cfg.mla
    dt = cfg.param_dtype
    H = cfg.num_heads
    ks = jax.random.split(key, 6)
    qk_hd = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "w_q": dense_init(ks[0], cfg.d_model, H * qk_hd, dt),
        "w_dkv": dense_init(ks[1], cfg.d_model, a.kv_lora_rank, dt),
        "w_uk": dense_init(ks[2], a.kv_lora_rank, H * a.qk_nope_head_dim, dt),
        "w_uv": dense_init(ks[3], a.kv_lora_rank, H * a.v_head_dim, dt),
        "w_kr": dense_init(ks[4], cfg.d_model, a.qk_rope_head_dim, dt),
        "w_o": dense_init(ks[5], H * a.v_head_dim, cfg.d_model, dt),
    }


def mla_block(p: dict, x: Array, positions: Array, cfg,
              cache: Optional[MLACache] = None,
              cache_pos: Optional[Array] = None,
              update: Optional[Array] = None,
              paged_table: Optional[Array] = None,
              paged_kernel: bool = False,
              q_lens: Optional[Array] = None,
              ) -> Tuple[Array, Optional[MLACache]]:
    a = cfg.mla
    B, T, D = x.shape
    H = cfg.num_heads
    qk_hd = a.qk_nope_head_dim + a.qk_rope_head_dim

    q = (x @ p["w_q"]).reshape(B, T, H, qk_hd)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = x @ p["w_dkv"]                              # (B, T, r)
    k_rope = (x @ p["w_kr"])[:, :, None, :]            # (B, T, 1, rope_hd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]

    if cache is not None and paged_table is not None:
        # paged latent decode (DESIGN.md §11): the (c_kv, k_rope) pair
        # of every valid token is written into the slot's owned pool
        # page, then the read runs in the ABSORBED form when
        # ``paged_kernel``: scores directly against the latent pages
        # with W_uk folded into the query and the output accumulated in
        # latent space (W_uv applied after) — the up-projected K/V
        # never exist.  The jnp fallback gathers the latent pages and
        # falls through to the shared unabsorbed decode math below;
        # both paths support the fused multi-query contract (``q_lens``
        # per slot, padding tokens drop-routed / garbage by contract).
        NP, P = cache.c_kv.shape[0], cache.c_kv.shape[1]
        M = paged_table.shape[1]
        start = cache_pos.astype(jnp.int32)                  # (B,)
        if q_lens is None:   # legacy single-token contract via update
            qlens = (jnp.ones((B,), jnp.int32) if update is None
                     else jnp.where(update, 1, 0).astype(jnp.int32))
        else:
            qlens = q_lens.astype(jnp.int32)
        pos_mat = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        pid = jnp.take_along_axis(paged_table,
                                  jnp.minimum(pos_mat // P, M - 1), axis=1)
        pid = jnp.where(jnp.arange(T)[None] < qlens[:, None], pid, NP)
        slot = pos_mat % P
        pages_kv = cache.c_kv.at[pid, slot].set(
            c_kv.astype(cache.c_kv.dtype), mode="drop")
        pages_kr = cache.k_rope.at[pid, slot].set(
            k_rope.astype(cache.k_rope.dtype), mode="drop")
        new_cache = MLACache(c_kv=pages_kv, k_rope=pages_kr)
        if paged_kernel:
            from repro.kernels.ops import paged_mla_attention_op
            r = a.kv_lora_rank
            w_uk = p["w_uk"].reshape(r, H, a.qk_nope_head_dim)
            q_abs = jnp.einsum("bthx,rhx->bthr",
                               q_nope.astype(jnp.float32),
                               w_uk.astype(jnp.float32))
            o_lat = paged_mla_attention_op(
                q_abs, q_rope, pages_kv, pages_kr, paged_table, start,
                qlens, scale=1.0 / math.sqrt(qk_hd),
                window=cfg.attention_window)
            w_uv = p["w_uv"].reshape(r, H, a.v_head_dim)
            out = jnp.einsum("bthr,rhx->bthx", o_lat,
                             w_uv.astype(jnp.float32)).astype(x.dtype)
            out = out.reshape(B, T, H * a.v_head_dim)
            return out @ p["w_o"], new_cache
        kv_lat = paged_gather(pages_kv, paged_table)         # (B, M*P, r)
        kr = paged_gather(pages_kr, paged_table)
        k_pos = jnp.broadcast_to(jnp.arange(kv_lat.shape[1])[None],
                                 (B, kv_lat.shape[1]))
        q_pos = pos_mat
    elif cache is None:
        kv_lat, kr = c_kv, k_rope
        k_pos = positions[0] if positions.ndim > 1 else positions
        q_pos = k_pos
        # prefill/training produce the latent cache for decode handoff
        new_cache = MLACache(c_kv=c_kv, k_rope=k_rope)
    elif jnp.ndim(cache_pos) == 0:
        S = cache.c_kv.shape[1]
        slot = (cache_pos % S).astype(jnp.int32)
        kv_lat = cache.c_kv.at[:, slot].set(c_kv[:, 0].astype(cache.c_kv.dtype))
        kr = cache.k_rope.at[:, slot].set(k_rope[:, 0].astype(cache.k_rope.dtype))
        slots = jnp.arange(S)
        wraps = (cache_pos // S).astype(jnp.int32)
        k_pos = jnp.where(slots <= slot, wraps * S + slots,
                          (wraps - 1) * S + slots)
        q_pos = cache_pos[None].astype(jnp.int32)
        new_cache = MLACache(c_kv=kv_lat, k_rope=kr)
    else:
        # per-slot decode (see layers.attention_block): masked slots
        # keep their latent cache untouched
        S = cache.c_kv.shape[1]
        slot, k_pos = ring_cache_positions(cache_pos, S)   # (B,), (B,S)
        row = jnp.arange(B)
        if update is not None:
            row = jnp.where(update, row, B)
        kv_lat = cache.c_kv.at[row, slot].set(
            c_kv[:, 0].astype(cache.c_kv.dtype), mode="drop")
        kr = cache.k_rope.at[row, slot].set(
            k_rope[:, 0].astype(cache.k_rope.dtype), mode="drop")
        q_pos = cache_pos[:, None].astype(jnp.int32)
        new_cache = MLACache(c_kv=kv_lat, k_rope=kr)

    k_nope = jnp.einsum("bsr,rx->bsx", kv_lat, p["w_uk"]).reshape(
        kv_lat.shape[0], kv_lat.shape[1], H, a.qk_nope_head_dim)
    v = jnp.einsum("bsr,rx->bsx", kv_lat, p["w_uv"]).reshape(
        kv_lat.shape[0], kv_lat.shape[1], H, a.v_head_dim)

    if cache is None and T > 1024:
        # flash-style path for long prefill/train: fold the shared rope
        # key into per-head keys and reuse the blockwise GQA core.
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        kr_b = jnp.broadcast_to(kr[:, :, None, :],
                                k_nope.shape[:3] + (a.qk_rope_head_dim,))
        k_cat = jnp.concatenate([k_nope, kr_b], axis=-1)
        # blockwise core scales by 1/sqrt(qk_hd) internally via head_dim
        out = blockwise_gqa_attention(
            q_cat, k_cat, v_pad_to_match(v, q_cat), q_pos, k_pos,
            causal=True, window=cfg.attention_window)
        out = out[..., :a.v_head_dim]
    else:
        if q_pos.ndim == 2:   # per-slot decode: (B,1) q vs (B,S) cache
            mask_b = decode_attention_mask(
                q_pos, k_pos, True, cfg.attention_window)[:, None]
        else:
            mask = attention_weights_mask(q_pos, k_pos, causal=True,
                                          window=cfg.attention_window)
            mask_b = mask[None, None]
        scale = 1.0 / math.sqrt(qk_hd)
        logits = (jnp.einsum("bqhx,bshx->bhqs", q_nope, k_nope)
                  + jnp.einsum("bqhx,bsx->bhqs", q_rope, kr)).astype(jnp.float32)
        logits = logits * scale
        logits = jnp.where(mask_b, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshx->bqhx", probs, v)
    out = out.reshape(B, T, H * a.v_head_dim)
    return out @ p["w_o"], new_cache
