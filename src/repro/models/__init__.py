"""repro.models — composable model definitions for the assigned
architectures (dense / MoE / MLA / SSM / hybrid / audio / VLM)."""
from repro.models.common import (ArchConfig, MLAConfig, MoEConfig, SSMConfig,
                                 XLSTMConfig, count_params,
                                 param_specs_like)
from repro.models.model import DecodeState, Model, PagedDecodeState
from repro.models.registry import (ARCH_IDS, INPUT_SHAPES, InputShape,
                                   get_config, get_smoke_config,
                                   pair_supported)

__all__ = [
    "ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "XLSTMConfig",
    "Model", "DecodeState", "PagedDecodeState", "count_params",
    "param_specs_like",
    "ARCH_IDS", "INPUT_SHAPES", "InputShape", "get_config",
    "get_smoke_config", "pair_supported",
]
