"""Architecture registry.  The canonical per-arch configs live in
``repro/configs/<id>.py`` (the deliverable); this module provides lookup
and the input-shape registry shared by dry-run / benchmarks / tests."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.models.common import ArchConfig

ARCH_IDS = (
    "granite-3-2b",
    "hubert-xlarge",
    "paligemma-3b",
    "dbrx-132b",
    "yi-34b",
    "hymba-1.5b",
    "xlstm-350m",
    "qwen1.5-110b",
    "llama3-405b",
    "deepseek-v2-lite-16b",
)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCH_IDS and arch_id != "paper-logreg":
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(_module_name(arch_id))
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    return get_config(arch_id).smoke()


def pair_supported(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) lowers; if not, the DESIGN.md-documented
    reason."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch without sliding-window "
                       "variant: long_500k skipped")
    return True, ""
