"""Core neural layers: RMSNorm, RoPE, GQA attention (causal /
bidirectional / sliding-window / KV-cache decode), gated MLP.

Pure functional JAX; parameters are plain dicts of arrays.  All matmul
layouts are (in_features, out_features) so the model axis shards the
output dim (Megatron column-parallel) or input dim (row-parallel) via
GSPMD propagation from the param specs.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key: Array, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ----------------------------------------------------------------------
# RMSNorm
# ----------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, hd); positions: (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array          # (B, S, kvH, hd) — S = cache capacity
    v: Array
    # positions currently written are derived from the decode position


def attention_weights_mask(q_pos: Array, k_pos: Array, causal: bool,
                           window: Optional[int],
                           full_prefix: int = 0) -> Array:
    """(..., Tq, Tk) boolean mask. True = attend.  ``full_prefix`` marks
    the first positions as bidirectionally attendable (PaliGemma-style
    prefix-LM)."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        c = q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            c &= q_pos[:, None] - k_pos[None, :] < window
        if full_prefix:
            c |= k_pos[None, :] < full_prefix
        m &= c
    elif window is not None:
        m &= jnp.abs(q_pos[:, None] - k_pos[None, :]) < window
    m &= k_pos[None, :] >= 0          # negative k_pos marks empty cache slots
    return m


def blockwise_gqa_attention(q: Array, k: Array, v: Array,
                            q_pos: Array, k_pos: Array, *,
                            causal: bool, window: Optional[int],
                            full_prefix: int = 0,
                            q_block: int = 512, k_block: int = 1024
                            ) -> Array:
    """Flash-style attention: online-softmax scan over key blocks so the
    (Tq, Tk) score matrix is never materialized (a 32k prefill otherwise
    needs O(T^2) temp — observed 0.5 TB/device in the dry-run).

    q: (B, Tq, H, hd); k/v: (B, Tk, kvH, hd).  Positions drive the
    causal/window/prefix mask exactly like
    :func:`attention_weights_mask`.
    """
    B, Tq, H, hd = q.shape
    Tk, kvH = k.shape[1], k.shape[2]
    G = H // kvH
    qb = min(q_block, Tq)
    kb = min(k_block, Tk)
    pq, pk = (-Tq) % qb, (-Tk) % kb

    qf = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qp = jnp.pad(q_pos, (0, pq), constant_values=-1)
    kp = jnp.pad(k_pos, (0, pk), constant_values=-(1 << 30))
    nq, nk = qf.shape[1] // qb, kf.shape[1] // kb

    qf = qf.reshape(B, nq, qb, kvH, G, hd).astype(jnp.float32)
    kf = kf.reshape(B, nk, kb, kvH, hd).astype(jnp.float32)
    vf = vf.reshape(B, nk, kb, kvH, hd).astype(jnp.float32)
    qp = qp.reshape(nq, qb)
    kp = kp.reshape(nk, kb)
    scale = 1.0 / math.sqrt(hd)

    def q_step(_, qi):
        qblk, qpos = qi                       # (B,qb,kvH,G,hd), (qb,)

        @jax.checkpoint
        def k_step(carry, ki):
            acc, m, l = carry
            kblk, vblk, kpos = ki
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                cm = qpos[:, None] >= kpos[None, :]
                if window is not None:
                    cm &= qpos[:, None] - kpos[None, :] < window
                if full_prefix:
                    cm |= kpos[None, :] < full_prefix
                mask &= cm
            mask &= kpos[None, :] >= 0
            mask &= qpos[:, None] >= 0
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vblk)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, kvH, G, qb, hd), jnp.float32)
        m0 = jnp.full((B, kvH, G, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((B, kvH, G, qb), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            k_step, (acc0, m0, l0),
            (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4), kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,kvH,G,qb,hd)
        return None, out.transpose(0, 3, 1, 2, 4)        # (B,qb,kvH,G,hd)

    _, outs = jax.lax.scan(q_step, None,
                           (qf.transpose(1, 0, 2, 3, 4, 5), qp))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qb, H, hd)
    return out[:, :Tq].astype(v.dtype)


def ring_cache_positions(cache_pos: Array, S: int) -> Tuple[Array, Array]:
    """Per-slot ring-buffer accounting for decode caches.  ``cache_pos``
    is the (B,) absolute next position of each batch slot; returns
    ``(slot, abs_pos)`` with ``slot`` (B,) the ring slot to write and
    ``abs_pos`` (B, S) the absolute position currently stored in every
    ring slot AFTER the write (never-written slots come out negative,
    which :func:`attention_weights_mask` semantics treat as empty)."""
    slot = (cache_pos % S).astype(jnp.int32)
    wraps = (cache_pos // S).astype(jnp.int32)
    slots = jnp.arange(S)
    abs_pos = jnp.where(slots[None, :] <= slot[:, None],
                        wraps[:, None] * S + slots[None, :],
                        (wraps[:, None] - 1) * S + slots[None, :])
    return slot, abs_pos


def decode_attention_mask(q_pos: Array, k_pos: Array, causal: bool,
                          window: Optional[int]) -> Array:
    """Batched decode mask: ``q_pos`` (B, 1), ``k_pos`` (B, S) ->
    (B, 1, S) boolean, the per-slot analog of
    :func:`attention_weights_mask` (negative k_pos = empty slot)."""
    m = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    if causal:
        c = q_pos[:, :, None] >= k_pos[:, None, :]
        if window is not None:
            c &= q_pos[:, :, None] - k_pos[:, None, :] < window
        m &= c
    m &= k_pos[:, None, :] >= 0
    return m


def paged_gather(pages: Array, page_table: Array) -> Array:
    """Collect one (B, M*P, ...) contiguous view of each slot's pages.
    ``pages`` is the pool array (NP, P, ...tail); ``page_table`` (B, M)
    physical ids.  Padded table entries contribute garbage rows whose
    positions are >= the slot's length and are masked by the caller."""
    B, M = page_table.shape
    g = pages[page_table]                       # (B, M, P, ...tail)
    return g.reshape((B, M * pages.shape[1]) + pages.shape[2:])


def gqa_attention(q: Array, k: Array, v: Array, mask: Array) -> Array:
    """q: (B, Tq, H, hd); k/v: (B, Tk, kvH, hd); mask: (Tq, Tk) or
    (B, Tq, Tk).  Grouped-query: H = G * kvH."""
    B, Tq, H, hd = q.shape
    kvH = k.shape[2]
    G = H // kvH
    q = q.reshape(B, Tq, kvH, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if mask.ndim == 2:
        mask_b = mask[None, None, None]
    else:
        mask_b = mask[:, None, None]
    logits = jnp.where(mask_b, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Tq, H, hd)


def init_attention(key: Array, cfg) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, cfg.param_dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, cfg.param_dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, cfg.param_dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), cfg.param_dtype)
    return p


def attention_block(p: dict, x: Array, positions: Array, cfg,
                    cache: Optional[KVCache] = None,
                    cache_pos: Optional[Array] = None,
                    causal: bool = True,
                    full_prefix: int = 0,
                    update: Optional[Array] = None,
                    paged_table: Optional[Array] = None,
                    paged_kernel: bool = False,
                    q_lens: Optional[Array] = None,
                    ) -> Tuple[Array, Optional[KVCache]]:
    """Full attention sub-block (pre-norm residual handled by caller).

    Training/prefill: ``cache=None`` — self-attention over x.
    Decode: ``cache`` given, x is (B, 1, D), ``cache_pos`` the absolute
    position; the KV pair is written at ``cache_pos % S`` (ring buffer,
    S = window for SWA else seq_len).  ``cache_pos`` may be scalar (all
    slots in lockstep — the legacy/dry-run path) or (B,) per-slot, in
    which case ``update`` optionally masks which slots write their KV
    (masked-out slots keep their cache bytes untouched — the serving
    prefill isolation fix).

    Paged decode (``paged_table`` given, DESIGN.md §11): ``cache``
    holds POOL pages (NP, P, kvH, hd) instead of per-slot rows; the new
    KV is written at page ``paged_table[b, pos // P]`` slot ``pos % P``
    and the read attends the slot's gathered pages (jnp gather, or the
    Pallas paged-attention kernel when ``paged_kernel``).  Requires
    per-slot ``cache_pos``; the serving engine guarantees every written
    page is exclusively owned (copy-on-write upstream).

    Fused multi-query paged decode (``q_lens`` given): x is (B, C, D)
    with up to C tokens per slot — chunked-prefill chunks and decode
    tokens share one forward.  ``cache_pos`` is the tokens per slot
    BEFORE this pass ("start"); token ``c`` of slot ``b`` sits at
    absolute position ``start[b] + c``, writes its page, and attends
    everything up to itself.  Tokens ``c >= q_lens[b]`` are padding:
    their writes are drop-routed and their outputs garbage by contract.
    """
    B, T, D = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.num_heads, hd)
    k = k.reshape(B, T, cfg.num_kv_heads, hd)
    v = v.reshape(B, T, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and paged_table is not None:
        # paged decode: write into the owned pool page, read via gather
        # (or the Pallas kernel).  With a single page of size >= max_seq
        # per slot the gather is the dense cache row and the jnp path is
        # the same masked gqa_attention as the per-slot dense branch —
        # the parity-anchor contract (DESIGN.md §11).
        NP, P = cache.k.shape[0], cache.k.shape[1]
        M = paged_table.shape[1]
        start = cache_pos.astype(jnp.int32)                 # (B,)
        if q_lens is None:   # legacy single-token contract via update
            qlens = (jnp.ones((B,), jnp.int32) if update is None
                     else jnp.where(update, 1, 0).astype(jnp.int32))
        else:
            qlens = q_lens.astype(jnp.int32)
        pos_mat = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        pid = jnp.take_along_axis(paged_table,
                                  jnp.minimum(pos_mat // P, M - 1), axis=1)
        pid = jnp.where(jnp.arange(T)[None] < qlens[:, None], pid, NP)
        slot = pos_mat % P
        k_new = cache.k.at[pid, slot].set(k.astype(cache.k.dtype),
                                          mode="drop")
        v_new = cache.v.at[pid, slot].set(v.astype(cache.v.dtype),
                                          mode="drop")
        if paged_kernel:
            from repro.kernels.ops import paged_attention_batched_op
            out = paged_attention_batched_op(
                q, k_new, v_new, paged_table, start, qlens,
                window=cfg.attention_window).astype(v.dtype)
        else:
            kg = paged_gather(k_new, paged_table)           # (B, M*P, ...)
            vg = paged_gather(v_new, paged_table)
            k_pos = jnp.broadcast_to(jnp.arange(kg.shape[1])[None],
                                     (B, kg.shape[1]))
            mask = decode_attention_mask(pos_mat, k_pos, causal,
                                         cfg.attention_window)
            out = gqa_attention(q, kg, vg, mask)
        out = out.reshape(B, T, cfg.num_heads * hd)
        return out @ p["wo"], KVCache(k=k_new, v=v_new)
    if cache is None:
        k_pos = positions[0] if positions.ndim > 1 else positions
        q_pos = k_pos
        if T > 1024:
            # flash-style blockwise path: O(block^2) memory
            out = blockwise_gqa_attention(
                q, k, v, q_pos, k_pos, causal=causal,
                window=cfg.attention_window, full_prefix=full_prefix)
        else:
            mask = attention_weights_mask(q_pos, k_pos, causal,
                                          cfg.attention_window,
                                          full_prefix=full_prefix)
            out = gqa_attention(q, k, v, mask)
        new_cache = KVCache(k=k, v=v)
    elif jnp.ndim(cache_pos) == 0:
        S = cache.k.shape[1]
        slot = (cache_pos % S).astype(jnp.int32)
        k_new = cache.k.at[:, slot].set(k[:, 0].astype(cache.k.dtype))
        v_new = cache.v.at[:, slot].set(v[:, 0].astype(cache.v.dtype))
        # absolute positions of cache slots given ring layout
        slots = jnp.arange(S)
        wraps = (cache_pos // S).astype(jnp.int32)
        abs_pos = jnp.where(slots <= slot, wraps * S + slots,
                            (wraps - 1) * S + slots)
        q_pos = cache_pos[None].astype(jnp.int32)
        mask = attention_weights_mask(q_pos, abs_pos, causal,
                                      cfg.attention_window)
        out = gqa_attention(q, k_new, v_new, mask)
        new_cache = KVCache(k=k_new, v=v_new)
    else:
        # per-slot decode: each batch slot writes at ITS ring position;
        # slots masked out by ``update`` leave their cache untouched
        # (the write is routed to a dropped out-of-bounds row)
        S = cache.k.shape[1]
        slot, abs_pos = ring_cache_positions(cache_pos, S)
        row = jnp.arange(B)
        if update is not None:
            row = jnp.where(update, row, B)
        k_new = cache.k.at[row, slot].set(k[:, 0].astype(cache.k.dtype),
                                          mode="drop")
        v_new = cache.v.at[row, slot].set(v[:, 0].astype(cache.v.dtype),
                                          mode="drop")
        q_pos = cache_pos[:, None].astype(jnp.int32)
        mask = decode_attention_mask(q_pos, abs_pos, causal,
                                     cfg.attention_window)
        out = gqa_attention(q, k_new, v_new, mask)
        new_cache = KVCache(k=k_new, v=v_new)

    out = out.reshape(B, T, cfg.num_heads * hd)
    return out @ p["wo"], new_cache


# ----------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ----------------------------------------------------------------------

def init_mlp(key: Array, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp_block(p: dict, x: Array, activation: str = "silu") -> Array:
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
