"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Expert-parallel under GSPMD: expert-stacked weights (E, d, f) carry a
``P('model', ...)`` (or ``P(None, 'model', ...)``) sharding so the grouped
matmul runs expert-parallel and the dispatch/combine scatter becomes the
all-to-all the roofline measures.

Dispatch algorithm (dropless-up-to-capacity, MaxText-style):
  1. router logits -> top-k (expert_id, weight) per token,
  2. flatten the (token, k) choices, sort by expert_id,
  3. rank each choice within its expert via a cumsum over the sorted
     one-hot; drop ranks >= capacity,
  4. scatter tokens into an (E, C, D) buffer, grouped-matmul the experts,
  5. combine back with the router weights (scatter-add to tokens).

Aux load-balance loss follows Shazeer et al. / Switch:
``E * sum_e f_e * p_e`` with f = fraction of tokens routed, p = mean
router prob.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array


def init_moe(key: Array, cfg) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 7)
    dt = cfg.param_dtype
    E, D, F = m.num_experts, cfg.d_model, m.d_expert
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) / math.sqrt(D)).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) / math.sqrt(D)).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) / math.sqrt(F)).astype(dt),
    }
    if m.num_shared_experts:
        Fs = m.d_expert * m.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], D, Fs, dt),
            "w_up": dense_init(ks[5], D, Fs, dt),
            "w_down": dense_init(ks[6], Fs, D, dt),
        }
    return p


def _capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.experts_per_token / m.num_experts
                      * m.capacity_factor))
    return max(8, -(-c // 8) * 8)   # pad to multiple of 8 (sublane)


def moe_block(p: dict, x: Array, cfg) -> Tuple[Array, Array]:
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar)."""
    m = cfg.moe
    B, T, D = x.shape
    N = B * T
    E, K = m.num_experts, m.experts_per_token
    C = _capacity(N, cfg)
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                   # (N, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)   # renormalize

    # ---- aux load-balance loss (Switch-style) ------------------------
    frac = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = m.router_aux_loss * E * jnp.sum(frac * mean_p)

    # ---- sort-based dispatch ------------------------------------------
    flat_e = top_e.reshape(-1)                                # (N*K,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), K)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # rank within expert group
    ones = jnp.ones_like(se)
    pos_in_sorted = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = pos_in_sorted - seg_start[se]
    keep = rank < C
    slot = se * C + jnp.where(keep, rank, 0)                  # (N*K,)

    # scatter tokens into (E*C, D) buffer
    buf = jnp.zeros((E * C, D), x.dtype)
    contrib = jnp.where(keep[:, None], xf[stok], 0)
    buf = buf.at[slot].add(jnp.where(keep[:, None], contrib, 0))
    buf = buf.reshape(E, C, D)

    # ---- expert computation (grouped matmul, expert-parallel) --------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)

    # ---- combine ------------------------------------------------------
    gathered = out_buf[slot] * jnp.where(keep, sw, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((N, D), x.dtype).at[stok].add(gathered)
    out = out.reshape(B, T, D)

    # ---- shared experts (DeepSeek) -------------------------------------
    if "shared" in p:
        s = p["shared"]
        sh = (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
        out = out + sh
    return out, aux
