"""Model assembly: embeddings -> blocks (scan or unrolled) -> head.

One :class:`Model` class covers all six arch families via block
composition:

* ``dense``   — GQA attention + gated MLP (granite, yi, qwen, llama3)
* ``moe``     — GQA attention + MoE FFN (dbrx); ``mla`` sub-config swaps
  the attention for Multi-head Latent Attention (deepseek-v2-lite)
* ``ssm``     — xLSTM: mLSTM blocks with every k-th an sLSTM (xlstm-350m)
* ``hybrid``  — parallel attention + Mamba heads per layer (hymba)
* ``audio``   — bidirectional encoder over stub frame embeddings (hubert)
* ``vlm``     — stub patch embeddings prefixed to a gemma-style decoder
  with full attention over the prefix (paligemma)

Training entry: ``loss(params, batch)``; decode entry:
``serve_step(params, token, state)`` (one new token against the cache).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_lib
from repro.models.common import ArchConfig
from repro.models.layers import (KVCache, attention_block, embed_init,
                                 init_attention, init_mlp, mlp_block,
                                 rmsnorm)
from repro.models.mla import MLACache, init_mla, mla_block
from repro.models.moe import init_moe, moe_block

Array = jax.Array


class DecodeState(NamedTuple):
    """Per-layer decode state; leaves stacked over layers for scanned
    models, tuples for unrolled (xLSTM)."""
    caches: Any
    position: Array       # scalar int32 — next absolute position


class PagedDecodeState(NamedTuple):
    """Decode state over a shared page pool (DESIGN.md §11).  Attention
    cache leaves (KVCache/MLACache) hold POOL pages — (num_pages,
    page_size, ...) instead of (batch, seq, ...) — addressed through one
    page table shared by every layer (all layers allocate identically,
    so one logical page id maps to the same physical row per layer).
    Recurrent (SSM/mamba) leaves stay dense per-slot: they are O(1) per
    sequence and have nothing to page."""
    caches: Any
    page_table: Array     # (B, max_pages) int32 physical page ids
    seq_lens: Array       # (B,) int32 tokens written per slot


# ======================================================================
# Blocks
# ======================================================================

def _block_init(key: Array, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    D = cfg.d_model
    p = {"ln1": jnp.ones((D,), dt)}
    if kind in ("dense", "encoder", "vlm"):
        p["attn"] = init_attention(ks[0], cfg)
        p["ln2"] = jnp.ones((D,), dt)
        p["mlp"] = init_mlp(ks[1], D, cfg.d_ff, dt)
    elif kind == "moe":
        if cfg.mla is not None:
            p["attn"] = init_mla(ks[0], cfg)
        else:
            p["attn"] = init_attention(ks[0], cfg)
        p["ln2"] = jnp.ones((D,), dt)
        p["moe"] = init_moe(ks[1], cfg)
    elif kind == "hybrid":
        p["attn"] = init_attention(ks[0], cfg)
        p["mamba"] = ssm_lib.init_mamba(ks[1], cfg)
        p["ln_attn"] = jnp.ones((D,), dt)
        p["ln_mamba"] = jnp.ones((D,), dt)
        p["ln2"] = jnp.ones((D,), dt)
        p["mlp"] = init_mlp(ks[2], D, cfg.d_ff, dt)
    elif kind == "mlstm":
        p["mix"] = ssm_lib.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["mix"] = ssm_lib.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _block_apply(p: dict, x: Array, positions: Array, cfg: ArchConfig,
                 kind: str, cache=None, cache_pos=None, prefix_len: int = 0,
                 update=None, paged_table=None,
                 paged_kernel: bool = False,
                 q_lens=None) -> Tuple[Array, Any, Array]:
    """-> (x_out, new_cache, aux_loss).  ``update`` (decode only): (B,)
    mask of batch slots whose attention caches may be written; recurrent
    (SSM) states are masked by the caller (:meth:`Model.serve_step`).
    ``paged_table`` (paged decode only): the (B, max_pages) page table
    routed to the attention caches — recurrent states never page.
    ``q_lens`` (fused paged decode only): per-slot valid-token counts
    for the multi-query contract (layers.attention_block)."""
    aux = jnp.zeros((), jnp.float32)
    causal = not cfg.is_encoder
    if kind in ("dense", "encoder", "vlm"):
        h, new_cache = attention_block(p["attn"], rmsnorm(x, p["ln1"], cfg.rms_eps),
                                       positions, cfg, cache=cache,
                                       cache_pos=cache_pos, causal=causal,
                                       full_prefix=prefix_len,
                                       update=update,
                                       paged_table=paged_table,
                                       paged_kernel=paged_kernel,
                                       q_lens=q_lens)
        x = x + h
        x = x + mlp_block(p["mlp"], rmsnorm(x, p["ln2"], cfg.rms_eps),
                          activation="gelu" if kind == "vlm" else "silu")
    elif kind == "moe":
        xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
        if cfg.mla is not None:
            h, new_cache = mla_block(p["attn"], xn, positions, cfg,
                                     cache=cache, cache_pos=cache_pos,
                                     update=update,
                                     paged_table=paged_table,
                                     paged_kernel=paged_kernel,
                                     q_lens=q_lens)
        else:
            h, new_cache = attention_block(p["attn"], xn, positions, cfg,
                                           cache=cache, cache_pos=cache_pos,
                                           causal=True, update=update,
                                           paged_table=paged_table,
                                           paged_kernel=paged_kernel,
                                           q_lens=q_lens)
        x = x + h
        mo, aux = moe_block(p["moe"], rmsnorm(x, p["ln2"], cfg.rms_eps), cfg)
        x = x + mo
    elif kind == "hybrid":
        xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
        a_cache = m_state = None
        if cache is not None:
            a_cache, m_state = cache
        h_attn, a_new = attention_block(p["attn"], xn, positions, cfg,
                                        cache=a_cache, cache_pos=cache_pos,
                                        causal=True, update=update,
                                        paged_table=paged_table,
                                        paged_kernel=paged_kernel,
                                        q_lens=q_lens)
        h_mamba, m_new = ssm_lib.mamba_forward(p["mamba"], xn, cfg,
                                               state=m_state)
        # parallel-head fusion (arXiv:2411.13676): mean of normalized outputs
        fused = 0.5 * (rmsnorm(h_attn, p["ln_attn"], cfg.rms_eps)
                       + rmsnorm(h_mamba, p["ln_mamba"], cfg.rms_eps))
        x = x + fused
        x = x + mlp_block(p["mlp"], rmsnorm(x, p["ln2"], cfg.rms_eps))
        new_cache = (a_new, m_new)
    elif kind == "mlstm":
        xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
        h, new_cache = ssm_lib.mlstm_forward(p["mix"], xn, cfg, state=cache)
        x = x + h
    elif kind == "slstm":
        xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
        h, new_cache = ssm_lib.slstm_forward(p["mix"], xn, cfg, state=cache)
        x = x + h
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def _pad_cache_capacity(caches: Any, extra: int) -> Any:
    """Grow the sequence axis of attention caches by ``extra`` empty
    slots (SSM states are O(1) and untouched).  Works for stacked
    (L, B, S, ...) and unstacked (B, S, ...) layouts: the seq axis sits
    at -3 for KVCache and -2 for MLACache leaves."""

    def pad_axis(x, axis):
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, extra)
        return jnp.pad(x, widths)

    def rec(c):
        if isinstance(c, KVCache):
            return KVCache(k=pad_axis(c.k, -3), v=pad_axis(c.v, -3))
        if isinstance(c, MLACache):
            return MLACache(c_kv=pad_axis(c.c_kv, -2),
                            k_rope=pad_axis(c.k_rope, -2))
        if isinstance(c, tuple) and not hasattr(c, "_fields"):
            return tuple(rec(e) for e in c)
        return c

    return rec(caches)


def map_cache_tree(tree: Any, on_attention, on_leaf, other: Any = None
                   ) -> Any:
    """THE decode-cache tree walk: ``on_attention`` handles whole
    KVCache/MLACache nodes, ``on_leaf`` every other array leaf
    (recurrent SSM state), tuples/NamedTuples recurse preserving type.
    With ``other`` given, walks two same-structure trees zipped and the
    callbacks take ``(leaf, other_leaf)``.  Every paged/dense cache
    transformation (masking, prefill scatter, COW copy, sharding specs,
    byte accounting) goes through here, so a new attention-cache
    NamedTuple is added in ONE place."""
    zipped = other is not None

    def rec(c, o):
        if isinstance(c, (KVCache, MLACache)):
            return on_attention(c, o) if zipped else on_attention(c)
        if isinstance(c, tuple):
            pairs = zip(c, o) if zipped else ((e, None) for e in c)
            merged = tuple(rec(e, oe) for e, oe in pairs)
            return type(c)(*merged) if hasattr(c, "_fields") else merged
        return on_leaf(c, o) if zipped else on_leaf(c)

    return rec(tree, other)


def _mask_recurrent_states(old: Any, new: Any, update: Array,
                           batch_axis: int) -> Any:
    """Merge decode states for a per-slot ``update`` mask: attention
    caches (KVCache/MLACache) already routed masked-out writes to a
    dropped row inside their blocks and pass through; every other array
    leaf is a recurrent (SSM) state updated wholesale, so masked-out
    slots get their OLD rows back along ``batch_axis`` (1 for stacked
    scan layouts, 0 for unstacked)."""

    def merge(o, n):
        shape = [1] * n.ndim
        shape[batch_axis] = n.shape[batch_axis]
        return jnp.where(update.reshape(shape), n, o)

    return map_cache_tree(old, on_attention=lambda o, n: n, on_leaf=merge,
                          other=new)


# ======================================================================
# Model
# ======================================================================

def _layer_kinds(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.arch_type == "dense":
        return ("dense",) * cfg.num_layers
    if cfg.arch_type == "moe":
        return ("moe",) * cfg.num_layers
    if cfg.arch_type == "hybrid":
        return ("hybrid",) * cfg.num_layers
    if cfg.arch_type == "audio":
        return ("encoder",) * cfg.num_layers
    if cfg.arch_type == "vlm":
        return ("vlm",) * cfg.num_layers
    if cfg.arch_type == "ssm":  # xLSTM mix
        k = cfg.xlstm.slstm_every
        return tuple("slstm" if (i + 1) % k == 0 else "mlstm"
                     for i in range(cfg.num_layers))
    raise ValueError(cfg.arch_type)


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.kinds = _layer_kinds(cfg)
        self.uniform = len(set(self.kinds)) == 1
        self.scan = cfg.scan_layers and self.uniform

    @property
    def attention_only(self) -> bool:
        """True when every layer's decode state is attention cache only
        (no recurrent SSM/mamba leaves) — the archs eligible for padded-
        bucket prefill and chunked (multi-token) paged decode: tail
        padding sits behind the causal mask, whereas a recurrent scan
        would thread garbage tokens through its state."""
        return all(k in ("dense", "encoder", "vlm", "moe")
                   for k in self.kinds)

    # -- params ----------------------------------------------------------
    def init_params(self, key: Array) -> dict:
        cfg = self.cfg
        k_embed, k_layers, k_head = jax.random.split(key, 3)
        params: dict = {"final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype)}
        # vocab padded to a multiple of 256 so embed/head shard over the
        # model axis (common.ArchConfig.padded_vocab)
        if cfg.frontend != "audio":
            params["embed"] = embed_init(k_embed, cfg.padded_vocab,
                                         cfg.d_model, cfg.param_dtype)
        else:
            params["embed_norm"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        if not cfg.tie_embeddings or cfg.frontend == "audio":
            params["lm_head"] = embed_init(k_head, cfg.padded_vocab,
                                           cfg.d_model, cfg.param_dtype).T

        layer_keys = jax.random.split(k_layers, cfg.num_layers)
        if self.uniform:
            kind = self.kinds[0]
            if self.scan:
                params["layers"] = jax.vmap(
                    lambda k: _block_init(k, cfg, kind))(layer_keys)
            else:
                stacked = [_block_init(k, cfg, kind) for k in layer_keys]
                params["layers"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *stacked)
        else:
            params["layers"] = tuple(
                _block_init(k, cfg, kind)
                for k, kind in zip(layer_keys, self.kinds))
        return params

    # -- embedding -------------------------------------------------------
    def _embed(self, params: dict, batch: dict) -> Tuple[Array, Array]:
        """-> (x (B, T, D), positions (T,))."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = rmsnorm(batch["embeds"], params["embed_norm"], cfg.rms_eps)
        elif cfg.frontend == "vision":
            tok = params["embed"][batch["tokens"]]
            x = jnp.concatenate([batch["embeds"].astype(tok.dtype), tok], axis=1)
        else:
            x = params["embed"][batch["tokens"]]
        if cfg.frontend != "vlm":
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
        positions = jnp.arange(x.shape[1])
        return x, positions

    # -- forward ----------------------------------------------------------
    def forward(self, params: dict, batch: dict, *,
                collect_caches: bool = False, last_token_only: bool = False,
                last_index: Optional[Array] = None):
        """Training/prefill forward.  -> (logits (B, T, V_pad), aux_loss)
        [+ per-layer caches if ``collect_caches``].  ``last_index``
        (bucketed prefill): dynamic true prompt length — the head runs
        on the single hidden state at position ``last_index - 1``, so a
        prompt padded to its bucket emits the same logits as the
        unpadded run (causal masking keeps tail padding out of every
        earlier position)."""
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        prefix_len = (batch["embeds"].shape[1]
                      if cfg.frontend == "vision" else 0)

        if self.scan:
            kind = self.kinds[0]

            def body(carry, layer_p):
                h, aux = carry
                h, c, a = _block_apply(layer_p, h, positions, cfg, kind,
                                       prefix_len=prefix_len)
                return (h, aux + a), (c if collect_caches else None)

            body_fn = jax.checkpoint(body) if cfg.remat else body
            (x, aux), caches = jax.lax.scan(
                body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
        else:
            aux = jnp.zeros((), jnp.float32)
            cache_list = []
            layers = params["layers"]
            for i, kind in enumerate(self.kinds):
                lp = (layers[i] if isinstance(layers, tuple)
                      else jax.tree.map(lambda t: t[i], layers))
                apply = functools.partial(_block_apply, kind=kind,
                                          prefix_len=prefix_len)
                if cfg.remat:
                    apply = jax.checkpoint(apply, static_argnums=(3,))
                x, c, a = apply(lp, x, positions, cfg)
                cache_list.append(c)
                aux = aux + a
            caches = tuple(cache_list) if collect_caches else None

        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        if last_index is not None:
            idx = jnp.maximum(jnp.asarray(last_index, jnp.int32) - 1, 0)
            x = jax.lax.dynamic_slice_in_dim(x, idx, 1, 1)
        elif last_token_only:
            x = x[:, -1:]
        head = (params["embed"].T if self.cfg.tie_embeddings
                and "lm_head" not in params else params["lm_head"])
        logits = x @ head
        if collect_caches:
            return logits, aux, caches
        return logits, aux

    def prefill(self, params: dict, batch: dict, extra_capacity: int = 0,
                true_len: Optional[Array] = None
                ) -> Tuple[Array, "DecodeState"]:
        """Inference prefill: run the full prompt once, return the
        last-position logits (B, vocab) and a DecodeState holding the
        per-layer KV caches / recurrent states for subsequent decode.
        Cache capacity is prompt length + ``extra_capacity`` (ring
        semantics evict the oldest tokens once exhausted).

        ``true_len`` (bucketed prefill, attention-only archs): the
        prompt is padded to a bucket length and ``true_len`` is its real
        length — the returned logits come from position ``true_len - 1``
        and the decode position starts there, so one jit compile serves
        every prompt length in the bucket."""
        cfg = self.cfg
        logits, _, caches = self.forward(
            params, batch, collect_caches=True,
            last_token_only=true_len is None, last_index=true_len)
        if extra_capacity:
            caches = _pad_cache_capacity(caches, extra_capacity)
        if cfg.frontend == "vision":
            T = batch["embeds"].shape[1] + batch["tokens"].shape[1]
        elif cfg.frontend == "audio":
            T = batch["embeds"].shape[1]
        else:
            T = batch["tokens"].shape[1]
        pos = (jnp.asarray(T, jnp.int32) if true_len is None
               else jnp.asarray(true_len, jnp.int32))
        return (logits[:, 0, :cfg.vocab_size],
                DecodeState(caches=caches, position=pos))

    # -- loss --------------------------------------------------------------
    def loss(self, params: dict, batch: dict) -> Array:
        """Next-token CE (decoder), frame CE (audio encoder), or text CE
        on the suffix (VLM)."""
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.frontend == "audio":
            targets = batch["targets"]
            mask = jnp.ones_like(targets, jnp.float32)
        elif cfg.frontend == "vision":
            ptoks = batch["embeds"].shape[1]
            logits = logits[:, ptoks:-1]
            targets = batch["tokens"][:, 1:]
            mask = (targets >= 0).astype(jnp.float32)
        else:
            logits = logits[:, :-1]
            targets = batch["tokens"][:, 1:]
            mask = (targets >= 0).astype(jnp.float32)
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(targets, 0)[..., None], axis=-1)[..., 0]
        ce = jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + aux

    # -- decode -------------------------------------------------------------
    def cache_capacity(self, seq_len: int) -> int:
        w = self.cfg.attention_window
        return min(seq_len, w) if w else seq_len

    def _layer_cache(self, kind: str, batch: int, seq_len: int,
                     dtype) -> Any:
        cfg = self.cfg
        S = self.cache_capacity(seq_len)
        if kind in ("dense", "vlm", "hybrid"):
            kv = KVCache(
                k=jnp.zeros((batch, S, cfg.num_kv_heads, cfg.hd), dtype),
                v=jnp.zeros((batch, S, cfg.num_kv_heads, cfg.hd), dtype))
            if kind == "hybrid":
                return (kv, ssm_lib.mamba_init_state(cfg, batch, dtype=dtype))
            return kv
        if kind == "moe":
            if cfg.mla is not None:
                a = cfg.mla
                return MLACache(
                    c_kv=jnp.zeros((batch, S, a.kv_lora_rank), dtype),
                    k_rope=jnp.zeros((batch, S, a.qk_rope_head_dim), dtype))
            return KVCache(
                k=jnp.zeros((batch, S, cfg.num_kv_heads, cfg.hd), dtype),
                v=jnp.zeros((batch, S, cfg.num_kv_heads, cfg.hd), dtype))
        if kind == "mlstm":
            return ssm_lib.mlstm_init_state(cfg, batch)
        if kind == "slstm":
            return ssm_lib.slstm_init_state(cfg, batch)
        raise ValueError(kind)

    def init_decode_state(self, batch: int, seq_len: int,
                          position: Optional[int] = None) -> DecodeState:
        """Empty caches sized for ``seq_len`` context.  ``position`` is the
        absolute next position (defaults to seq_len: the dry-run scenario
        'cache already holds seq_len tokens')."""
        cfg = self.cfg
        dtype = cfg.param_dtype
        pos = seq_len if position is None else position
        if self.scan:
            single = self._layer_cache(self.kinds[0], batch, seq_len, dtype)
            caches = jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None], (cfg.num_layers,) + t.shape).copy(), single)
        else:
            caches = tuple(self._layer_cache(k, batch, seq_len, dtype)
                           for k in self.kinds)
        return DecodeState(caches=caches,
                           position=jnp.asarray(pos, jnp.int32))

    def serve_step(self, params: dict, tokens: Array, state: DecodeState,
                   update: Optional[Array] = None
                   ) -> Tuple[Array, DecodeState]:
        """One decode step.  tokens: (B, 1) int32 -> logits (B, V).

        ``state.position`` may be scalar (all slots advance in lockstep
        — the legacy/dry-run path, bit-identical to before) or a (B,)
        per-slot vector, in which case each slot writes its cache at
        ITS OWN ring position.  ``update`` (requires per-slot
        positions): (B,) bool — masked-out slots touch NOTHING (caches,
        recurrent states, and positions stay put; their returned logits
        are garbage and must be ignored).  This is what lets a serving
        loop prefill one slot while other slots hold live decodes
        (serving/decode.py)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
        pos = state.position
        per_slot = jnp.ndim(pos) == 1
        if update is not None and not per_slot:
            raise ValueError("serve_step(update=...) needs per-slot "
                             "positions: state.position must be (B,)")
        if per_slot:
            positions = pos[:, None].astype(jnp.int32)   # (B, 1)
        else:
            positions = pos[None].astype(jnp.int32)      # (1,)

        if self.scan:
            kind = self.kinds[0]

            def body(h, xs):
                layer_p, cache = xs
                h, new_cache, _ = _block_apply(layer_p, h, positions, cfg,
                                               kind, cache=cache,
                                               cache_pos=pos,
                                               update=update)
                return h, new_cache

            x, new_caches = jax.lax.scan(body, x,
                                         (params["layers"], state.caches))
        else:
            new_caches = []
            layers = params["layers"]
            for i, kind in enumerate(self.kinds):
                lp = (layers[i] if isinstance(layers, tuple)
                      else jax.tree.map(lambda t: t[i], layers))
                x, nc, _ = _block_apply(lp, x, positions, cfg, kind,
                                        cache=state.caches[i], cache_pos=pos,
                                        update=update)
                new_caches.append(nc)
            new_caches = tuple(new_caches)

        if update is not None:
            new_caches = _mask_recurrent_states(
                state.caches, new_caches, update,
                batch_axis=1 if self.scan else 0)

        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                and "lm_head" not in params else params["lm_head"])
        logits = (x @ head)[:, 0, :cfg.vocab_size]
        if update is None:
            new_pos = pos + 1
        else:
            new_pos = jnp.where(update, pos + 1, pos)
        return logits, DecodeState(caches=new_caches, position=new_pos)

    # -- paged decode (DESIGN.md §11) ---------------------------------------
    def _layer_paged_cache(self, kind: str, num_pages: int, page_size: int,
                           batch: int, dtype) -> Any:
        """One layer's cache with attention leaves laid out as POOL pages
        (num_pages, page_size, ...); recurrent leaves stay (B, ...)."""
        cfg = self.cfg
        if kind in ("dense", "vlm", "hybrid"):
            kv = KVCache(
                k=jnp.zeros((num_pages, page_size, cfg.num_kv_heads,
                             cfg.hd), dtype),
                v=jnp.zeros((num_pages, page_size, cfg.num_kv_heads,
                             cfg.hd), dtype))
            if kind == "hybrid":
                return (kv, ssm_lib.mamba_init_state(cfg, batch, dtype=dtype))
            return kv
        if kind == "moe":
            if cfg.mla is not None:
                a = cfg.mla
                return MLACache(
                    c_kv=jnp.zeros((num_pages, page_size, a.kv_lora_rank),
                                   dtype),
                    k_rope=jnp.zeros((num_pages, page_size,
                                      a.qk_rope_head_dim), dtype))
            return KVCache(
                k=jnp.zeros((num_pages, page_size, cfg.num_kv_heads,
                             cfg.hd), dtype),
                v=jnp.zeros((num_pages, page_size, cfg.num_kv_heads,
                             cfg.hd), dtype))
        if kind == "mlstm":
            return ssm_lib.mlstm_init_state(cfg, batch)
        if kind == "slstm":
            return ssm_lib.slstm_init_state(cfg, batch)
        raise ValueError(kind)

    def init_paged_state(self, batch: int, num_pages: int, page_size: int,
                         max_pages: int) -> PagedDecodeState:
        """Empty paged state: a ``num_pages``-page pool per layer plus a
        zeroed (B, max_pages) page table and (B,) lengths.  The serving
        engine owns the allocator (serving/pages.py); the model only
        reads/writes through the table it is handed."""
        cfg = self.cfg
        dtype = cfg.param_dtype
        if self.scan:
            single = self._layer_paged_cache(self.kinds[0], num_pages,
                                             page_size, batch, dtype)
            caches = jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None], (cfg.num_layers,) + t.shape).copy(), single)
        else:
            caches = tuple(
                self._layer_paged_cache(k, num_pages, page_size, batch,
                                        dtype)
                for k in self.kinds)
        return PagedDecodeState(
            caches=caches,
            page_table=jnp.zeros((batch, max_pages), jnp.int32),
            seq_lens=jnp.zeros((batch,), jnp.int32))

    def paged_fused_step(self, params: dict, tokens: Array,
                         state: PagedDecodeState, q_lens: Array,
                         use_kernel: bool = False
                         ) -> Tuple[Array, PagedDecodeState]:
        """THE paged forward (DESIGN.md §11): one launch over all active
        slots, each carrying up to C tokens.  tokens: (B, C) int32 —
        slot ``b``'s tokens land at absolute positions ``seq_lens[b] +
        c`` for ``c < q_lens[b]``; the rest are padding (writes
        drop-routed, outputs garbage).  A pure decode pass is C == 1
        with ``q_lens`` of ones; a chunked-prefill pass folds prompt
        chunks (q_lens up to C) into the same launch.  Returns the
        logits of each slot's LAST valid token (B, vocab) — garbage for
        slots with ``q_lens == 0`` — and the advanced state
        (``seq_lens += q_lens``).  C > 1 requires an attention-only arch
        (recurrent states cannot mask a mid-scan tail)."""
        cfg = self.cfg
        C = tokens.shape[1]
        if C > 1 and not self.attention_only:
            raise ValueError(
                f"fused multi-token paged decode (C={C}) needs an "
                f"attention-only arch; {cfg.arch_type} carries recurrent "
                "state — serve it with C=1 (bulk prefill + plain decode)")
        x = params["embed"][tokens]
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
        start = state.seq_lens
        q_lens = q_lens.astype(jnp.int32)
        positions = (start[:, None]
                     + jnp.arange(C, dtype=jnp.int32)[None])   # (B, C)
        table = state.page_table

        if self.scan:
            kind = self.kinds[0]

            def body(h, xs):
                layer_p, cache = xs
                h, new_cache, _ = _block_apply(layer_p, h, positions, cfg,
                                               kind, cache=cache,
                                               cache_pos=start,
                                               paged_table=table,
                                               paged_kernel=use_kernel,
                                               q_lens=q_lens)
                return h, new_cache

            x, new_caches = jax.lax.scan(body, x,
                                         (params["layers"], state.caches))
        else:
            new_caches = []
            layers = params["layers"]
            for i, kind in enumerate(self.kinds):
                lp = (layers[i] if isinstance(layers, tuple)
                      else jax.tree.map(lambda t: t[i], layers))
                x, nc, _ = _block_apply(lp, x, positions, cfg, kind,
                                        cache=state.caches[i],
                                        cache_pos=start, paged_table=table,
                                        paged_kernel=use_kernel,
                                        q_lens=q_lens)
                new_caches.append(nc)
            new_caches = tuple(new_caches)

        # recurrent leaves update wholesale — restore rows of inactive
        # slots (attention caches already drop-routed their writes)
        new_caches = _mask_recurrent_states(
            state.caches, new_caches, q_lens > 0,
            batch_axis=1 if self.scan else 0)

        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                and "lm_head" not in params else params["lm_head"])
        logits_all = x @ head                          # (B, C, V_pad)
        last = jnp.maximum(q_lens - 1, 0)[:, None, None]
        logits = jnp.take_along_axis(logits_all, last,
                                     axis=1)[:, 0, :cfg.vocab_size]
        return logits, PagedDecodeState(caches=new_caches, page_table=table,
                                        seq_lens=start + q_lens)

    def paged_serve_step(self, params: dict, tokens: Array,
                         state: PagedDecodeState,
                         update: Optional[Array] = None,
                         use_kernel: bool = False
                         ) -> Tuple[Array, PagedDecodeState]:
        """One decode step against the page pool — the C == 1 view of
        :meth:`paged_fused_step`.  Same ``update`` contract as
        :meth:`serve_step`: masked-out slots touch nothing and their
        logits are garbage."""
        B = tokens.shape[0]
        q_lens = (jnp.ones((B,), jnp.int32) if update is None
                  else jnp.where(update, 1, 0).astype(jnp.int32))
        return self.paged_fused_step(params, tokens, state, q_lens,
                                     use_kernel=use_kernel)

    def write_prefill_to_pages(self, caches: Any, prefill_caches: Any,
                               table_row: Array, shared_len: Array,
                               slot, *, page_size: int,
                               true_len: Optional[Array] = None) -> Any:
        """Scatter a bulk-prefill handoff (:meth:`prefill` on one (1, T)
        prompt) into the pool: attention KV of positions
        ``[shared_len, T)`` lands in the pages of ``table_row`` (the
        shared-prefix positions are already resident in shared pages and
        are drop-routed); recurrent leaves overwrite ``slot``'s row
        wholesale — the prefill state IS the recurrent state after the
        prompt, so nothing of a previous occupant survives.
        ``true_len`` (bucketed prefill): positions past the real prompt
        length are bucket padding and are drop-routed too."""
        scan = self.scan
        P = page_size

        def page_idx(T, n_pages):
            pos = jnp.arange(T)
            idx = jnp.minimum(pos // P, table_row.shape[0] - 1)
            pid = table_row[idx]
            pid = jnp.where(pos >= shared_len, pid, n_pages)   # drop shared
            if true_len is not None:
                pid = jnp.where(pos < true_len, pid, n_pages)  # drop pad
            return pid, pos % P

        def pages_write(pages, seq):
            if scan:                       # (L, NP, P, ...) <- (L, 1, T, ...)
                pid, sl = page_idx(seq.shape[2], pages.shape[1])
                return pages.at[:, pid, sl].set(
                    seq[:, 0].astype(pages.dtype), mode="drop")
            pid, sl = page_idx(seq.shape[1], pages.shape[0])
            return pages.at[pid, sl].set(seq[0].astype(pages.dtype),
                                         mode="drop")

        def recurrent_write(cur, new):
            if scan:                       # (L, B, ...) <- (L, 1, ...)
                return cur.at[:, slot].set(new[:, 0].astype(cur.dtype))
            return cur.at[slot].set(new[0].astype(cur.dtype))

        def attn_write(c, pc):
            return type(c)(*(pages_write(a, b) for a, b in zip(c, pc)))

        return map_cache_tree(caches, on_attention=attn_write,
                              on_leaf=recurrent_write,
                              other=prefill_caches)

    def copy_cache_page(self, caches: Any, src, dst) -> Any:
        """Copy-on-write data move: duplicate physical page ``src`` into
        ``dst`` across every layer's attention leaves (unwritten slots
        carry stale bytes along; they stay behind the validity mask)."""
        axis = 1 if self.scan else 0

        def cp(x):
            idx_src = [slice(None)] * x.ndim
            idx_src[axis] = src
            idx_dst = list(idx_src)
            idx_dst[axis] = dst
            return x.at[tuple(idx_dst)].set(x[tuple(idx_src)])

        return map_cache_tree(caches,
                              on_attention=lambda c: type(c)(*map(cp, c)),
                              on_leaf=lambda c: c)
