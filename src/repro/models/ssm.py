"""State-space / recurrent blocks: Mamba (S6 selective scan), and the two
xLSTM cells (mLSTM matrix-memory, sLSTM scalar-memory).

Training-mode scans:
* Mamba uses a **chunked associative scan** — outer ``lax.scan`` over
  chunks carrying the (d_inner, N) state, inner ``associative_scan``
  within a chunk — bounding the materialized state to
  ``chunk * d_inner * N`` per example (DESIGN.md §5).
* mLSTM / sLSTM use a time-step ``lax.scan`` (exponential gating with the
  max-stabilizer from arXiv:2405.04517).  The chunkwise-parallel mLSTM
  form is a §Perf candidate, not a baseline requirement.

Decode mode: every block exposes a ``*_step`` single-token update with an
O(1)-size carried state — this is what makes ``long_500k`` native for the
SSM/hybrid architectures.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array


# ======================================================================
# Mamba (S6)
# ======================================================================

class MambaState(NamedTuple):
    conv: Array   # (B, W-1, d_inner) — causal-conv tail
    h: Array      # (B, d_inner, N)


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def init_mamba(key: Array, cfg, d_in: Optional[int] = None) -> dict:
    s = cfg.ssm
    dt = cfg.param_dtype
    D = d_in or cfg.d_model
    di = s.expand * D
    R = _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], D, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, di)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_bc": dense_init(ks[2], di, 2 * s.state_dim, dt),
        "x_dt": dense_init(ks[3], di, R, dt),
        "dt_proj": dense_init(ks[4], R, di, dt),
        "dt_bias": (jnp.log(jnp.expm1(jnp.full((di,), 0.01)))).astype(dt),
        "A_log": jnp.log(A),          # float32, A = -exp(A_log)
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, D, dt),
    }


def _causal_conv(x: Array, w: Array, b: Array, tail: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """x: (B, T, di); w: (W, di) depthwise.  Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    return y, xp[:, -(W - 1):] if W > 1 else tail


def _ssm_scan_chunked(xi: Array, dt: Array, Bm: Array, Cm: Array,
                      A: Array, h0: Array, chunk: int
                      ) -> Tuple[Array, Array]:
    """Selective-scan core, chunked so the (B, T, di, N) state-history
    tensor is never materialized: per chunk we form a = exp(dt A) and
    bx = dt*B*x for ``chunk`` steps only, run an associative scan, and
    contract with C immediately.  Chunk bodies are checkpointed so the
    backward stores only the (B, di, N) carry per chunk boundary.

    xi/dt: (B, T, di); Bm/Cm: (B, T, N); A: (di, N); h0: (B, di, N).
    Returns (y (B, T, di), h_last).
    """
    B, T, di = xi.shape
    N = A.shape[1]
    c = min(chunk, T)
    pad = (-T) % c

    def padt(x):
        return jnp.concatenate(
            [x, jnp.zeros((B, pad) + x.shape[2:], x.dtype)], 1) if pad else x

    xi, dt, Bm, Cm = map(padt, (xi, dt, Bm, Cm))
    nc = xi.shape[1] // c

    def chunkify(x):
        return x.reshape((B, nc, c) + x.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, x.ndim + 1)))

    xs = tuple(map(chunkify, (xi, dt, Bm, Cm)))   # each (nc, B, c, ...)

    def combine(l, r):
        (a1, b1), (a2, b2) = l, r
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint
    def chunk_body(h, xc):
        xi_c, dt_c, b_c, c_c = xc                 # (B, c, di) / (B, c, N)
        a = jnp.exp(dt_c[..., None] * A[None, None])          # (B,c,di,N)
        bx = (dt_c * xi_c)[..., None] * b_c[:, :, None, :]    # (B,c,di,N)
        aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
        hs = aa * h[:, None] + bb
        y = jnp.einsum("bcdn,bcn->bcd", hs, c_c)              # (B,c,di)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, nc * c, di)[:, :T]
    return y, h_last


def mamba_forward(p: dict, x: Array, cfg,
                  state: Optional[MambaState] = None
                  ) -> Tuple[Array, Optional[MambaState]]:
    """Training/prefill over a full sequence.  x: (B, T, D)."""
    s = cfg.ssm
    B, T, D = x.shape
    di = p["conv_b"].shape[0]
    N = s.state_dim

    zx = x @ p["in_proj"]
    z, xi = jnp.split(zx, 2, axis=-1)
    tail = state.conv if state is not None else None
    xi, new_tail = _causal_conv(xi, p["conv_w"], p["conv_b"], tail)
    xi = jax.nn.silu(xi)

    bc = xi @ p["x_bc"]
    Bm, Cm = jnp.split(bc, 2, axis=-1)                     # (B, T, N)
    dt = jax.nn.softplus(xi @ p["x_dt"] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)  # (B,T,di)
    A = -jnp.exp(p["A_log"])                               # (di, N)
    h0 = state.h if state is not None else jnp.zeros((B, di, N), jnp.float32)
    y, h_last = _ssm_scan_chunked(
        xi.astype(jnp.float32), dt, Bm.astype(jnp.float32),
        Cm.astype(jnp.float32), A, h0, s.chunk)
    y = (y + xi.astype(jnp.float32) * p["D"]).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, MambaState(conv=new_tail, h=h_last)


def mamba_init_state(cfg, batch: int, d_in: Optional[int] = None,
                     dtype=jnp.float32) -> MambaState:
    s = cfg.ssm
    di = s.expand * (d_in or cfg.d_model)
    return MambaState(
        conv=jnp.zeros((batch, s.conv_width - 1, di), dtype),
        h=jnp.zeros((batch, di, s.state_dim), jnp.float32))


def mamba_step(p: dict, x: Array, cfg, state: MambaState
               ) -> Tuple[Array, MambaState]:
    """Single-token decode.  x: (B, 1, D)."""
    out, new_state = mamba_forward(p, x, cfg, state=state)
    return out, new_state


def _chunked_cell_scan(cell, init_state, xs, chunk: int):
    """Time-scan with gradient checkpointing at chunk boundaries: backward
    stores only the carry every ``chunk`` steps and recomputes within a
    chunk — essential for the mLSTM whose per-step carry is the (hd, hd)
    matrix memory (an unchunked scan would save T copies of it).
    """
    T = xs[0].shape[0]
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        xs = tuple(jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:],
                                                 x.dtype)], 0) for x in xs)
    nc = xs[0].shape[0] // c
    xs_c = tuple(x.reshape((nc, c) + x.shape[1:]) for x in xs)

    @jax.checkpoint
    def chunk_body(carry, xc):
        return jax.lax.scan(cell, carry, xc)

    st, ys = jax.lax.scan(chunk_body, init_state, xs_c)
    ys = ys.reshape((nc * c,) + ys.shape[2:])[:T]
    return st, ys


# ======================================================================
# mLSTM (xLSTM matrix memory)
# ======================================================================

class MLSTMState(NamedTuple):
    C: Array   # (B, H, hd, hd)
    n: Array   # (B, H, hd)
    m: Array   # (B, H)


def init_mlstm(key: Array, cfg) -> dict:
    xl = cfg.xlstm
    dt = cfg.param_dtype
    D = cfg.d_model
    di = int(xl.proj_factor_mlstm * D)
    H = cfg.num_heads
    di = -(-di // H) * H
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], D, 2 * di, dt),
        "wq": dense_init(ks[1], di, di, dt),
        "wk": dense_init(ks[2], di, di, dt),
        "wv": dense_init(ks[3], di, di, dt),
        "w_if": dense_init(ks[4], di, 2 * H, dt),
        "b_if": jnp.zeros((2 * H,), dt),
        "down": dense_init(ks[5], di, D, dt),
    }


def mlstm_init_state(cfg, batch: int) -> MLSTMState:
    xl = cfg.xlstm
    H = cfg.num_heads
    di = -(-int(xl.proj_factor_mlstm * cfg.d_model) // H) * H
    hd = di // H
    return MLSTMState(C=jnp.zeros((batch, H, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, H, hd), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


def _mlstm_cell(carry: MLSTMState, qkvif):
    q, k, v, i_t, f_t = qkvif       # q/k/v: (B,H,hd); i/f: (B,H)
    C, n, m = carry
    m_new = jnp.maximum(f_t + m, i_t)
    i_ = jnp.exp(i_t - m_new)
    f_ = jnp.exp(f_t + m - m_new)
    C_new = f_[..., None, None] * C + i_[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n_new = f_[..., None] * n + i_[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                        jnp.exp(-m_new))
    y = jnp.einsum("bhde,bhe->bhd", C_new, q) / denom[..., None]
    return MLSTMState(C_new, n_new, m_new), y


def mlstm_forward(p: dict, x: Array, cfg,
                  state: Optional[MLSTMState] = None
                  ) -> Tuple[Array, Optional[MLSTMState]]:
    B, T, D = x.shape
    H = cfg.num_heads
    up = x @ p["up"]
    z, xi = jnp.split(up, 2, axis=-1)
    di = xi.shape[-1]
    hd = di // H
    q = (xi @ p["wq"]).reshape(B, T, H, hd).astype(jnp.float32) / math.sqrt(hd)
    k = (xi @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (xi @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    gif = (xi @ p["w_if"] + p["b_if"]).astype(jnp.float32)
    i_t, f_t = jnp.split(gif, 2, axis=-1)                  # (B, T, H)
    f_t = jax.nn.log_sigmoid(f_t)

    st = state if state is not None else mlstm_init_state(cfg, B)
    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_t.transpose(1, 0, 2),
          f_t.transpose(1, 0, 2))
    st_new, ys = _chunked_cell_scan(_mlstm_cell, st, xs, chunk=64)
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, di).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["down"]
    return out, st_new


def mlstm_step(p, x, cfg, state):
    return mlstm_forward(p, x, cfg, state=state)


# ======================================================================
# sLSTM (xLSTM scalar memory)
# ======================================================================

class SLSTMState(NamedTuple):
    c: Array   # (B, di)
    n: Array
    h: Array
    m: Array


def init_slstm(key: Array, cfg) -> dict:
    xl = cfg.xlstm
    dt = cfg.param_dtype
    D = cfg.d_model
    di = int(xl.proj_factor_slstm * D)
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], D, 4 * di, dt),       # z, i, f, o pre-acts
        "r": (jax.random.normal(ks[1], (di, 4 * di)) * 0.02).astype(dt),
        "b": jnp.zeros((4 * di,), dt),
        "down": dense_init(ks[2], di, D, dt),
        "up_gate": dense_init(ks[3], D, di, dt),
    }


def slstm_init_state(cfg, batch: int) -> SLSTMState:
    di = int(cfg.xlstm.proj_factor_slstm * cfg.d_model)
    z = jnp.zeros((batch, di), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def _slstm_cell(p, carry: SLSTMState, u):
    c, n, h, m = carry
    pre = u + h.astype(u.dtype) @ p["r"].astype(jnp.float32)
    z, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
    f_t = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(f_t + m, i_t)
    i_ = jnp.exp(i_t - m_new)
    f_ = jnp.exp(f_t + m - m_new)
    c_new = f_ * c + i_ * jnp.tanh(z)
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return SLSTMState(c_new, n_new, h_new, m_new), h_new


def slstm_forward(p: dict, x: Array, cfg,
                  state: Optional[SLSTMState] = None
                  ) -> Tuple[Array, Optional[SLSTMState]]:
    B, T, D = x.shape
    u = (x @ p["w_in"] + p["b"]).astype(jnp.float32)       # (B, T, 4di)
    st = state if state is not None else slstm_init_state(cfg, B)
    st_new, hs = _chunked_cell_scan(
        lambda c, xs_: _slstm_cell(p, c, xs_[0]), st,
        (u.transpose(1, 0, 2),), chunk=64)
    h = hs.transpose(1, 0, 2).astype(x.dtype)              # (B, T, di)
    out = (h * jax.nn.silu(x @ p["up_gate"])) @ p["down"]
    return out, st_new


def slstm_step(p, x, cfg, state):
    return slstm_forward(p, x, cfg, state=state)
