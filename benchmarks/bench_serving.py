"""Serving-layer benchmark: dense ``DecodeServer`` vs ``PagedEngine``
(DESIGN.md §11) over a batch x prompt-mix x page-size sweep.

Per cell, both engines serve the SAME mixed workload (many short
prompts + a few long ones — the shape that makes dense per-slot
``(B, max_seq)`` caches wasteful) and we report

* ``prefill_steps`` — model passes spent ingesting prompts: the dense
  server teacher-forces token-by-token (one serve pass per prompt
  token), the paged engine runs ONE bulk ``Model.prefill`` forward per
  admission (re-admissions after preemption included);
* ``cache_hbm_bytes`` — attention-cache bytes held: dense allocates
  ``B * max_seq`` rows up front, the paged pool is sized to the
  workload (half the dense worst case here) and COW-shares prefixes;
* ``tok/s`` wall-clock (CPU smoke: jit-compile noise included, so the
  acceptance asserts are on the deterministic step/byte counts, not
  wall time);
* greedy token agreement between the two engines (REPORTED, not
  asserted: argmax near-ties on random-param smoke models can flip —
  the seeded parity asserts live in tests/test_paged_engine.py).

Smoke acceptance (the CI row): paged prefill passes < dense prefill
passes on every cell, and paged cache bytes < dense cache bytes.
Results land in ``results/BENCH_serving.json`` so the perf trajectory
records serving numbers from this PR on.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def _workload(cfg, n_short: int, n_long: int, new_tokens: int,
              long_len: int, seed: int = 0):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_short):
        plen = int(rng.integers(3, 7))
        reqs.append(Request(
            uid=i, prompt=rng.integers(1, cfg.vocab_size, plen).tolist(),
            max_new_tokens=new_tokens))
    for j in range(n_long):
        reqs.append(Request(
            uid=n_short + j,
            prompt=rng.integers(1, cfg.vocab_size, long_len).tolist(),
            max_new_tokens=new_tokens))
    return reqs


def _dense_cache_bytes(server) -> int:
    from repro.serving.engine import attention_cache_bytes
    return attention_cache_bytes(server.state.caches)


def _cell(model, params, cfg, *, batch: int, page_size: int,
          max_seq: int, new_tokens: int, long_len: int) -> dict:
    from repro.serving import DecodeServer, PagedEngine

    n_short, n_long = 3 * batch // 2, max(1, batch // 2)
    mk = lambda: _workload(cfg, n_short, n_long, new_tokens, long_len)

    dense = DecodeServer(model, params, batch_size=batch, max_seq_len=max_seq)
    t0 = time.perf_counter()
    d_out = dense.run(mk())
    t_dense = time.perf_counter() - t0
    dense_prefill_steps = sum(len(r.prompt) or 1 for r in d_out)
    dense_bytes = _dense_cache_bytes(dense)

    # pool sized to the workload: half the dense worst-case capacity
    num_pages = max(1, (batch * max_seq) // (2 * page_size))
    paged = PagedEngine(model, params, batch_size=batch, max_seq_len=max_seq,
                        page_size=page_size, num_pages=num_pages)
    t0 = time.perf_counter()
    p_out = paged.run(mk())
    t_paged = time.perf_counter() - t0

    # report (not assert) token agreement: the two engines are
    # mathematically identical greedy decodes but reduce in different
    # shapes, so an argmax near-tie on these random-param smoke models
    # can legitimately flip a token — the hard parity asserts live in
    # the seeded tests (tests/test_paged_engine.py); a benchmark cell
    # must not flake CI on a tie
    mismatches = sum(a.generated != b.generated
                     for a, b in zip(d_out, p_out))

    tokens = sum(len(r.generated) for r in d_out)
    m = paged.metrics()
    return {
        "batch": batch, "page_size": page_size, "max_seq": max_seq,
        "requests": len(d_out), "tokens": tokens,
        "dense_prefill_steps": dense_prefill_steps,
        "paged_prefill_steps": paged.prefill_forwards,
        "dense_cache_bytes": dense_bytes,
        "paged_cache_bytes": m["cache_hbm_bytes"],
        "dense_tok_s": tokens / max(t_dense, 1e-9),
        "paged_tok_s": tokens / max(t_paged, 1e-9),
        "token_mismatches": mismatches,
        "preemptions": m["pool"]["preemptions"],
        "prefix_hits": m["pool"]["prefix_hits"],
        "cow_copies": m["pool"]["cow_copies"],
        "pool_peak_pages": m["pool"]["peak_in_use"],
        "latency_p50": m.get("latency_p50"),
        "latency_p95": m.get("latency_p95"),
    }


def run(quick: bool = False, arch: str = "granite-3-2b"):
    from repro.models import Model, get_smoke_config
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    max_seq, new_tokens, long_len = 48, 8, 28
    cells = ([(2, 8), (4, 4)] if quick
             else [(2, 4), (2, 8), (4, 4), (4, 8), (4, 16), (8, 8)])
    rows = []
    for batch, page_size in cells:
        rows.append(_cell(model, params, cfg, batch=batch,
                          page_size=page_size, max_seq=max_seq,
                          new_tokens=new_tokens, long_len=long_len))
    return rows


def main(quick: bool = True):
    rows = run(quick=quick)
    print("# serving layer: dense ring cache vs paged pool")
    for r in rows:
        print(f"  serving,b={r['batch']},P={r['page_size']},"
              f"prefill={r['paged_prefill_steps']}/{r['dense_prefill_steps']},"
              f"bytes={r['paged_cache_bytes']}/{r['dense_cache_bytes']},"
              f"tok_s={r['paged_tok_s']:.1f}/{r['dense_tok_s']:.1f},"
              f"preempt={r['preemptions']},prefix={r['prefix_hits']},"
              f"mismatch={r['token_mismatches']},"
              f"p95={r['latency_p95']:.0f}")
        # the §11 acceptance: bulk prefill beats token-by-token, and the
        # workload-sized pool undercuts the dense worst-case cache
        assert r["paged_prefill_steps"] < r["dense_prefill_steps"], r
        assert r["paged_cache_bytes"] < r["dense_cache_bytes"], r
    print("OK: paged bulk prefill beats dense token-by-token prefill "
          "with a smaller cache footprint")
    os.makedirs("results", exist_ok=True)
    with open("results/BENCH_serving.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    yield rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="two cells, small shapes — the CI row")
    args = ap.parse_args()
    list(main(quick=args.smoke))
