"""Serving-layer benchmark: dense ``DecodeServer`` vs ``PagedEngine``
(DESIGN.md §11) — prefill/memory sweep plus the decode-throughput
head-to-head.

Two kinds of cells, both serving the SAME workloads on both engines:

* **prefill/memory cells** (batch x page-size sweep, mixed short/long
  prompts): ``prefill_steps`` (dense teacher-forces one serve pass per
  prompt token; the paged engine chunk-folds prompts into shared fused
  passes, with bulk one-forward-per-admission as the reference point)
  and ``cache_hbm_bytes`` (dense allocates ``B * max_seq`` rows up
  front; the pool is sized to half that and COW-shares prefixes);
* **decode cells** (short prompts, long ``max_seq``): steady-state
  decode tok/s from the engines' ``decode_tokens / decode_seconds``
  counters — pure decode passes only, prefill excluded on both sides.
  Each engine gets a warmup run (jit compiles, every page-table width)
  before the measured runs; best-of-N damps scheduler noise.  The paged
  engine wins because its fused pass attends ``table_width *
  page_size`` positions (live context, power-of-two bucketed) while the
  dense ring always pays ``max_seq``.

Token agreement between the engines is REPORTED, not asserted (argmax
near-ties on random-param smoke models can flip; the seeded parity
asserts live in tests/test_paged_engine.py and
tests/test_chunked_prefill.py).

Acceptance (the CI row): on every prefill cell, chunked paged prefill
passes <= bulk passes < dense passes and paged cache bytes < dense
cache bytes; on every decode cell, paged decode tok/s >= dense.

``results/BENCH_serving.json`` is a TRAJECTORY: each bench run appends
one entry (timestamp, backend, cells) instead of overwriting, so the
perf history accumulates across PRs.  ``--check-baseline`` replays the
bench and compares against the last committed entry of the same mode
WITHOUT appending — the CI regression gate: deterministic counters
(prefill passes, byte ratios) must not regress at all, the wall-clock
decode ratio must stay >= 1 and within noise of the baseline.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_PATH = "results/BENCH_serving.json"
# wall-clock gate slack: the decode ratio is machine-dependent, so the
# baseline comparison only fails when the advantage collapses
DECODE_RATIO_NOISE = 0.6


def _workload(cfg, n_short: int, n_long: int, new_tokens: int,
              long_len: int, seed: int = 0):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_short):
        plen = int(rng.integers(3, 7))
        reqs.append(Request(
            uid=i, prompt=rng.integers(1, cfg.vocab_size, plen).tolist(),
            max_new_tokens=new_tokens))
    for j in range(n_long):
        reqs.append(Request(
            uid=n_short + j,
            prompt=rng.integers(1, cfg.vocab_size, long_len).tolist(),
            max_new_tokens=new_tokens))
    return reqs


def _dense_cache_bytes(server) -> int:
    from repro.serving.engine import attention_cache_bytes
    return attention_cache_bytes(server.state.caches)


def _cell(model, params, cfg, *, batch: int, page_size: int,
          max_seq: int, new_tokens: int, long_len: int) -> dict:
    from repro.serving import DecodeServer, PagedEngine

    n_short, n_long = 3 * batch // 2, max(1, batch // 2)
    mk = lambda: _workload(cfg, n_short, n_long, new_tokens, long_len)

    dense = DecodeServer(model, params, batch_size=batch, max_seq_len=max_seq)
    t0 = time.perf_counter()
    d_out = dense.run(mk())
    t_dense = time.perf_counter() - t0
    dense_prefill_steps = sum(len(r.prompt) or 1 for r in d_out)
    dense_bytes = _dense_cache_bytes(dense)

    # pool sized to the workload: half the dense worst-case capacity
    num_pages = max(1, (batch * max_seq) // (2 * page_size))
    # bulk reference: one prefill forward per admission (the pre-chunked
    # engine behavior) — the bound chunked admission must not exceed
    bulk = PagedEngine(model, params, batch_size=batch, max_seq_len=max_seq,
                       page_size=page_size, num_pages=num_pages,
                       prefill_chunk_tokens=0)
    bulk.run(mk())

    paged = PagedEngine(model, params, batch_size=batch, max_seq_len=max_seq,
                        page_size=page_size, num_pages=num_pages)
    t0 = time.perf_counter()
    p_out = paged.run(mk())
    t_paged = time.perf_counter() - t0

    # report (not assert) token agreement: the two engines are
    # mathematically identical greedy decodes but reduce in different
    # shapes, so an argmax near-tie on these random-param smoke models
    # can legitimately flip a token — the hard parity asserts live in
    # the seeded tests; a benchmark cell must not flake CI on a tie
    mismatches = sum(a.generated != b.generated
                     for a, b in zip(d_out, p_out))

    tokens = sum(len(r.generated) for r in d_out)
    m = paged.metrics()
    return {
        "batch": batch, "page_size": page_size, "max_seq": max_seq,
        "requests": len(d_out), "tokens": tokens,
        "dense_prefill_steps": dense_prefill_steps,
        "bulk_prefill_steps": bulk.prefill_forwards,
        "paged_prefill_steps": paged.prefill_forwards,
        "mixed_passes": m["mixed_passes"],
        "dense_cache_bytes": dense_bytes,
        "paged_cache_bytes": m["cache_hbm_bytes"],
        "bytes_ratio": m["cache_hbm_bytes"] / dense_bytes,
        "dense_tok_s": tokens / max(t_dense, 1e-9),
        "paged_tok_s": tokens / max(t_paged, 1e-9),
        "token_mismatches": mismatches,
        "preemptions": m["pool"]["preemptions"],
        "prefix_hits": m["pool"]["prefix_hits"],
        "cow_copies": m["pool"]["cow_copies"],
        "pool_peak_pages": m["pool"]["peak_in_use"],
        "latency_p50": m.get("latency_p50"),
        "latency_p95": m.get("latency_p95"),
        "ttft_p50": m.get("ttft_p50"),
        "ttft_p95": m.get("ttft_p95"),
    }


def _decode_requests(cfg, n: int, new_tokens: int, seed: int):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def _decode_cell(model, params, cfg, *, batch: int, max_seq: int,
                 page_size: int, new_tokens: int, repeats: int) -> dict:
    """Steady-state decode tok/s head-to-head.  Short prompts + a long
    ``max_seq``: the dense ring attends (and masks) ``max_seq``
    positions every step while the paged fused pass attends only the
    power-of-two table width covering the live context."""
    from repro.serving import DecodeServer, PagedEngine

    n_req = 2 * batch

    def measure(server):
        server.run(_decode_requests(cfg, n_req, new_tokens, seed=0))
        best = 0.0
        for s in range(1, repeats + 1):
            server.reset_perf_counters()
            server.run(_decode_requests(cfg, n_req, new_tokens, seed=s))
            best = max(best, server.decode_tokens
                       / max(server.decode_seconds, 1e-9))
        return best, server

    dense_tps, _ = measure(DecodeServer(model, params, batch_size=batch,
                                        max_seq_len=max_seq))
    paged_tps, paged = measure(PagedEngine(model, params, batch_size=batch,
                                           max_seq_len=max_seq,
                                           page_size=page_size))
    return {
        "batch": batch, "max_seq": max_seq, "page_size": page_size,
        "new_tokens": new_tokens, "repeats": repeats,
        "decode_tokens": paged.decode_tokens,
        "dense_decode_tok_s": dense_tps,
        "paged_decode_tok_s": paged_tps,
        "decode_ratio": paged_tps / max(dense_tps, 1e-9),
    }


def run(quick: bool = False, arch: str = "granite-3-2b") -> dict:
    from repro.models import Model, get_smoke_config
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    max_seq, new_tokens, long_len = 48, 8, 28
    cells = ([(2, 8), (4, 4)] if quick
             else [(2, 4), (2, 8), (4, 4), (4, 8), (4, 16), (8, 8)])
    rows = []
    for batch, page_size in cells:
        rows.append(_cell(model, params, cfg, batch=batch,
                          page_size=page_size, max_seq=max_seq,
                          new_tokens=new_tokens, long_len=long_len))
    dcells = ([(8, 256, 8, 16, 2)] if quick
              else [(8, 128, 8, 16, 3), (8, 256, 8, 16, 3)])
    decode = [
        _decode_cell(model, params, cfg, batch=b, max_seq=ms,
                     page_size=p, new_tokens=nt, repeats=rep)
        for b, ms, p, nt, rep in dcells]
    return {"cells": rows, "decode": decode}


def _assert_gates(res: dict) -> None:
    for r in res["cells"]:
        # §11 acceptance: both paged modes beat dense token-by-token,
        # and the workload-sized pool undercuts the dense cache
        assert r["paged_prefill_steps"] < r["dense_prefill_steps"], r
        assert r["bulk_prefill_steps"] < r["dense_prefill_steps"], r
        assert r["paged_cache_bytes"] < r["dense_cache_bytes"], r
    # chunk folding wins in aggregate: a single prompt longer than the
    # chunk budget legitimately takes more passes than one bulk forward,
    # but across the sweep the folded admissions more than pay for it
    assert (sum(r["paged_prefill_steps"] for r in res["cells"])
            <= sum(r["bulk_prefill_steps"] for r in res["cells"])), \
        res["cells"]
    for d in res["decode"]:
        # the PR 7 headline: the fused launch + table-width bucketing
        # flip the decode gap — paged wins steady-state tok/s
        assert d["paged_decode_tok_s"] >= d["dense_decode_tok_s"], d


def _load_trajectory(path: str) -> list:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if data and isinstance(data, list) and "cells" not in data[0]:
        # pre-trajectory format (a bare row list): keep it as one entry
        return [{"mode": "legacy", "cells": data, "decode": []}]
    return data


def _check_baseline(res: dict, mode: str, path: str = RESULTS_PATH) -> None:
    """CI regression gate: compare a fresh run against the last
    committed entry of the same mode.  Deterministic counters must not
    regress at all; the wall-clock decode ratio gets noise slack."""
    entries = [e for e in _load_trajectory(path) if e.get("mode") == mode]
    if not entries:
        raise SystemExit(f"no '{mode}' baseline entry in {path}; run the "
                         "bench once without --check-baseline and commit "
                         "the result")
    base = entries[-1]
    by_key = {(c["batch"], c["page_size"]): c for c in base["cells"]}
    for r in res["cells"]:
        b = by_key.get((r["batch"], r["page_size"]))
        if b is None:
            continue
        assert r["paged_prefill_steps"] <= b["paged_prefill_steps"], (
            "prefill-pass regression", r, b)
        assert r["bytes_ratio"] <= b["bytes_ratio"] * 1.001, (
            "HBM-bytes-ratio regression", r, b)
    dbase = {(d["batch"], d["max_seq"]): d for d in base.get("decode", [])}
    for d in res["decode"]:
        b = dbase.get((d["batch"], d["max_seq"]))
        floor = max(1.0, b["decode_ratio"] * DECODE_RATIO_NOISE) \
            if b is not None else 1.0
        assert d["decode_ratio"] >= floor, (
            "decode-tok/s regression", d, b)
    print(f"baseline check OK vs entry of {base.get('ts', '?')} "
          f"({len(res['cells'])} cells, {len(res['decode'])} decode cells)")


def _append_trajectory(res: dict, mode: str, path: str = RESULTS_PATH):
    from repro.obs import provenance

    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "mode": mode,
        "backend": jax.default_backend(),
        "provenance": provenance.collect(),
        "cells": res["cells"],
        "decode": res["decode"],
    }
    traj = _load_trajectory(path)
    traj.append(entry)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(traj, f, indent=1, default=str)


def main(quick: bool = True, check_baseline: bool = False,
         trace_out: str = None, metrics_out: str = None):
    from repro.obs import start_run

    mode = "smoke" if quick else "full"
    # every bench run leaves trace + metrics artifacts next to the
    # trajectory (results/* is gitignored; only the BENCH jsons commit)
    obsrun = start_run(
        trace_out=trace_out or f"results/traces/bench_serving_{mode}.trace.json",
        metrics_out=metrics_out
        or f"results/traces/bench_serving_{mode}.metrics.json",
        meta={"cli": "bench_serving", "mode": mode})
    res = run(quick=quick)
    obsrun.finish()
    print("# serving layer: dense ring cache vs paged pool")
    for r in res["cells"]:
        print(f"  serving,b={r['batch']},P={r['page_size']},"
              f"prefill={r['paged_prefill_steps']}"
              f"/{r['bulk_prefill_steps']}/{r['dense_prefill_steps']},"
              f"bytes={r['paged_cache_bytes']}/{r['dense_cache_bytes']},"
              f"preempt={r['preemptions']},prefix={r['prefix_hits']},"
              f"mismatch={r['token_mismatches']},"
              f"ttft_p95={r['ttft_p95']:.0f},p95={r['latency_p95']:.0f}")
    for d in res["decode"]:
        print(f"  decode,b={d['batch']},S={d['max_seq']},"
              f"paged={d['paged_decode_tok_s']:.0f},"
              f"dense={d['dense_decode_tok_s']:.0f},"
              f"ratio={d['decode_ratio']:.2f}")
    _assert_gates(res)
    print("OK: chunked paged prefill beats dense token-by-token, smaller "
          "cache footprint, paged decode tok/s >= dense")
    if check_baseline:
        _check_baseline(res, mode)
    else:
        _append_trajectory(res, mode)
    yield res["cells"] + res["decode"]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="two prefill cells + one decode cell — the CI row")
    ap.add_argument("--check-baseline", action="store_true",
                    help="compare against the last committed trajectory "
                         "entry instead of appending (the CI gate)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="Chrome trace artifact path (default under "
                         "results/traces/)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="metrics snapshot path (default under "
                         "results/traces/)")
    args = ap.parse_args()
    list(main(quick=args.smoke, check_baseline=args.check_baseline,
              trace_out=args.trace_out, metrics_out=args.metrics_out))
