"""Roofline analysis from the dry-run artifacts (deliverable g).

For each (arch x input-shape x mesh) JSON produced by
``repro.launch.dryrun`` we derive the three roofline terms in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = sum_links collective_bytes / link_bw       (~50 GB/s/link)

``cost_analysis`` supplies per-device FLOPs and bytes; collective bytes
are parsed from the SPMD-partitioned HLO (dryrun.collective_bytes) —
ring all-gather/reduce-scatter move ~(n-1)/n of the payload across the
slowest link, all-reduce ~2(n-1)/n, all-to-all ~1/n per link; we apply
these factors per op class using the data-axis size.

Also reported per pair: MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D
(MoE) and the usefulness ratio MODEL_FLOPS / (chips · HLO_FLOPs) which
catches remat/redundancy waste, the dominant term, and a one-line
actionable note.
"""
from __future__ import annotations

import glob
import json
import math
import os
from typing import Dict, List, Optional

from repro.models.registry import ARCH_IDS, INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 per chip (v5e)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def active_params(arch_id: str) -> float:
    """N (dense) or N_active (MoE: shared + top-k routed + non-FFN)."""
    cfg = get_config(arch_id)
    from repro.models import Model, count_params
    import jax
    total = count_params(jax.eval_shape(
        Model(cfg).init_params, jax.random.key(0)))
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    expert_p = 3 * cfg.d_model * m.d_expert          # gate/up/down
    routed_total = cfg.num_layers * m.num_experts * expert_p
    routed_active = cfg.num_layers * m.experts_per_token * expert_p
    return float(total - routed_total + routed_active)


def attention_flops(arch_id: str, shape_name: str) -> float:
    """Analytic attention score+value flops (the 6·N·D rule misses the
    O(T²) term).  Causal halves the work; SWA replaces T by the window;
    SSM/xLSTM mixers are linear in T (folded into the 6·N·D count)."""
    cfg = get_config(arch_id)
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k":
        cfg = cfg.for_long_context()
    if cfg.arch_type == "ssm":
        return 0.0
    H, hd, L = cfg.num_heads, cfg.hd, cfg.num_layers
    if cfg.mla is not None:
        hd = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
    B, T = shape.global_batch, shape.seq_len
    w = cfg.attention_window
    if shape.kind == "decode":
        t_q, t_kv = 1, min(T, w) if w else T
        causal = 1.0
    else:
        t_q = T
        t_kv = min(T, w) if w else T
        causal = 0.5 if not cfg.is_encoder else 1.0
    fwd = 4.0 * B * t_q * t_kv * H * hd * causal * L   # QK^T + PV, mul+add
    return 3.0 * fwd if shape.kind == "train" else fwd


def model_flops(arch_id: str, shape_name: str) -> float:
    """Exact algorithmic flops: 6·N_active·tokens (train fwd+bwd) or
    2·N_active·tokens (inference) + the analytic attention term; train
    additionally x2 for the DASHA-PP-MVR gradient pair (same batch at
    x^{t+1} and x^t)."""
    shape = INPUT_SHAPES[shape_name]
    n_act = active_params(arch_id)
    attn = attention_flops(arch_id, shape_name)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * (6.0 * n_act * tokens + attn)
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len + attn
    return 2.0 * n_act * shape.global_batch * 1.0 + attn   # one token


def roofline_terms(rec: Dict, chips: int) -> Dict:
    """Three terms in seconds per step.

    * compute: ANALYTIC model flops / peak.  (XLA's cost_analysis counts
      while-loop bodies once — scanned models' HLO flops are ~num_layers
      too small, verified by flops_hlo*L/flops_model ≈ 1-2; the analytic
      count is exact and is the standard MFU denominator.)
    * memory: HLO bytes-accessed / HBM bw.  Stacked-layer buffers are
      accounted at the loop boundary, so this is order-correct (see
      EXPERIMENTS.md §Roofline note).
    * collective: per-class payload bytes from the SPMD HLO with ring
      factors.  The DASHA-PP aggregation collectives live OUTSIDE the
      layer scan (whole-gradient leaves) and are exact; in-scan tensor-
      parallel collectives are counted once per step (lower bound),
      noted per pair via hlo_undercount.
    """
    n_data = 32 if rec["mesh"] == "2x16x16" else 16
    mf = model_flops(rec["arch"], rec["shape"])
    comp = mf / chips / PEAK_FLOPS
    mem = rec["bytes_per_device"] / HBM_BW
    coll = rec.get("collectives", {})
    ring = (n_data - 1) / n_data
    coll_bytes_link = (
        coll.get("all-gather", 0) * ring
        + coll.get("reduce-scatter", 0) * ring
        + coll.get("all-reduce", 0) * 2 * ring
        + coll.get("all-to-all", 0) / n_data
        + coll.get("collective-permute", 0))
    collective = coll_bytes_link / LINK_BW
    dom = max(("compute", comp), ("memory", mem),
              ("collective", collective), key=lambda kv: kv[1])
    # how much of compiled compute the HLO reports vs analytic — ≈1/L for
    # scanned models (cost-analysis loop undercount), ≈1 for unrolled
    hlo_ratio = (chips * rec["flops_per_device"] / mf) if mf else float("nan")
    return dict(compute_s=comp, memory_s=mem, collective_s=collective,
                dominant=dom[0], bound_s=dom[1], model_flops=mf,
                useful_ratio=hlo_ratio)


_NOTES = {
    "compute": ("compute-bound: raise arithmetic efficiency — larger "
                "matmul tiles, drop the MVR double-backward via "
                "gradient-pair reuse, or reduce remat recompute"),
    "memory": ("memory-bound: fuse elementwise chains (dasha_update "
               "kernel), cut temp materialization (blockwise attention, "
               "chunked scans), store variates in bf16"),
    "collective": ("collective-bound: raise the compression ratio "
                   "(smaller K), move aggregation to sparse all-gather, "
                   "overlap collectives with compute, or coarsen node "
                   "granularity to the pod axis"),
}


def load_records(dryrun_dir: str, tag: str = "baseline") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"{tag}__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyze(dryrun_dir: str = "results/dryrun", tag: str = "baseline",
            mesh: Optional[str] = "16x16") -> List[Dict]:
    rows = []
    for rec in load_records(dryrun_dir, tag):
        if mesh and rec.get("mesh") != mesh:
            continue
        row = {k: rec.get(k) for k in ("arch", "shape", "mesh", "status")}
        if rec.get("status") == "ok":
            chips = 512 if rec["mesh"] == "2x16x16" else 256
            row.update(roofline_terms(rec, chips))
            row["note"] = _NOTES[row["dominant"]]
            row["temp_gib"] = rec["memory"]["temp_bytes"] / 2**30
        elif rec.get("status") == "skipped":
            row["note"] = rec.get("reason")
        rows.append(row)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<22}{'shape':<13}{'compute_s':>11}{'memory_s':>11}"
           f"{'coll_s':>11}{'dominant':>11}{'hlo/an':>8}{'temp GiB':>10}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") == "ok":
            lines.append(
                f"{r['arch']:<22}{r['shape']:<13}{r['compute_s']:>11.4f}"
                f"{r['memory_s']:>11.4f}{r['collective_s']:>11.4f}"
                f"{r['dominant']:>11}{r['useful_ratio']:>8.2f}"
                f"{r['temp_gib']:>10.1f}")
        else:
            lines.append(f"{r['arch']:<22}{r['shape']:<13}  "
                         f"[{r.get('status')}] {r.get('note', '')}")
    return "\n".join(lines)


def main(quick: bool = True):
    for mesh in ("16x16", "2x16x16"):
        rows = analyze(mesh=mesh)
        if rows:
            print(f"# Roofline ({mesh}, from dry-run artifacts)")
            print(format_table(rows))
        else:
            print(f"# Roofline ({mesh}): no dry-run artifacts found — run "
                  "PYTHONPATH=src python -m repro.launch.dryrun first")
        yield rows


if __name__ == "__main__":
    list(main(quick=False))
