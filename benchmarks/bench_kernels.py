"""Kernel-layer microbenchmarks: the fused dasha_update Pallas kernel
vs the unfused jnp chain, and BlockRandK gather/scatter vs XLA gather.

On this CPU container the Pallas kernels run in interpret mode, so
WALL-TIME is not meaningful for them; what we report instead is the HLO
**bytes-accessed** of each variant (the memory-roofline quantity the
fusion targets) plus wall-time of the jnp reference paths.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import dasha_update_op


def hlo_bytes(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    return float(c.cost_analysis().get("bytes accessed", float("nan")))


def timeit(fn, *args, iters: int = 20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(d: int = 1 << 20, quick: bool = False):
    if quick:
        d = 1 << 16
    key = jax.random.key(0)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    part = jnp.asarray(1.0)
    kwargs = dict(b=0.3, a=0.05, pa=0.5)

    unfused = jax.jit(lambda *xs: ref.dasha_update_ref(
        *xs, participates=part, **kwargs))
    b_unfused = hlo_bytes(lambda *xs: ref.dasha_update_ref(
        *xs, participates=part, **kwargs), gn, go, h, gi)
    t_unfused = timeit(unfused, gn, go, h, gi)

    # fused kernel ideal traffic: 4 reads + 3 writes of d f32
    ideal = 7 * d * 4.0
    rows = [dict(name="dasha_update_unfused_jnp", us=t_unfused,
                 hlo_bytes=b_unfused, ideal_bytes=ideal,
                 ratio=b_unfused / ideal)]

    # interpret-mode correctness check counts as the kernel row
    k1, h1, p1 = dasha_update_op(gn, go, h, gi, participates=part, **kwargs)
    k2, h2, p2 = ref.dasha_update_ref(gn, go, h, gi, participates=part,
                                      **kwargs)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in [(k1, k2), (h1, h2), (p1, p2)])
    rows.append(dict(name="dasha_update_pallas(interpret)", us=float("nan"),
                     hlo_bytes=ideal, ideal_bytes=ideal, ratio=1.0,
                     max_err_vs_ref=err))
    return rows


def main(quick: bool = True):
    rows = run(quick=quick)
    print("# kernel layer: HBM traffic of the control-variate update")
    for r in rows:
        print(f"  kernels,{r['name']},us={r['us']:.1f},"
              f"bytes={r['hlo_bytes']:.3e},x_ideal={r['ratio']:.2f}")
    yield rows


if __name__ == "__main__":
    list(main(quick=False))
