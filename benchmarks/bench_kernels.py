"""Kernel-layer microbenchmarks: the fused DASHA update kernels vs the
unfused jnp chains, for every ``k_i`` rule of Algorithm 1, plus the
fused update+BlockRandK-compress wire path.

HBM-bytes accounting (the §6 roofline claim, DESIGN.md): the update is
elementwise with arithmetic intensity O(1), so its cost is HBM traffic.
For each variant we report

* ``hlo_bytes``   — XLA's bytes-accessed cost analysis of the *unfused*
  jnp chain (what the compiler actually materializes),
* ``ideal_bytes`` — the fused kernel's traffic (reads + writes of its
  operands, once each),
* ``ratio``       — hlo/ideal, the roofline headroom the fusion closes.

On this CPU container the Pallas kernels run in interpret mode (Python
loop per grid step), so their WALL-TIME is meaningless and the >=1.2x
fused-speedup acceptance check is exempt; on TPU
(``REPRO_PALLAS_INTERPRET=0``) the same code times both paths and
reports ``speedup = t_unfused / t_fused``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import (buffered_commit_op,
                               dasha_page_h_update_op,
                               dasha_page_payload_blocks_op,
                               dasha_page_update_op,
                               dasha_payload_blocks_op, dasha_tail_op,
                               dasha_update_batched_op, dasha_update_op,
                               interpret_default, paged_attention_batched_op,
                               paged_attention_op, paged_mla_attention_op)
from repro.kernels.paged_attention import (paged_attention_batched_ref,
                                           paged_attention_ref,
                                           paged_mla_attention_ref)

SPEEDUP_TARGET = 1.2   # acceptance: fused >= 1.2x on the update phase

# Append-per-run trajectory file (same format as results/BENCH_*.json;
# benchmarks/README.md).  Self-managed: benchmarks/run.py must NOT dump
# its generic per-suite json over this path.
RESULTS_PATH = "results/bench/kernels.json"


def hlo_bytes(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    return float(cost.get("bytes accessed", float("nan")))


def timeit(fn, *args, iters: int = 20) -> float:
    jax.tree.leaves(fn(*args))[0].block_until_ready()   # warm up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6   # us


def _max_err(outs, refs) -> float:
    return max(float(jnp.max(jnp.abs(o - r))) for o, r in zip(outs, refs))


def _row(name, *, t_unfused, t_fused, b_unfused, ideal, err, interpret):
    # ``interpret`` is an explicit key on every row: downstream tooling
    # keys wall-time validity off it instead of parsing a NaN sentinel
    row = dict(name=name, interpret=bool(interpret), us_unfused=t_unfused,
               hlo_bytes=b_unfused, ideal_bytes=ideal,
               ratio=b_unfused / ideal, max_err=err)
    if not interpret:
        row.update(us_fused=t_fused, speedup=t_unfused / t_fused)
    return row


def run(d: int = 1 << 20, n: int = 8, quick: bool = False):
    if quick:
        d, n = 1 << 16, 4
    interpret = interpret_default()
    key = jax.random.key(0)
    mk = lambda i, shape: jax.random.normal(jax.random.fold_in(key, i),
                                            shape)
    rows = []

    # -- flat single-node update (Algs. 2/5 k-rule, sharded per-leaf) ----
    gn, go, h, gi = (mk(i, (d,)) for i in range(4))
    part = jnp.asarray(1.0)
    kw = dict(b=0.3, a=0.05, pa=0.5)
    unfused = lambda *xs: ref.dasha_update_ref(*xs, participates=part, **kw)
    fused = lambda *xs: dasha_update_op(*xs, participates=part, **kw)
    ideal = 7 * d * 4.0            # 4 reads + 3 writes of d f32
    rows.append(_row(
        "update_flat(grad/mvr)",
        t_unfused=timeit(jax.jit(unfused), gn, go, h, gi),
        t_fused=None if interpret else timeit(jax.jit(fused), gn, go, h, gi),
        b_unfused=hlo_bytes(unfused, gn, go, h, gi), ideal=ideal,
        err=_max_err(fused(gn, go, h, gi), unfused(gn, go, h, gi)),
        interpret=interpret))

    # -- batched node-major update (reference DashaPP engine) ------------
    db = d // n
    bgn, bgo, bh, bgi = (mk(10 + i, (n, db)) for i in range(4))
    mask = (jnp.arange(n) % 2).astype(jnp.float32)
    bunf = lambda *xs: ref.dasha_update_batched_ref(*xs, mask, **kw)
    bfus = lambda *xs: dasha_update_batched_op(*xs, mask, **kw)
    ideal = 7 * n * db * 4.0
    rows.append(_row(
        "update_batched(n-major)",
        t_unfused=timeit(jax.jit(bunf), bgn, bgo, bh, bgi),
        t_fused=None if interpret else timeit(jax.jit(bfus), bgn, bgo, bh, bgi),
        b_unfused=hlo_bytes(bunf, bgn, bgo, bh, bgi), ideal=ideal,
        err=_max_err(bfus(bgn, bgo, bh, bgi), bunf(bgn, bgo, bh, bgi)),
        interpret=interpret))

    # -- fused PAGE rule (Alg. 3: both branches + coin) ------------------
    bbn, bbo = mk(20, (n, db)), mk(21, (n, db))
    coin = jnp.asarray(1.0)
    pkw = dict(p_page=0.125, **kw)
    punf = lambda *xs: ref.dasha_page_update_ref(*xs, mask, coin, **pkw)
    pfus = lambda *xs: dasha_page_update_op(*xs, mask, coin, **pkw)
    ideal = 9 * n * db * 4.0       # 6 reads + 3 writes
    rows.append(_row(
        "update_page(alg3)",
        t_unfused=timeit(jax.jit(punf), bgn, bgo, bbn, bbo, bh, bgi),
        t_fused=None if interpret else timeit(jax.jit(pfus), bgn, bgo, bbn, bbo,
                                              bh, bgi),
        b_unfused=hlo_bytes(punf, bgn, bgo, bbn, bbo, bh, bgi),
        ideal=ideal,
        err=_max_err(pfus(bgn, bgo, bbn, bbo, bh, bgi),
                     punf(bgn, bgo, bbn, bbo, bh, bgi)),
        interpret=interpret))

    # -- finite-MVR tail (Alg. 4: k precomputed by the scatter) ----------
    tunf = lambda *xs: ref.dasha_tail_ref(*xs, mask, a=kw["a"],
                                          pa=kw["pa"])
    tfus = lambda *xs: dasha_tail_op(*xs, mask, a=kw["a"], pa=kw["pa"])
    ideal = 5 * n * db * 4.0       # 3 reads + 2 writes
    rows.append(_row(
        "update_tail(finite_mvr)",
        t_unfused=timeit(jax.jit(tunf), bgn, bh, bgi),
        t_fused=None if interpret else timeit(jax.jit(tfus), bgn, bh, bgi),
        b_unfused=hlo_bytes(tunf, bgn, bh, bgi), ideal=ideal,
        err=_max_err(tfus(bgn, bh, bgi), tunf(bgn, bh, bgi)),
        interpret=interpret))

    # -- fused update+compress (sparse wire: payload never dense) --------
    bs, ratio = 128, 1 / 64
    nb = -(-d // bs)
    kb = max(1, int(ratio * nb))
    idx = jnp.asarray(
        np.random.default_rng(0).choice(nb, kb, replace=False), jnp.int32)
    ckw = dict(scale=nb / kb, block_size=bs, **kw)
    cunf = lambda *xs: ref.dasha_payload_blocks_ref(*xs, idx, **ckw)
    cfus = lambda *xs: dasha_payload_blocks_op(*xs, idx, **ckw)
    # selected-blocks traffic only: 4 reads + 1 write of kb*bs f32
    ideal = 5 * kb * bs * 4.0
    rows.append(_row(
        "payload_compress(blockrandk)",
        t_unfused=timeit(jax.jit(cunf), gn, go, h, gi),
        t_fused=None if interpret else timeit(jax.jit(cfus), gn, go, h, gi),
        b_unfused=hlo_bytes(cunf, gn, go, h, gi), ideal=ideal,
        err=_max_err([cfus(gn, go, h, gi)], [cunf(gn, go, h, gi)]),
        interpret=interpret))

    # -- fused PAGE wire pair (h in-register + payload at blocks) --------
    # coin is a *traced* argument so XLA cannot fold one branch away in
    # the unfused chain (it is a runtime scalar in production too).
    pbn, pbo = mk(30, (d,)), mk(31, (d,))
    coin1 = jnp.asarray(1.0)
    pckw = dict(p_page=0.125, **ckw)
    pcunf = lambda *xs: ref.dasha_page_payload_blocks_ref(*xs[:-1], idx,
                                                          xs[-1], **pckw)
    pcfus = lambda *xs: dasha_page_payload_blocks_op(*xs[:-1], idx,
                                                     xs[-1], **pckw)
    ideal = 7 * kb * bs * 4.0      # 6 reads + 1 write of selected blocks
    rows.append(_row(
        "page_payload_compress(blockrandk)",
        t_unfused=timeit(jax.jit(pcunf), gn, go, pbn, pbo, h, gi, coin1),
        t_fused=None if interpret else timeit(jax.jit(pcfus), gn, go, pbn,
                                              pbo, h, gi, coin1),
        b_unfused=hlo_bytes(pcunf, gn, go, pbn, pbo, h, gi, coin1),
        ideal=ideal,
        err=_max_err([pcfus(gn, go, pbn, pbo, h, gi, coin1)],
                     [pcunf(gn, go, pbn, pbo, h, gi, coin1)]),
        interpret=interpret))

    # -- async buffered commit (K-arrival buffer -> server g, §9) --------
    K = n
    gsrv, mbuf = mk(40, (d,)), mk(41, (K, d))
    wts = jnp.abs(mk(42, (K,)))
    cunf2 = lambda g_, m_, w_: g_ + (w_ @ m_) / float(n)
    cfus2 = lambda g_, m_, w_: buffered_commit_op(g_, m_, w_, n_nodes=n)
    ideal = (K + 2) * d * 4.0      # K buffer rows + g read + g write
    rows.append(_row(
        "buffered_commit(async)",
        t_unfused=timeit(jax.jit(cunf2), gsrv, mbuf, wts),
        t_fused=None if interpret else timeit(jax.jit(cfus2), gsrv, mbuf,
                                              wts),
        b_unfused=hlo_bytes(cunf2, gsrv, mbuf, wts), ideal=ideal,
        err=_max_err([cfus2(gsrv, mbuf, wts)], [cunf2(gsrv, mbuf, wts)]),
        interpret=interpret))

    # -- paged-attention decode read (serving §11) -----------------------
    # the unfused jnp path gathers the full (B, M*P) context into HBM
    # before the attention reduction reads it back; the kernel streams
    # each page through VMEM once with the softmax state in scratch.
    B, H, kvh, hd = (2, 4, 2, 32) if quick else (8, 8, 4, 64)
    P_pg, M_pg = (8, 4) if quick else (16, 16)
    NP_pg = 2 * B * M_pg
    pkey = jax.random.fold_in(key, 99)
    qd = jax.random.normal(jax.random.fold_in(pkey, 0), (B, H, hd))
    kpg = jax.random.normal(jax.random.fold_in(pkey, 1), (NP_pg, P_pg, kvh, hd))
    vpg = jax.random.normal(jax.random.fold_in(pkey, 2), (NP_pg, P_pg, kvh, hd))
    prng = np.random.default_rng(0)
    table = jnp.asarray(prng.permutation(NP_pg)[:B * M_pg].reshape(B, M_pg),
                        jnp.int32)
    lens = jnp.asarray(prng.integers(P_pg, M_pg * P_pg + 1, B), jnp.int32)
    paunf = lambda *xs: paged_attention_ref(*xs)
    pafus = lambda *xs: paged_attention_op(*xs)
    # gathered K+V pages once through VMEM + q read + out write
    ideal = (2 * B * M_pg * P_pg * kvh * hd + 2 * B * H * hd) * 4.0
    rows.append(_row(
        "paged_attention(decode)",
        t_unfused=timeit(jax.jit(paunf), qd, kpg, vpg, table, lens),
        t_fused=None if interpret else timeit(jax.jit(pafus), qd, kpg, vpg,
                                              table, lens),
        b_unfused=hlo_bytes(paunf, qd, kpg, vpg, table, lens), ideal=ideal,
        err=_max_err([pafus(qd, kpg, vpg, table, lens)],
                     [paunf(qd, kpg, vpg, table, lens)]),
        interpret=interpret))

    # -- fused multi-request batched launch (chunked-prefill pass) -------
    # C queries per slot ride the same page walk; the jnp path still
    # gathers the dense (B, M*P) context per pass.
    Cq = 4
    qb = jax.random.normal(jax.random.fold_in(pkey, 3), (B, Cq, H, hd))
    start = jnp.maximum(lens - Cq, 0)
    qlens = jnp.full((B,), Cq, jnp.int32)
    baunf = lambda *xs: paged_attention_batched_ref(*xs)
    bafus = lambda *xs: paged_attention_batched_op(*xs)
    ideal = (2 * B * M_pg * P_pg * kvh * hd + 2 * B * Cq * H * hd) * 4.0
    rows.append(_row(
        "paged_attention_batched(fused)",
        t_unfused=timeit(jax.jit(baunf), qb, kpg, vpg, table, start, qlens),
        t_fused=None if interpret else timeit(jax.jit(bafus), qb, kpg, vpg,
                                              table, start, qlens),
        b_unfused=hlo_bytes(baunf, qb, kpg, vpg, table, start, qlens),
        ideal=ideal,
        err=_max_err([bafus(qb, kpg, vpg, table, start, qlens)],
                     [baunf(qb, kpg, vpg, table, start, qlens)]),
        interpret=interpret))

    # -- paged MLA latent attention (absorbed decode, §11) ---------------
    # per-token page traffic is r + rope_hd floats; the up-projected
    # K/V never exist in either path (the ref is already absorbed).
    r_lat, rr_rope = (32, 16) if quick else (64, 32)
    qa = jax.random.normal(jax.random.fold_in(pkey, 4), (B, Cq, H, r_lat))
    qr = jax.random.normal(jax.random.fold_in(pkey, 5), (B, Cq, H, rr_rope))
    ckvp = jax.random.normal(jax.random.fold_in(pkey, 6),
                             (NP_pg, P_pg, r_lat))
    krp = jax.random.normal(jax.random.fold_in(pkey, 7),
                            (NP_pg, P_pg, rr_rope))
    mscale = 1.0 / float(np.sqrt(hd))
    munf = lambda *xs: paged_mla_attention_ref(*xs, scale=mscale)
    mfus = lambda *xs: paged_mla_attention_op(*xs, scale=mscale)
    ideal = (B * M_pg * P_pg * (r_lat + rr_rope)
             + B * Cq * H * (2 * r_lat + rr_rope)) * 4.0
    rows.append(_row(
        "paged_mla_attention(absorbed)",
        t_unfused=timeit(jax.jit(munf), qa, qr, ckvp, krp, table, start,
                         qlens),
        t_fused=None if interpret else timeit(jax.jit(mfus), qa, qr, ckvp,
                                              krp, table, start, qlens),
        b_unfused=hlo_bytes(munf, qa, qr, ckvp, krp, table, start, qlens),
        ideal=ideal,
        err=_max_err([mfus(qa, qr, ckvp, krp, table, start, qlens)],
                     [munf(qa, qr, ckvp, krp, table, start, qlens)]),
        interpret=interpret))

    hkw = dict(b=kw["b"], pa=kw["pa"], p_page=0.125)
    hunf = lambda *xs: ref.dasha_page_h_update_ref(*xs[:-1], part, xs[-1],
                                                   **hkw)
    hfus = lambda *xs: dasha_page_h_update_op(
        *xs[:-1], xs[-1], participates=part, **hkw)
    ideal = 6 * d * 4.0            # 5 reads + 1 write of d f32
    rows.append(_row(
        "page_h_update(in-register k)",
        t_unfused=timeit(jax.jit(hunf), gn, go, pbn, pbo, h, coin1),
        t_fused=None if interpret else timeit(jax.jit(hfus), gn, go, pbn,
                                              pbo, h, coin1),
        b_unfused=hlo_bytes(hunf, gn, go, pbn, pbo, h, coin1), ideal=ideal,
        err=_max_err([hfus(gn, go, pbn, pbo, h, coin1)],
                     [hunf(gn, go, pbn, pbo, h, coin1)]),
        interpret=interpret))
    return rows


def _load_trajectory(path: str = RESULTS_PATH) -> list:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if data and isinstance(data, list) and \
            not (isinstance(data[0], dict) and "cells" in data[0]):
        # pre-trajectory format (a bare — possibly nested — row list):
        # absorb it as one entry so history survives the conversion
        flat = []
        stack = list(data)
        while stack:
            item = stack.pop(0)
            if isinstance(item, list):
                stack = list(item) + stack
            elif isinstance(item, dict):
                flat.append(item)
        return [{"mode": "legacy", "cells": flat}]
    return data


def _append_trajectory(rows: list, mode: str, path: str = RESULTS_PATH):
    from repro.obs import provenance

    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "mode": mode,
        "backend": jax.default_backend(),
        "provenance": provenance.collect(),
        "cells": rows,
    }
    traj = _load_trajectory(path)
    traj.append(entry)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(traj, f, indent=1, default=str)
    print(f"  trajectory: appended '{mode}' entry #{len(traj)} to {path}")


def main(quick: bool = True):
    rows = run(quick=quick)
    print("# kernel layer: HBM traffic of the control-variate update")
    ok = True
    for r in rows:
        line = (f"  kernels,{r['name']},us_unfused={r['us_unfused']:.1f},"
                f"bytes={r['hlo_bytes']:.3e},x_ideal={r['ratio']:.2f},"
                f"max_err={r['max_err']:.2e}")
        if r["interpret"]:
            line += ",interpret=true"
        else:
            line += f",us_fused={r['us_fused']:.1f},speedup={r['speedup']:.2f}"
            ok &= r["speedup"] >= SPEEDUP_TARGET
        # roofline sanity: every unfused chain must move more bytes than
        # the fused ideal, else the fusion has nothing to win (nan =
        # backend exposes no bytes-accessed analysis; nothing to check)
        assert np.isnan(r["ratio"]) or r["ratio"] >= 1.0, \
            (r["name"], r["ratio"])
        print(line)
    if not ok:
        print(f"  WARNING: fused speedup below {SPEEDUP_TARGET}x target")
    _append_trajectory(rows, mode="smoke" if quick else "full")
    yield rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; exercises every kernel in the "
                         "family (interpret mode on CPU) — the CI job")
    args = ap.parse_args()
    list(main(quick=args.smoke))
