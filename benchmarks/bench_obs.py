"""Observability-overhead benchmark: the DESIGN.md §13 acceptance gate
that DISABLED tracing stays invisible in the serving hot path.

Two measurements:

* ``span_ns`` — nanoseconds per ``obs_trace.span(...)`` call with no
  tracer installed.  The disabled fast path returns a shared null-span
  singleton (no allocation, no clock read), so this is a few hundred
  ns of dict-kwarg plumbing at worst.
* ``pass_us`` — microseconds per fused serve pass, measured from the
  ``PagedEngine`` decode counters on a small pure-decode workload with
  tracing uninstalled (the engine path crosses ~4 span/counter sites
  per pass: serve.pass, serve.admit, the pool counter, and the admit
  fast-exit).

Acceptance (the CI row): ``SPANS_PER_PASS * span_ns`` must be under
``OVERHEAD_BUDGET`` (3%) of the measured pass time — i.e. leaving the
instrumentation compiled in costs the serving engine effectively
nothing when no ``--trace-out`` is given.
"""
from __future__ import annotations

import time

# span/counter call sites crossed by one fused serve pass (serve.pass +
# serve.admit + pool.pages_live counter, rounded up for slack)
SPANS_PER_PASS = 8
OVERHEAD_BUDGET = 0.03   # disabled tracing may cost < 3% of a pass


def _null_span_ns(calls: int = 200_000) -> float:
    """ns per disabled ``span()`` call (kwargs included, like the
    engine's hot sites)."""
    from repro.obs import trace as obs_trace

    obs_trace.uninstall()   # defensive: measure the DISABLED path
    span = obs_trace.span
    # warmup
    for _ in range(1000):
        with span("bench.noop", track="bench", step=0):
            pass
    t0 = time.perf_counter_ns()
    for i in range(calls):
        with span("bench.noop", track="bench", step=i):
            pass
    return (time.perf_counter_ns() - t0) / calls


def _serve_pass_us(arch: str = "granite-3-2b") -> dict:
    """µs per fused serve pass, pure-decode steady state, no tracer."""
    import jax
    import numpy as np

    from repro.models import Model, get_smoke_config
    from repro.obs import trace as obs_trace
    from repro.serving import PagedEngine, Request

    obs_trace.uninstall()
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)

    def mk():
        return [Request(uid=i,
                        prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
                        max_new_tokens=16)
                for i in range(8)]

    eng = PagedEngine(model, params, batch_size=4, max_seq_len=128,
                      page_size=8)
    eng.run(mk())            # warmup: jit compiles, every table width
    eng.reset_perf_counters()
    eng.run(mk())
    steps = max(1, eng.decode_steps)
    return {"pass_us": eng.decode_seconds / steps * 1e6,
            "decode_steps": steps,
            "decode_tokens": eng.decode_tokens}


def run(quick: bool = True) -> dict:
    span_ns = _null_span_ns(50_000 if quick else 200_000)
    cell = _serve_pass_us()
    overhead = SPANS_PER_PASS * span_ns / 1e3 / cell["pass_us"]
    return {
        "span_ns": span_ns,
        "spans_per_pass": SPANS_PER_PASS,
        "overhead_frac": overhead,
        "budget": OVERHEAD_BUDGET,
        **cell,
    }


def main(quick: bool = True):
    res = run(quick=quick)
    print("# obs: disabled-tracing overhead vs the fused serve pass")
    print(f"  obs,span_ns={res['span_ns']:.0f},"
          f"pass_us={res['pass_us']:.0f},"
          f"overhead={res['overhead_frac'] * 100:.3f}%,"
          f"budget={res['budget'] * 100:.0f}%")
    # §13 acceptance: instrumentation left compiled-in is free when off
    assert res["overhead_frac"] < res["budget"], res
    print("OK: disabled tracing costs <3% of a fused serve pass")
    yield [res]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer null-span iterations — the CI row")
    args = ap.parse_args()
    list(main(quick=args.smoke))
