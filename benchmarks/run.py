"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (see DESIGN.md §7):
  bench_pa_sweep      Fig. 1   (1/p_a degradation, finite-sum + stochastic)
  bench_methods       Figs.2-5 (DASHA-PP vs MARINA vs FRECON)
  bench_comm          Tab.1-2  (communication complexity, CC column)
  bench_batch_effect  §C       (mean-estimation batch-size effect)
  bench_kernels       kernels  (fused update HBM traffic)
  roofline            §Roofline (from dry-run artifacts, if present)

Prints ``name,...,derived`` CSV lines per benchmark.  ``--full`` runs
paper-scale round counts (slow on 1 CPU core); the default quick mode
keeps every benchmark's qualitative claim intact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/bench")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (bench_async, bench_batch_effect, bench_comm,
                            bench_fleet, bench_kernels, bench_methods,
                            bench_obs, bench_pa_sweep, bench_serving,
                            roofline)
    suites = {
        "pa_sweep": bench_pa_sweep.main,
        "methods": bench_methods.main,
        "comm": bench_comm.main,
        "batch_effect": bench_batch_effect.main,
        "kernels": bench_kernels.main,
        "async": bench_async.main,
        "serving": bench_serving.main,
        "fleet": bench_fleet.main,
        "obs": bench_obs.main,
        "roofline": roofline.main,
    }
    # suites that append to their own trajectory file under results/;
    # the generic per-suite dump below must not clobber it
    self_managed = {"kernels"}
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for name, fn in suites.items():
        print(f"\n===== benchmark: {name} =====", flush=True)
        t0 = time.time()
        try:
            results = list(fn(quick=quick))
            if name not in self_managed:
                with open(os.path.join(args.out, f"{name}.json"), "w") as f:
                    json.dump(results, f, indent=1, default=str)
            print(f"===== {name} done in {time.time()-t0:.1f}s =====",
                  flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"FAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
