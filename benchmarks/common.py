"""Shared benchmark utilities: problem construction mirroring paper §A,
method runners, and stepsize finetuning over {2^i} (the paper's
protocol: all parameters as theory suggests, stepsize finetuned)."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Frecon, FreconConfig, LogisticSigmoidProblem, Marina,
                        MarinaConfig, NonconvexSoftmaxProblem, RandK, SNice,
                        dasha, dasha_mvr, dasha_pp, dasha_pp_finite_mvr,
                        dasha_pp_mvr, dasha_pp_page,
                        make_synthetic_classification, theory)
from repro.core.participation import FullParticipation


def make_paper_problem(setting: str = "finite_sum", n: int = 100,
                       m: int = 36, d: int = 300, seed: int = 0):
    """Synthetic analogue of the paper's real-sim split: n=100 nodes,
    sparse features, heterogeneous nodes.  ``setting`` picks eq. (11)
    (finite-sum) or eq. (12)-style (stochastic)."""
    feats, y = make_synthetic_classification(
        jax.random.key(seed), n_nodes=n, m_per_node=m, d=d,
        heterogeneity=1.0, density=0.15)
    if setting == "stochastic_reg":
        return NonconvexSoftmaxProblem(feats, y, lam=1e-3)
    return LogisticSigmoidProblem(feats, y)


def constants_of(problem) -> theory.ProblemConstants:
    L, L_hat, L_max, L_sigma = problem.smoothness()
    return theory.ProblemConstants(L=L, L_hat=L_hat, L_max=L_max,
                                   L_sigma=L_sigma, n=problem.n,
                                   m=problem.m, d=problem.d)


@dataclasses.dataclass
class RunResult:
    name: str
    grad_norm_sq: np.ndarray       # per round
    bits_per_node: np.ndarray      # cumulative uplink bits / n
    gamma: float
    loss: Optional[np.ndarray] = None

    def rounds_to(self, eps: float) -> Optional[int]:
        hit = np.nonzero(self.grad_norm_sq <= eps)[0]
        return int(hit[0]) if hit.size else None

    def bits_to(self, eps: float) -> Optional[float]:
        r = self.rounds_to(eps)
        return float(self.bits_per_node[r]) if r is not None else None


def run_method(make_alg: Callable[[float], object], key, x0, rounds: int,
               gamma_grid: Optional[List[float]] = None,
               n_nodes: int = 100) -> RunResult:
    """Run ``make_alg(gamma)`` for each gamma in the grid, keep the best
    final gradient norm (paper: stepsizes finetuned from {2^i})."""
    best = None
    for gamma in (gamma_grid or [None]):
        alg = make_alg(gamma)
        _, mets = jax.jit(lambda k: alg.run(k, x0, rounds))(key)
        g = np.asarray(mets.grad_norm_sq)
        losses = np.asarray(mets.loss)
        xn = np.asarray(mets.x_norm)
        if not np.all(np.isfinite(g)):
            continue
        # the paper's metric is ||grad f||^2; interior stationary points
        # count as converged even if f rose (nonconvex).  Only reject
        # actual escape to infinity (flat tails at ||x|| -> inf; converged
        # logistic solutions here live at ||x|| = O(10)).
        if xn[-1] > 1e3:
            continue
        score = np.log(np.maximum(g[-(rounds // 10):], 1e-30)).mean()
        if best is None or score < best[0]:
            bits = np.cumsum(np.asarray(mets.bits_sent)) / n_nodes
            best = (score, RunResult(name="", grad_norm_sq=g,
                                     bits_per_node=bits,
                                     gamma=float(gamma or 0.0),
                                     loss=losses))
    if best is None:
        return RunResult(name="", grad_norm_sq=np.array([np.inf]),
                         bits_per_node=np.array([0.0]), gamma=float("nan"))
    return best[1]


def gamma_grid_around(gamma0: float, lo: int = 0, hi: int = 7
                      ) -> List[float]:
    """{gamma0 * 2^i} — theory gamma is a lower bound, finetune upward."""
    return [gamma0 * (2.0 ** i) for i in range(lo, hi)]
