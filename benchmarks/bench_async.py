"""Async-runtime benchmark: virtual wall-clock of buffered first-K
aggregation vs the full-barrier round, swept over latency heterogeneity
x buffer size x variant (DESIGN.md §9).

The sync engines price a round at the cohort MAX latency; the async
server prices it at the K-th order statistic.  Under lognormal
heterogeneity the gap is the paper's partial-participation story told
in wall-clock: the server never needed everyone, so it should not pay
for everyone.  Reported per row:

* ``t_virtual``   — total virtual seconds for the same dispatch budget,
* ``speedup``     — barrier time / this time (barrier row = 1.0),
* ``gnorm``       — median final ||∇f(x)||² (solution quality),
* ``s_mean``      — mean commit staleness (the price of not waiting),
* ``util``        — mean client busy-fraction.

    PYTHONPATH=src python benchmarks/bench_async.py [--smoke] [--sharded]

``--sharded`` additionally compares barrier vs gang-scheduled cohorts
on the sharded LM TRAINER (DESIGN.md §10) by driving
``repro.launch.async_sharded_train`` in subprocesses (the host mesh
needs XLA_FLAGS set before jax imports, which this process has already
done) and asserts the flight-buffered scheduler beats the barrier in
virtual wall-clock on a heterogeneous fleet.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import make_paper_problem  # noqa: E402
from repro.core import RandK, SNice
from repro.core.dasha_pp import DashaPPConfig
from repro.fl import (AsyncConfig, AsyncDashaServer, ConstantLatency,
                      LognormalLatency)


def run_cell(prob, variant: str, sigma: float, buffer_frac, rounds: int,
             s_cohort: int, seed: int = 1):
    samp = SNice(n=prob.n, s=s_cohort)
    comp = RandK(k=max(1, prob.d // 20))
    cfg = DashaPPConfig(variant, gamma=0.05, a=0.1, b=0.3, p_page=0.25,
                        batch_size=2)
    if sigma == 0.0:
        lat = ConstantLatency(compute_s=1.0, bandwidth_bps=1e5)
    else:
        lat = LognormalLatency(compute_s=1.0, sigma=sigma,
                               client_sigma=sigma, bandwidth_bps=1e5,
                               bandwidth_sigma=sigma / 2)
    K = (None if buffer_frac is None
         else max(1, int(round(buffer_frac * s_cohort))))
    srv = AsyncDashaServer(prob, comp, samp, cfg, AsyncConfig(
        buffer_size=K, staleness_exponent=0.5), lat)
    _, res = srv.run(jax.random.key(seed), jnp.zeros(prob.d), rounds)
    return dict(
        t_virtual=res.total_time,
        gnorm=float(np.median(res.grad_norm_sq[-max(1, rounds // 10):])),
        s_mean=float(np.mean(res.staleness_mean)),
        util=float(np.mean(res.utilization)),
        bits=float(res.bits_cum[-1]))


def main(quick: bool = True):
    if quick:
        n, m, d, rounds = 8, 6, 24, 25
        variants_ = ("mvr", "page")
        sigmas = (0.0, 1.0)
        buffers = (None, 0.5)
    else:
        n, m, d, rounds = 32, 12, 120, 400
        variants_ = ("gradient", "mvr", "page", "finite_mvr")
        sigmas = (0.0, 0.5, 1.0)
        buffers = (None, 0.5, 0.25)
    prob = make_paper_problem(setting="finite_sum", n=n, m=m, d=d)
    s_cohort = max(2, n // 4)

    print("# async runtime: buffered first-K vs full barrier "
          "(virtual wall-clock)")
    rows, ok = [], True
    for variant in variants_:
        for sigma in sigmas:
            base = None
            for frac in buffers:
                cell = run_cell(prob, variant, sigma, frac, rounds,
                                s_cohort)
                if frac is None:
                    base = cell["t_virtual"]
                speed = base / cell["t_virtual"]
                tag = "barrier" if frac is None else f"K={frac:.2f}s"
                cell.update(variant=variant, sigma=sigma, buffer=tag,
                            speedup=speed)
                rows.append(cell)
                print(f"  async,{variant},sigma={sigma},{tag},"
                      f"t_virtual={cell['t_virtual']:.1f},"
                      f"speedup={speed:.2f},gnorm={cell['gnorm']:.3e},"
                      f"s_mean={cell['s_mean']:.2f},"
                      f"util={cell['util']:.2f}")
                # acceptance: under heterogeneity, not waiting for the
                # stragglers must be faster than waiting for them
                if sigma > 0 and frac is not None:
                    ok &= speed > 1.0
    # AssertionError (not SystemExit) so benchmarks/run.py's failure
    # handling records this suite and still runs the rest
    assert ok, ("buffered first-K failed to beat the barrier under "
                "latency heterogeneity")
    print("OK: buffered-first-K beats the full barrier under "
          "heterogeneity")
    yield rows


def _run_sharded_cell(buffer: int, rounds: int, sigma: float) -> dict:
    """One barrier-vs-gang cell on the LM trainer, via the CLI in a
    subprocess (a fresh process so --smoke can set the host-mesh
    XLA_FLAGS before jax initializes)."""
    import re
    import subprocess

    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
        + (os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""))
    cmd = [sys.executable, "-m", "repro.launch.async_sharded_train",
           "--smoke", "--rounds", str(rounds), "--buffer", str(buffer),
           "--latency", "lognormal", "--sigma", str(sigma),
           "--variant", "mvr", "--seed", "3"]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    m = re.search(r"^RESULT (.*)$", out.stdout, re.M)
    assert m, out.stdout
    return {k: float(v) for k, v in
            (kv.split("=") for kv in m.group(1).split())}


def main_sharded(quick: bool = True):
    """Gang-scheduled cohorts vs barrier on the sharded LM trainer."""
    rounds = 10 if quick else 40
    sigma = 1.2
    print("# sharded trainer: gang-scheduled cohorts vs barrier "
          "(virtual wall-clock, lognormal sigma=%.1f)" % sigma)
    base = None
    rows = []
    for buffer in (0, 3):   # 0 = barrier
        cell = _run_sharded_cell(buffer, rounds, sigma)
        if base is None:
            base = cell["t_virtual"]
        speed = base / max(cell["t_virtual"], 1e-9)
        tag = "barrier" if buffer == 0 else f"K={buffer}"
        cell.update(buffer=tag, speedup=speed)
        rows.append(cell)
        print(f"  async-sharded,mvr,{tag},"
              f"t_virtual={cell['t_virtual']:.1f},speedup={speed:.2f},"
              f"loss={cell['loss']:.4f},s_mean={cell['s_mean']:.2f}")
    assert rows[-1]["speedup"] > 1.0, (
        "gang-scheduled cohorts failed to beat the barrier in virtual "
        "wall-clock on the heterogeneous fleet")
    print("OK: gang-scheduled cohorts beat the trainer barrier under "
          "heterogeneity")
    yield rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / fewer cells — the CI row")
    ap.add_argument("--sharded", action="store_true",
                    help="also run the LM-trainer cohort comparison")
    args = ap.parse_args()
    list(main(quick=args.smoke))
    if args.sharded:
        list(main_sharded(quick=args.smoke))
