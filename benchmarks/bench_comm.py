"""Paper Tables 1-2, CC column: communication complexity — uplink bits
per node to reach an eps-solution, across methods and compressors.

Validates: compressed DASHA-PP reaches eps with far fewer bits than its
uncompressed (identity) variant and than MARINA (which periodically
ships full gradients), and RandK's K trades rounds for bits per the
Corollary-2 prescription K = Theta(B d / sqrt(m)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (constants_of, gamma_grid_around,
                               make_paper_problem, run_method)
from repro.core import (Identity, Marina, MarinaConfig, RandK, SNice,
                        dasha_pp_page, theory)


def run(rounds: int = 2500, n: int = 100, s: int = 50, batch_size: int = 1,
        seed: int = 0, quick: bool = False):
    if quick:
        rounds, n, s = 900, 20, 10
    # communication claims need d large enough that index bits don't
    # drown the savings (the paper uses d = 20958)
    prob = make_paper_problem(setting="finite_sum", n=n,
                              m=12 if quick else 36,
                              d=240 if quick else 1200, seed=seed)
    c = constants_of(prob)
    samp = SNice(n=prob.n, s=s)
    pa, paa = samp.p_a, samp.p_aa
    x0 = jnp.zeros(prob.d)
    key = jax.random.key(seed + 3)

    k_cor2 = theory.corollary2_randk_k(prob.d, prob.m, batch_size)
    compressors = {
        "identity": Identity(),
        f"randk_cor2(K={k_cor2})": RandK(k=k_cor2),
        f"randk(K={max(1, prob.d // 20)})": RandK(k=max(1, prob.d // 20)),
    }
    rows = {}
    eps = None
    for cname, comp in compressors.items():
        omega = comp.omega(prob.d)
        hp = theory.dasha_pp_page(c, omega, pa, paa, batch_size)
        mk = lambda g, _c=comp, _h=hp: dasha_pp_page(
            prob, _c, samp, gamma=g, a=_h.a, b=_h.b, p_page=_h.p_page,
            batch_size=batch_size)
        res = run_method(mk, key, x0, rounds,
                         gamma_grid=[hp.gamma * (2.0 ** i) for i in range(0, 11, 2)],
                         n_nodes=prob.n)
        res.name = f"dasha-pp/{cname}"
        if eps is None:
            eps = float(res.grad_norm_sq[rounds // 3])
        rows[res.name] = res
    # MARINA baseline with the same RandK and the same minibatch oracle
    # (VR-MARINA style) so oracle costs are comparable
    comp = RandK(k=max(1, prob.d // 20))
    omega = comp.omega(prob.d)
    hp = theory.marina(c, omega)
    mk = lambda g: Marina(prob, comp, samp,
                          MarinaConfig(gamma=g, p_sync=1 / (1 + omega),
                                       batch_size=batch_size))
    res = run_method(mk, key, x0, rounds,
                     gamma_grid=[hp.gamma * (2.0 ** i) for i in range(0, 11, 2)],
                     n_nodes=prob.n)
    res.name = "marina/randk"
    rows[res.name] = res

    out = []
    for name, res in rows.items():
        out.append(dict(method=name, eps=eps,
                        rounds=res.rounds_to(eps),
                        mbits_per_node=(res.bits_to(eps) or float("nan")) / 1e6,
                        gamma=res.gamma))
    return out


def main(quick: bool = True):
    rows = run(quick=quick)
    print("# Tables 1-2 CC analogue: uplink cost to eps")
    for r in rows:
        print(f"  comm,{r['method']},rounds={r['rounds']},"
              f"Mbits/node={r['mbits_per_node']:.3f}")
    yield rows


if __name__ == "__main__":
    list(main(quick=False))
