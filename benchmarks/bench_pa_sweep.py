"""Paper Figure 1: DASHA-PP vs DASHA as a function of p_a.

Claim validated: DASHA-PP with s-nice sampling (p_a = s/n) converges no
more than ~1/p_a times slower in communication rounds than DASHA — and
approximately exactly 1/p_a times slower (paper §A: "DASHA-PP with s=10
and s=1 converges approximately x10 and x100 slower").

Both the finite-sum (DASHA-PP-PAGE, Fig. 1a) and stochastic
(DASHA-PP-MVR, Fig. 1b) settings are exercised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (constants_of, gamma_grid_around,
                               make_paper_problem, run_method)
from repro.core import RandK, SNice, dasha_mvr, dasha_page, dasha_pp_mvr, \
    dasha_pp_page, theory


def run(rounds: int = 2500, n: int = 100, s_values=(100, 10),
        setting: str = "finite_sum", batch_size: int = 1,
        seed: int = 0, quick: bool = False):
    if quick:
        rounds, n, s_values = 600, 20, (20, 5)
    prob = make_paper_problem(setting=setting, n=n,
                              m=12 if quick else 36,
                              d=60 if quick else 300, seed=seed)
    c = constants_of(prob)
    comp = RandK(k=max(1, prob.d // 20))
    omega = comp.omega(prob.d)
    x0 = jnp.zeros(prob.d)
    key = jax.random.key(seed + 1)
    rows = []
    eps = None
    for s in s_values:
        samp = SNice(n=prob.n, s=s)
        pa, paa = samp.p_a, samp.p_aa
        if setting == "finite_sum":
            hp = theory.dasha_pp_page(c, omega, pa, paa, batch_size)
            if s == prob.n:
                make = lambda g: dasha_page(
                    prob, comp, gamma=g, a=hp.a, b=hp.b, p_page=hp.p_page,
                    batch_size=batch_size)
            else:
                make = lambda g, _s=samp, _hp=hp: dasha_pp_page(
                    prob, comp, _s, gamma=g, a=_hp.a, b=_hp.b,
                    p_page=_hp.p_page, batch_size=batch_size)
        else:
            hp = theory.dasha_pp_mvr(c, omega, pa, paa, batch_size)
            if s == prob.n:
                make = lambda g: dasha_mvr(prob, comp, gamma=g, a=hp.a,
                                           b=hp.b, batch_size=batch_size)
            else:
                make = lambda g, _s=samp, _hp=hp: dasha_pp_mvr(
                    prob, comp, _s, gamma=g, a=_hp.a, b=_hp.b,
                    batch_size=batch_size)
        # PP runs get ~1/p_a x the round budget (the expected degradation)
        mult = int(min(16, max(1, round(1.0 / pa))))
        res = run_method(make, key, x0, rounds * mult,
                         gamma_grid=gamma_grid_around(hp.gamma),
                         n_nodes=prob.n)
        res.name = f"s={s} (p_a={pa:.2f})"
        if eps is None:
            # target: early full-participation level, clamped to >= 8x the
            # stochastic plateau so the PP runs' (comparable, see Thm. 4
            # with b = p_a/(2-p_a)) noise floor cannot dominate the
            # time-to-target measurement
            early = float(res.grad_norm_sq[rounds // 6])
            plateau = float(np.median(res.grad_norm_sq[-max(10, rounds // 10):]))
            eps = max(early, 8.0 * plateau)
        rows.append((s, pa, res))
    # report degradation ratios
    base_rounds = rows[0][2].rounds_to(eps)
    out = []
    for s, pa, res in rows:
        r = res.rounds_to(eps)
        ratio = (r / base_rounds) if (r and base_rounds) else float("nan")
        out.append(dict(s=s, p_a=pa, rounds_to_eps=r, ratio=ratio,
                        expected_max=1.0 / pa, gamma=res.gamma,
                        final_gnorm=float(res.grad_norm_sq[-1])))
    return dict(setting=setting, eps=eps, rows=out)


def main(quick: bool = True):
    for setting in ("finite_sum", "stochastic"):
        r = run(setting=setting, quick=quick)
        print(f"# Fig.1 analogue [{setting}] eps={r['eps']:.3e}")
        for row in r["rows"]:
            print(f"  pa_sweep,{setting},s={row['s']},rounds={row['rounds_to_eps']},"
                  f"ratio={row['ratio']:.2f},bound=1/pa={row['expected_max']:.1f}")
        yield r


if __name__ == "__main__":
    list(main(quick=False))
