"""Hierarchical-fleet benchmark: aggregation-tree pre-reduction vs the
flat topology (DESIGN.md §12) at EQUAL cohort size and round count.

Per cell, a depth-1 and a depth-2 tree and the flat (depth-0) fleet run
the same streamed DASHA-PP workload under the same per-edge s-nice
sampler, zero jitter and barrier buffers — so all three commit the
identical contribution multiset and the only difference is the wire.
We report per topology:

* ``root_bits`` — bits crossing the final hop into the root server (the
  link the paper's partial-participation accounting prices; for the
  flat fleet this is the client uplink itself);
* ``total_bits`` — all hops summed (trees pay extra interior hops; the
  claim is about the root bottleneck, so total is REPORTED, not
  asserted);
* ``bits_per_contribution`` at the root — the fair equal-work metric.

Smoke acceptance (the CI row): on every cell the tree's root-hop
bits/contribution are strictly below the flat fleet's at equal cohort
size — pre-reduction (round-grouped float64 merge + sparse-or-dense
re-encoding) turns E*s client uplinks into at most a few near-dense
messages per round.

``results/BENCH_fleet.json`` is a TRAJECTORY (same contract as
``results/BENCH_serving.json``): each run appends one entry
``{ts, mode, backend, provenance, cells}`` instead of overwriting, so
the wire-cost history accumulates across PRs; a pre-trajectory file (a
bare row list) is absorbed as one legacy entry on first append.
"""
from __future__ import annotations

import json
import math
import os
import time

RESULTS_PATH = "results/BENCH_fleet.json"


def _run_topology(*, depth: int, n: int, d: int, edges: int, mid: int,
                  s: int, k: int, rounds: int, backend: str):
    import jax
    import numpy as np

    from repro.core import RandK
    from repro.core.participation import EdgeSNice
    from repro.fl import (ConstantLatency, FleetConfig,
                          HierarchicalFleet, StreamedGradientWorkload,
                          TierConfig, edge_partition)

    bounds = tuple(int(b) for b in edge_partition(n, edges))
    wl = StreamedGradientWorkload(
        sampler=EdgeSNice(bounds=bounds, s=s), d=d,
        compressor=RandK(k=k), gamma=0.05, a=0.1, b=0.3,
        m_per_client=1)
    tiers = ()
    if depth >= 1:
        tiers += (TierConfig(aggregators=edges),)
    if depth >= 2:
        tiers += (TierConfig(aggregators=mid),)
    fleet = HierarchicalFleet(wl, FleetConfig(tiers=tiers),
                              ConstantLatency(compute_s=1.0),
                              store_backend=backend)
    t0 = time.perf_counter()
    fs, res = fleet.run(jax.random.key(1), np.zeros(d, np.float32),
                        rounds)
    wall = time.perf_counter() - t0
    committed = int(res.committed.sum())
    out = {
        "depth": depth,
        "committed": committed,
        "root_bits": float(res.tier_bits[-1]),
        "total_bits": float(res.bits_cum[-1]),
        "bits_per_contribution": float(res.tier_bits[-1]) / committed,
        "grad_norm_sq": float(res.grad_norm_sq[-1]),
        "wall_s": wall,
    }
    fs.store.close()
    return out, committed


def _cell(*, n: int, d: int, edges: int, mid: int, s: int,
          ratio: float, rounds: int, backend: str) -> dict:
    k = max(1, math.ceil(ratio * d))
    row = {"n": n, "d": d, "edges": edges, "mid": mid, "s": s,
           "cohort": edges * s, "randk_k": k, "rounds": rounds,
           "store": backend}
    committed = {}
    for depth, name in ((0, "flat"), (1, "tree1"), (2, "tree2")):
        out, c = _run_topology(depth=depth, n=n, d=d, edges=edges,
                               mid=mid, s=s, k=k, rounds=rounds,
                               backend=backend)
        committed[name] = c
        for key, val in out.items():
            if key != "depth":
                row[f"{name}_{key}"] = val
    # equal work: same sampler + zero jitter + barrier => the three
    # topologies committed the same number of contributions
    assert len(set(committed.values())) == 1, committed
    return row


def run(quick: bool = True):
    if quick:
        cells = [dict(n=4096, d=256, edges=8, mid=2, s=16, ratio=0.05,
                      rounds=5, backend="ram"),
                 dict(n=10000, d=128, edges=4, mid=2, s=24, ratio=0.1,
                      rounds=5, backend="memmap")]
    else:
        cells = [dict(n=100000, d=256, edges=16, mid=4, s=16,
                      ratio=0.05, rounds=10, backend="memmap"),
                 dict(n=100000, d=512, edges=8, mid=2, s=32,
                      ratio=0.05, rounds=10, backend="memmap")]
    return [_cell(**c) for c in cells]


def _load_trajectory(path: str) -> list:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if data and isinstance(data, list) and "cells" not in data[0]:
        # pre-trajectory format (a bare row list): keep it as one entry
        return [{"mode": "legacy", "cells": data}]
    return data


def _append_trajectory(rows: list, mode: str, path: str = RESULTS_PATH):
    import jax

    from repro.obs import provenance

    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "mode": mode,
        "backend": jax.default_backend(),
        "provenance": provenance.collect(),
        "cells": rows,
    }
    traj = _load_trajectory(path)
    traj.append(entry)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(traj, f, indent=1, default=str)


def main(quick: bool = True, trace_out: str = None, metrics_out: str = None):
    from repro.obs import start_run

    mode = "smoke" if quick else "full"
    obsrun = start_run(
        trace_out=trace_out or f"results/traces/bench_fleet_{mode}.trace.json",
        metrics_out=metrics_out
        or f"results/traces/bench_fleet_{mode}.metrics.json",
        meta={"cli": "bench_fleet", "mode": mode})
    rows = run(quick=quick)
    obsrun.finish()
    print("# hierarchical fleet: root-hop bits vs flat, equal cohort")
    for r in rows:
        print(f"  fleet,n={r['n']},d={r['d']},E={r['edges']},"
              f"cohort={r['cohort']},"
              f"root_bits/contrib flat={r['flat_bits_per_contribution']:.0f},"
              f"tree1={r['tree1_bits_per_contribution']:.0f},"
              f"tree2={r['tree2_bits_per_contribution']:.0f},"
              f"committed={r['flat_committed']}")
        # the §12 acceptance: pre-reduction undercuts the flat root
        # uplink at equal cohort size, and deeper trees keep the win
        assert r["tree1_bits_per_contribution"] \
            < r["flat_bits_per_contribution"], r
        assert r["tree2_bits_per_contribution"] \
            < r["flat_bits_per_contribution"], r
    print("OK: tree pre-reduction undercuts the flat root uplink at "
          "equal cohort size")
    _append_trajectory(rows, mode)
    yield rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="two small cells — the CI row")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="Chrome trace artifact path (default under "
                         "results/traces/)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="metrics snapshot path (default under "
                         "results/traces/)")
    args = ap.parse_args()
    list(main(quick=args.smoke, trace_out=args.trace_out,
              metrics_out=args.metrics_out))
