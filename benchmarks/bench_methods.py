"""Paper Figures 2-5: DASHA-PP vs MARINA vs FRECON in the partial
participation + compression setting.

Claims validated:
  * DASHA-PP converges faster (in communication rounds) than MARINA,
  * FRECON, lacking stochastic-gradient variance reduction, stalls at a
    less accurate solution in the stochastic setting,
  * trends hold across participation levels (10% / 50% / 90%).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (constants_of, gamma_grid_around,
                               make_paper_problem, run_method)
from repro.core import (Frecon, FreconConfig, Marina, MarinaConfig, RandK,
                        SNice, dasha_pp_mvr, dasha_pp_page, theory)


def run(rounds: int = 2000, n: int = 100, participation=(0.1, 0.5, 0.9),
        setting: str = "finite_sum", batch_size: int = 1, seed: int = 0,
        quick: bool = False):
    if quick:
        rounds, n, participation = 500, 20, (0.25, 0.75)
    prob = make_paper_problem(setting=setting, n=n, m=12 if quick else 36,
                              d=60 if quick else 300, seed=seed)
    c = constants_of(prob)
    comp = RandK(k=max(1, prob.d // 20))
    omega = comp.omega(prob.d)
    x0 = jnp.zeros(prob.d)
    key = jax.random.key(seed + 2)
    results = {}
    for frac in participation:
        s = max(1, int(round(frac * prob.n)))
        samp = SNice(n=prob.n, s=s)
        pa, paa = samp.p_a, samp.p_aa
        if setting == "finite_sum":
            hp = theory.dasha_pp_page(c, omega, pa, paa, batch_size)
            mk_dasha = lambda g, _s=samp, _h=hp: dasha_pp_page(
                prob, comp, _s, gamma=g, a=_h.a, b=_h.b, p_page=_h.p_page,
                batch_size=batch_size)
            marina_batch = batch_size   # oracle-fair: minibatch diffs
        else:
            hp = theory.dasha_pp_mvr(c, omega, pa, paa, batch_size)
            mk_dasha = lambda g, _s=samp, _h=hp: dasha_pp_mvr(
                prob, comp, _s, gamma=g, a=_h.a, b=_h.b,
                batch_size=batch_size)
            marina_batch = batch_size
        # wide coarse grids: every method reaches its own stability edge
        grid = [hp.gamma * (2.0 ** i) for i in range(0, 11, 2)]
        grid_frecon = [hp.gamma * (2.0 ** i) for i in range(-6, 5, 2)]
        p_sync = 1.0 / (1.0 + omega)
        mk_marina = lambda g, _s=samp: Marina(
            prob, comp, _s, MarinaConfig(gamma=g, p_sync=p_sync,
                                         batch_size=marina_batch))
        mk_frecon = lambda g, _s=samp: Frecon(
            prob, comp, _s, FreconConfig(gamma=g, batch_size=batch_size))

        runs = {}
        for name, mk in [("dasha-pp", mk_dasha), ("marina", mk_marina),
                         ("frecon", mk_frecon)]:
            res = run_method(mk, key, x0, rounds,
                             gamma_grid=(grid_frecon if name == "frecon"
                                         else grid),
                             n_nodes=prob.n)
            res.name = name
            runs[name] = res
        results[frac] = runs
    return dict(setting=setting, results=results)


def main(quick: bool = True):
    for setting in ("finite_sum", "stochastic"):
        out = run(setting=setting, quick=quick)
        print(f"# Figs.2-5 analogue [{setting}]")
        for frac, runs in out["results"].items():
            tail = {k: float(np.median(v.grad_norm_sq[-50:]))
                    for k, v in runs.items()}
            tloss = {k: (float(np.median(v.loss[-50:]))
                         if v.loss is not None else float("nan"))
                     for k, v in runs.items()}
            print(f"  methods,{setting},pa={frac}: " + " ".join(
                f"{k}={v:.3e}(loss={tloss[k]:.3f})"
                for k, v in tail.items()))
        yield out


if __name__ == "__main__":
    list(main(quick=False))
