"""§Perf hillclimb driver: re-lower a chosen (arch x shape) pair under a
named optimization variant and diff the roofline terms vs the baseline
artifact.

Variants are the hypothesis list of EXPERIMENTS.md §Perf; each maps to
dasha-config / arch-config overrides applied to the SAME lowering path
as the baseline sweep, so before/after numbers are apples-to-apples.

    PYTHONPATH=src python -m benchmarks.perf_iterations \
        --arch llama3-405b --shape train_4k --variant dense_psum
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json

VARIANTS = {
    # paper-faithful baseline re-run (sanity)
    "baseline": {},
    # H-agg: dense psum aggregation instead of sparse all-gather
    "dense_psum": {"dasha": {"aggregation": "dense_psum"}},
    # H-K: 4x stronger compression (K/D = 1/256, omega = 255)
    "ratio_256": {"dasha": {"compression_ratio": 1.0 / 256}},
    # H-K2: 4x weaker compression (K/D = 1/16, omega = 15)
    "ratio_16": {"dasha": {"compression_ratio": 1.0 / 16}},
    # H-full: identity compressor (uncompressed upper bound)
    "uncompressed": {"dasha": {"compression_ratio": None}},
    # H-pallas: fused control-variate kernel in the node update
    "pallas": {"dasha": {"use_pallas": True}},
    # H-remat: disable layer remat (memory<->compute trade)
    "no_remat": {"arch": {"remat": False}},
    # H-block: larger compression block (1 KiB lanes)
    "block_1024": {"dasha": {"block_size": 1024}},
    # H-pod: coarse node granularity (multi-pod only)
    "pod_client": {"dasha": {"data_axes": ("pod",)}},
    # H-fsdp: replicate params over data (federated-faithful memory
    # layout; removes the per-node-grad FSDP reshard at a params-sized
    # HBM cost)
    "no_fsdp": {"fsdp": False},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    from benchmarks.roofline import roofline_terms
    from repro.launch.dryrun import lower_pair

    ov = VARIANTS[args.variant]
    rec = lower_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                     dasha_overrides=ov.get("dasha"),
                     arch_overrides=ov.get("arch"),
                     fsdp=ov.get("fsdp", True))
    rec["variant"] = args.variant
    if rec.get("status") == "ok":
        chips = 512 if args.multi_pod else 256
        rec["roofline"] = roofline_terms(rec, chips)
    os.makedirs(args.out, exist_ok=True)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    path = os.path.join(
        args.out, f"{args.variant}__{args.arch}__{args.shape}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline", {})
    print(f"{args.arch} x {args.shape} [{args.variant}] -> "
          f"compute={r.get('compute_s', float('nan')):.4f}s "
          f"memory={r.get('memory_s', float('nan')):.4f}s "
          f"collective={r.get('collective_s', float('nan')):.4f}s "
          f"dominant={r.get('dominant')} "
          f"(compile {rec.get('compile_s')}s)")


if __name__ == "__main__":
    main()
