"""Paper Section C: the mean-estimation effect — under partial
participation the benefit of the local batch size B saturates once
B ≳ L_max^2 / (1_pa^2 L_hat^2), unlike full participation where any B
scales.

We measure the empirical variance of the distributed mean estimator
exactly as in eqs. (13)-(14): nodes hold m vectors; sample minibatches
of size B (with replacement); s-nice sample the nodes; compare the
estimator variance against the closed forms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def theoretical_variance(x: np.ndarray, B: int, s: int) -> float:
    """Eq. (14): (1/(sB)) Lmax-term + ((n-s)/(s(n-1))) Lhat-term."""
    n, m, d = x.shape
    node_means = x.mean(axis=1)                       # (n, d)
    within = ((x - node_means[:, None]) ** 2).sum(-1).mean()   # L_max^2 analogue
    grand = node_means.mean(0)
    between = ((node_means - grand) ** 2).sum(-1).mean()       # L_hat^2 analogue
    return within / (s * B) + (n - s) / (s * (n - 1)) * between


def empirical_variance(key, x: jnp.ndarray, B: int, s: int,
                       trials: int = 2000) -> float:
    n, m, d = x.shape
    grand = jnp.mean(x, axis=(0, 1))

    def one(k):
        k1, k2 = jax.random.split(k)
        perm = jax.random.permutation(k1, n)[:s]
        idx = jax.random.randint(k2, (s, B), 0, m)
        sel = x[perm[:, None], idx]                   # (s, B, d)
        est = jnp.mean(sel, axis=(0, 1))
        return jnp.sum((est - grand) ** 2)

    keys = jax.random.split(key, trials)
    return float(jnp.mean(jax.vmap(one)(keys)))


def run(n: int = 40, m: int = 64, d: int = 30, s: int = 10,
        B_values=(1, 2, 4, 8, 16, 32, 64), seed: int = 0,
        quick: bool = False):
    if quick:
        n, m, trials = 20, 32, 400
        B_values = (1, 4, 16, 32)
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    # heterogeneous node means so the between-node term dominates at large B
    node_mu = 2.0 * jax.random.normal(k1, (n, 1, d))
    x = node_mu + jax.random.normal(k2, (n, m, d))
    rows = []
    for B in B_values:
        emp = empirical_variance(jax.random.key(seed + B), x, B, s,
                                 trials=400 if quick else 2000)
        theo = theoretical_variance(np.asarray(x), B, s)
        rows.append(dict(B=B, empirical=emp, theoretical=float(theo)))
    # the floor: between-node term that B cannot reduce
    floor = rows[-1]["theoretical"] - 0  # large-B limit approximates it
    return rows


def main(quick: bool = True):
    rows = run(quick=quick)
    print("# Section C analogue: estimator variance vs batch size B")
    for r in rows:
        print(f"  batch_effect,B={r['B']},empirical={r['empirical']:.4f},"
              f"theory={r['theoretical']:.4f}")
    yield rows


if __name__ == "__main__":
    list(main(quick=False))
