"""Property tests for the hierarchical fleet (fl/tree.py), via the
hypothesis shim in tests/_hypo.py (real hypothesis when installed, a
seeded deterministic fallback otherwise):

(a) the wire-bits ledger balances — the cumulative ``bits_cum`` metric
    equals the sum of per-hop totals, which themselves equal the
    arrival-counted client uplinks and the per-tier message logs;
(b) staleness composes across hops — every commit record's staleness
    telescopes through its hop stamps to commit minus dispatch round;
(c) edge pre-reduction is associative — with a lossless schedule (zero
    jitter, barrier buffers) a tree commits the same contribution
    multiset as the flat topology and lands on the same estimator up
    to float64 summation order.
"""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st
from repro.core import (LogisticSigmoidProblem, RandK, SNice,
                        make_synthetic_classification)
from repro.core.dasha_pp import DashaPPConfig
from repro.fl import (ConstantLatency, DenseProblemWorkload, FleetConfig,
                      HierarchicalFleet, LognormalLatency, TierConfig,
                      compose_hops)

N, M, D = 6, 5, 16


@pytest.fixture(scope="module")
def workload():
    feats, y = make_synthetic_classification(jax.random.key(0),
                                             n_nodes=N, m_per_node=M, d=D)
    problem = LogisticSigmoidProblem(feats, y)
    return DenseProblemWorkload(
        problem, RandK(k=4), SNice(n=N, s=3),
        DashaPPConfig("gradient", gamma=0.02, a=0.1, b=0.3,
                      batch_size=2))


def _fcfg(depth, edge_k, root_k, max_st=None):
    tiers = ()
    if depth >= 1:
        tiers += (TierConfig(aggregators=2, buffer_size=edge_k),)
    if depth >= 2:
        tiers += (TierConfig(aggregators=2, buffer_size=edge_k),)
    return FleetConfig(tiers=tiers, buffer_size=root_k,
                       max_staleness=max_st)


def _run(workload, fcfg, latency, seed, rounds=6):
    fleet = HierarchicalFleet(workload, fcfg, latency)
    return fleet.run(jax.random.key(seed), jnp.zeros(D), rounds)


# ----------------------------------------------------------------------
# (a) the wire-bits ledger balances
# ----------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(depth=st.integers(0, 2),
       edge_k=st.sampled_from([None, 1, 2]),
       root_k=st.sampled_from([None, 1, 2]),
       dropout=st.sampled_from([0.0, 0.3]),
       seed=st.integers(0, 5))
def test_wire_bits_ledger(workload, depth, edge_k, root_k, dropout, seed):
    fcfg = _fcfg(depth, edge_k, root_k, max_st=4)
    lat = LognormalLatency(compute_s=1.0, sigma=0.7, client_sigma=0.7,
                           dropout=dropout, seed=seed)
    _, res = _run(workload, fcfg, lat, seed)
    assert len(res.tier_bits) == depth + 1
    # the headline metric is exactly the sum of the per-hop ledgers
    assert res.bits_cum[-1] == pytest.approx(res.tier_bits.sum(),
                                             rel=1e-9)
    # hop 0: one client uplink per delivered ARRIVAL event
    n_arrivals = sum(1 for e in res.event_log if e[2] == "arrival")
    assert res.tier_bits[0] == pytest.approx(
        n_arrivals * workload.wire_bits, rel=1e-9)
    # hop k+1: the sum of tier-k flush messages, as logged on the wire
    for k in range(depth):
        logged = sum(m.bits for m in res.message_log if m.tier == k)
        assert res.tier_bits[k + 1] == pytest.approx(logged, rel=1e-9)
    # the root hop is what root_bits_cum tracks
    assert res.root_bits_cum[-1] == pytest.approx(res.tier_bits[-1],
                                                  rel=1e-9)


# ----------------------------------------------------------------------
# (b) staleness composes across hops
# ----------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(depth=st.integers(0, 2),
       edge_k=st.sampled_from([None, 1, 3]),
       root_k=st.sampled_from([None, 1, 2]),
       seed=st.integers(0, 5))
def test_staleness_composes_across_hops(workload, depth, edge_k, root_k,
                                        seed):
    fcfg = _fcfg(depth, edge_k, root_k)
    lat = LognormalLatency(compute_s=1.0, sigma=1.0, client_sigma=1.0,
                           seed=seed)
    _, res = _run(workload, fcfg, lat, seed)
    assert res.commit_log
    for rec in res.commit_log:
        assert len(rec.hops) == depth
        total, increments = compose_hops(
            rec.dispatch_round, [r for _, r in rec.hops],
            rec.commit_round)
        assert total == rec.staleness \
            == rec.commit_round - rec.dispatch_round
        assert sum(increments) == total
        assert all(i >= 0 for i in increments)
        assert [k for k, _ in rec.hops] == list(range(depth))
    assert Counter(r.staleness for r in res.commit_log) \
        == res.staleness_hist


def test_compose_hops_rejects_time_travel():
    with pytest.raises(ValueError):
        compose_hops(3, [2], 5)
    total, inc = compose_hops(1, [2, 4], 7)
    assert total == 6 and inc == (1, 2, 3)


# ----------------------------------------------------------------------
# (c) edge pre-reduction is associative
# ----------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(depth=st.integers(1, 2), seed=st.integers(0, 5))
def test_pre_reduction_is_associative(workload, depth, seed):
    """Zero jitter + barrier buffers: the tree and the flat topology
    dispatch identical cohorts, commit the identical (client, round)
    multiset, and agree on g to float64 summation order — pre-reduction
    reorders the sum, it never changes it."""
    lat = ConstantLatency(compute_s=1.0)
    fs_tree, r_tree = _run(workload, _fcfg(depth, None, None), lat, seed)
    fs_flat, r_flat = _run(workload, _fcfg(0, None, None), lat, seed)
    assert Counter((r.client, r.dispatch_round)
                   for r in r_tree.commit_log) \
        == Counter((r.client, r.dispatch_round)
                   for r in r_flat.commit_log)
    np.testing.assert_allclose(fs_tree.g, fs_flat.g,
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(fs_tree.x, fs_flat.x, rtol=0, atol=0)
    np.testing.assert_array_equal(fs_tree.store.dense("g_i"),
                                  fs_flat.store.dense("g_i"))
