"""Algorithm-level tests for the DASHA-PP family: convergence with
theory hyperparameters, reduction identities, baseline equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FullParticipation, Identity, QuadraticProblem, RandK,
                        SNice, dasha, dasha_pp, dasha_pp_finite_mvr,
                        dasha_pp_mvr, dasha_pp_page, theory)


def _constants(prob):
    L, L_hat, L_max, L_sigma = prob.smoothness()
    return theory.ProblemConstants(L=L, L_hat=L_hat, L_max=L_max,
                                   L_sigma=L_sigma, n=prob.n, m=prob.m,
                                   d=prob.d)


@pytest.fixture(scope="module")
def quad():
    return QuadraticProblem.random(jax.random.key(0), n=8, d=12, cond=5.0)


def test_dasha_pp_gradient_converges_theory_params(quad):
    """Theorem 2 end-to-end: gnorm -> ~0 with the exact (a, b, gamma)."""
    c = _constants(quad)
    comp = RandK(k=3)
    samp = SNice(n=quad.n, s=3)
    hp = theory.dasha_pp_gradient(c, comp.omega(quad.d), samp.p_a, samp.p_aa)
    alg = dasha_pp(quad, comp, samp, gamma=hp.gamma, a=hp.a, b=hp.b)
    x0 = jnp.zeros(quad.d)
    _, mets = jax.jit(lambda k: alg.run(k, x0, 4000))(jax.random.key(1))
    g = np.asarray(mets.grad_norm_sq)
    assert np.all(np.isfinite(g))
    assert g[-1] < 1e-4 * g[0], (g[0], g[-1])


@pytest.mark.parametrize("variant", ["page", "finite_mvr", "mvr"])
def test_variants_converge(small_problem, variant):
    prob = small_problem
    c = _constants(prob)
    comp = RandK(k=max(1, prob.d // 8))
    samp = SNice(n=prob.n, s=4)
    omega = comp.omega(prob.d)
    B = 2
    if variant == "page":
        hp = theory.dasha_pp_page(c, omega, samp.p_a, samp.p_aa, B)
        alg = dasha_pp_page(prob, comp, samp, gamma=hp.gamma * 64, a=hp.a,
                            b=hp.b, p_page=hp.p_page, batch_size=B)
    elif variant == "finite_mvr":
        hp = theory.dasha_pp_finite_mvr(c, omega, samp.p_a, samp.p_aa, B)
        alg = dasha_pp_finite_mvr(prob, comp, samp, gamma=hp.gamma * 64,
                                  a=hp.a, b=hp.b, batch_size=B)
    else:
        hp = theory.dasha_pp_mvr(c, omega, samp.p_a, samp.p_aa, B)
        alg = dasha_pp_mvr(prob, comp, samp, gamma=hp.gamma * 64, a=hp.a,
                           b=hp.b, batch_size=B)
    x0 = jnp.zeros(prob.d)
    _, mets = jax.jit(lambda k: alg.run(k, x0, 1500))(jax.random.key(2))
    g = np.asarray(mets.grad_norm_sq)
    assert np.all(np.isfinite(g))
    assert np.median(g[-100:]) < 0.05 * g[0], (g[0], np.median(g[-100:]))


def test_full_participation_reduces_to_dasha(quad):
    """With p_a = 1 and identity compressor + b=1, DASHA-PP produces the
    exact gradient-descent trajectory of DASHA (which itself reduces to
    GD when C = I)."""
    comp = Identity()
    gamma = 0.05
    alg_pp = dasha_pp(quad, comp, FullParticipation(n=quad.n),
                      gamma=gamma, a=1.0, b=1.0)
    alg_da = dasha(quad, comp, gamma=gamma, a=1.0)
    x0 = jnp.ones(quad.d)
    st_pp, _ = jax.jit(lambda k: alg_pp.run(k, x0, 50))(jax.random.key(0))
    st_da, _ = jax.jit(lambda k: alg_da.run(k, x0, 50))(jax.random.key(5))
    np.testing.assert_allclose(np.asarray(st_pp.x), np.asarray(st_da.x),
                               rtol=1e-5)
    # and both equal plain GD
    x = x0
    for _ in range(50):
        x = x - gamma * quad.full_grad(x)
    np.testing.assert_allclose(np.asarray(st_pp.x), np.asarray(x), rtol=1e-4)


def test_nonparticipating_state_frozen(quad):
    """Nodes outside S keep h_i, g_i exactly (Alg. 1 lines 15-17)."""
    comp = RandK(k=4)
    samp = SNice(n=quad.n, s=2)
    alg = dasha_pp(quad, comp, samp, gamma=0.01, a=0.1, b=0.3)
    st = alg.init(jax.random.key(0), jnp.zeros(quad.d))
    key = jax.random.key(7)
    st2, _ = jax.jit(alg.step)(key, st)
    # recompute the mask the step used
    k_part, _, _ = jax.random.split(key, 3)
    mask = np.asarray(samp.sample(k_part))
    h_same = np.asarray(jnp.all(st2.h_i == st.h_i, axis=1))
    g_same = np.asarray(jnp.all(st2.g_i == st.g_i, axis=1))
    assert np.all(h_same[~mask]) and np.all(g_same[~mask])
    assert np.all(~h_same[mask])   # participants moved


def test_metrics_accounting(quad):
    comp = RandK(k=4)
    samp = SNice(n=quad.n, s=3)
    alg = dasha_pp(quad, comp, samp, gamma=0.01, a=0.1, b=0.3)
    st = alg.init(jax.random.key(0), jnp.zeros(quad.d))
    _, met = jax.jit(alg.step)(jax.random.key(1), st)
    assert int(met.participants) == 3
    assert float(met.bits_sent) == 3 * comp.wire_bits(quad.d)


@pytest.mark.parametrize("variant",
                         ["gradient", "page", "finite_mvr", "mvr"])
def test_pallas_path_matches_reference_trajectory(small_problem, quad,
                                                  variant):
    """use_pallas=True must reproduce the unfused trajectory (x, g, h_i)
    for every k_i rule — the fused kernels consume randomness exactly
    like the jnp chain, so 30 jitted rounds stay allclose."""
    prob = quad if variant == "gradient" else small_problem
    comp = RandK(k=4)
    samp = SNice(n=prob.n, s=max(2, prob.n // 2))

    def make(use_pallas):
        kw = dict(gamma=0.01, a=0.1, b=0.3, use_pallas=use_pallas)
        if variant == "gradient":
            return dasha_pp(prob, comp, samp, **kw)
        if variant == "page":
            return dasha_pp_page(prob, comp, samp, p_page=0.3,
                                 batch_size=2, **kw)
        if variant == "finite_mvr":
            return dasha_pp_finite_mvr(prob, comp, samp, batch_size=2, **kw)
        return dasha_pp_mvr(prob, comp, samp, batch_size=2, **kw)

    x0 = jnp.zeros(prob.d)
    st_ref, met_ref = jax.jit(lambda k: make(False).run(k, x0, 30))(
        jax.random.key(1))
    st_pal, met_pal = jax.jit(lambda k: make(True).run(k, x0, 30))(
        jax.random.key(1))
    for a, b in [(st_ref.x, st_pal.x), (st_ref.g, st_pal.g),
                 (st_ref.h_i, st_pal.h_i), (st_ref.g_i, st_pal.g_i)]:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(met_ref.grad_norm_sq),
                               np.asarray(met_pal.grad_norm_sq),
                               rtol=1e-4)


def test_theory_gamma_positive_and_monotone():
    c = theory.ProblemConstants(L=1.0, L_hat=1.5, L_max=3.0, L_sigma=3.0,
                                n=16, m=64, d=100)
    for omega in (0.0, 3.0, 63.0):
        hps = [theory.dasha_pp_gradient(c, omega, pa, pa * pa)
               for pa in (1.0, 0.5, 0.1)]
        gammas = [h.gamma for h in hps]
        assert all(g > 0 for g in gammas)
        # smaller p_a -> smaller admissible stepsize
        assert gammas[0] >= gammas[1] >= gammas[2]
        for h, pa in zip(hps, (1.0, 0.5, 0.1)):
            assert np.isclose(h.a, pa / (2 * omega + 1))
            assert np.isclose(h.b, pa / (2 - pa))
