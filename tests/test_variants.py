"""The variant-rule layer (core/variants.py): registry contents, pure
k_i formulas, oracle/uplink accounting, the shared randomness contract,
and sampler parity between the leaf-level ``participates`` (sharded
engine) and the reference samplers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import variants
from repro.core.compressors import BlockRandK
from repro.core.participation import (FullParticipation, Independent,
                                      SNice, participates, snice_size)

# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------


def test_registry_contents():
    assert sorted(variants.VARIANTS) == ["finite_mvr", "gradient", "mvr",
                                         "page"]
    assert sorted(variants.BASELINES) == ["frecon", "marina"]
    page = variants.get_rule("page")
    assert page.needs_coin and page.needs_minibatch
    fin = variants.get_rule("finite_mvr")
    assert fin.component_trackers and fin.trainer_supported
    for name in ("gradient", "mvr"):
        r = variants.get_rule(name)
        assert not (r.needs_coin or r.component_trackers)
        assert r.trainer_supported
    # every rule documents its oracle and paper algorithm
    for r in list(variants.VARIANTS.values()) + \
            list(variants.BASELINES.values()):
        assert r.oracle and r.algorithm
    with pytest.raises(ValueError):
        variants.get_rule("nope")
    with pytest.raises(ValueError):
        variants.get_baseline("gradient")   # not a baseline


def test_engine_configs_reject_unknown_variant():
    from repro.core.dasha_pp import DashaPPConfig
    from repro.core.sharded import ShardedDashaConfig
    with pytest.raises(ValueError):
        DashaPPConfig("bogus", gamma=0.1, a=0.1, b=0.1)
    with pytest.raises(ValueError):
        ShardedDashaConfig(gamma=0.1, a=0.1, b=0.1, variant="bogus")


# ----------------------------------------------------------------------
# Pure formulas
# ----------------------------------------------------------------------


def test_k_formulas_shape_polymorphic():
    """The same leaf function serves node-major (n, d) and flat (D,)."""
    key = jax.random.key(0)
    gn, go, h = (jax.random.normal(jax.random.fold_in(key, i), (3, 8))
                 for i in range(3))
    k2 = variants.k_same_sample(gn, go, h, b=0.3)
    k1 = variants.k_same_sample(gn[0], go[0], h[0], b=0.3)
    np.testing.assert_allclose(np.asarray(k2[0]), np.asarray(k1))
    np.testing.assert_allclose(
        np.asarray(k2), np.asarray(gn - go - 0.3 * (h - go)))


@pytest.mark.parametrize("coin", [0, 1])
def test_k_page_branches(coin):
    key = jax.random.key(1)
    gn, go, bn, bo, h = (jax.random.normal(jax.random.fold_in(key, i),
                                           (8,)) for i in range(5))
    k = variants.k_page(gn, go, bn, bo, h, jnp.asarray(bool(coin)),
                        b=0.3, p_page=0.25)
    want = (gn - go - (0.3 / 0.25) * (h - go)) if coin else (bn - bo)
    np.testing.assert_allclose(np.asarray(k), np.asarray(want),
                               rtol=1e-6)


def test_k_finite_mvr_scatter():
    """Selected components get the scaled update, others exactly zero."""
    m, B, d = 6, 2, 4
    key = jax.random.key(2)
    gn, go, h = (jax.random.normal(jax.random.fold_in(key, i), (B, d))
                 for i in range(3))
    idx = jnp.asarray([1, 4])
    k_ij = variants.k_finite_mvr_components(gn, go, h, idx, m, b=0.3)
    assert k_ij.shape == (m, d)
    want_sel = (m / B) * (gn - go - 0.3 * (h - go))
    np.testing.assert_allclose(np.asarray(k_ij[idx]),
                               np.asarray(want_sel), rtol=1e-6)
    others = np.delete(np.asarray(k_ij), np.asarray(idx), axis=0)
    assert (others == 0).all()


def test_control_variate_tail_masking():
    key = jax.random.key(3)
    k, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (8,))
                for i in range(3))
    h_new, payload = variants.control_variate_tail(
        k, h, gi, a=0.1, pa=0.5, part=jnp.asarray(0.0))
    np.testing.assert_array_equal(np.asarray(h_new), np.asarray(h))
    np.testing.assert_allclose(
        np.asarray(payload),
        np.asarray(k / 0.5 - (0.1 / 0.5) * (gi - h)), rtol=1e-6)


# ----------------------------------------------------------------------
# Accounting
# ----------------------------------------------------------------------


def test_oracle_call_accounting():
    n, m, B = 10, 32, 4
    assert int(variants.get_rule("gradient").oracle_calls(n, m)) \
        == 2 * m * n
    assert int(variants.get_rule("mvr").oracle_calls(n, m, B)) == 2 * B * n
    assert int(variants.get_rule("finite_mvr").oracle_calls(n, m, B)) \
        == 2 * B * n
    page = variants.get_rule("page")
    assert int(page.oracle_calls(n, m, B, coin=jnp.asarray(True))) \
        == 2 * m * n
    assert int(page.oracle_calls(n, m, B, coin=jnp.asarray(False))) \
        == 2 * B * n
    marina = variants.get_baseline("marina")
    assert int(marina.oracle_calls(n, m)) == 2 * m * n
    assert int(marina.oracle_calls(n, m, B, coin=jnp.asarray(True))) \
        == m * n + B * n
    frecon = variants.get_baseline("frecon")
    assert int(frecon.oracle_calls(n, m, B)) == B * n
    assert int(frecon.oracle_calls(n, m)) == m * n


def test_uplink_bits_aggregation_aware():
    """dense_psum moves dense messages regardless of the ratio; only
    sparse_allgather gets the compressed wire."""
    d, bs, ratio, pa = 10_000, 128, 1 / 64, 0.5
    dense = variants.uplink_bits_per_node(
        d, aggregation="dense_psum", compression_ratio=ratio,
        block_size=bs, p_a=pa)
    ident = variants.uplink_bits_per_node(
        d, aggregation="sparse_allgather", compression_ratio=None,
        block_size=bs, p_a=pa)
    sparse = variants.uplink_bits_per_node(
        d, aggregation="sparse_allgather", compression_ratio=ratio,
        block_size=bs, p_a=pa)
    assert dense == ident == pa * d * 32.0
    _, nb, kb = variants.block_plan(d, bs, ratio)
    assert sparse == pa * kb * (bs * 32.0 + 32.0)
    assert sparse < dense / 10


def test_sharded_engine_uplink_accounting():
    """ShardedDasha.uplink_bits_per_round delegates to the rule layer
    (the dense_psum bug: it used to report compressed bits there)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh
    from repro.core.sharded import ShardedDasha, ShardedDashaConfig
    mesh = make_mesh((1,), ("data",))
    base = dict(gamma=0.1, a=0.1, b=0.1, p_a=0.5, compression_ratio=1 / 64,
                block_size=128, data_axes=("data",))
    sparse = ShardedDasha(mesh, {"w": P()}, ShardedDashaConfig(
        aggregation="sparse_allgather", **base))
    dense = ShardedDasha(mesh, {"w": P()}, ShardedDashaConfig(
        aggregation="dense_psum", **base))
    d = 100_000
    assert dense.uplink_bits_per_round(d) == 0.5 * d * 32.0
    assert sparse.uplink_bits_per_round(d) < \
        dense.uplink_bits_per_round(d) / 10


# ----------------------------------------------------------------------
# Sampler parity (sharded `participates` vs reference samplers)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("kind,samp", [
    ("independent", Independent(n=12, p=0.3)),
    ("s_nice", SNice(n=12, s=4)),
    ("full", FullParticipation(n=12)),
])
def test_participates_matches_sampler_exactly(kind, samp):
    """The leaf-level draw the sharded engine uses IS the reference
    sampler's mask coordinate — bitwise, for every key."""
    n = samp.n
    for seed in range(20):
        key = jax.random.key(seed)
        mask_ref = np.asarray(samp.sample(key))
        mask_leaf = np.asarray(jax.vmap(
            lambda i: participates(kind, key, i, n, samp.p_a)
        )(jnp.arange(n)))
        np.testing.assert_array_equal(mask_ref, mask_leaf)


def test_participates_snice_exactly_s():
    n, pa = 12, 1 / 3
    s = snice_size(pa, n)
    assert s == 4
    for seed in range(30):
        mask = jax.vmap(
            lambda i: participates("s_nice", jax.random.key(seed), i, n,
                                   pa))(jnp.arange(n))
        assert int(jnp.sum(mask)) == s


def test_participates_independent_rate():
    n, pa, trials = 12, 0.3, 2000
    keys = jax.random.split(jax.random.key(0), trials)
    masks = jax.vmap(lambda k: jax.vmap(
        lambda i: participates("independent", k, i, n, pa)
    )(jnp.arange(n)))(keys)
    p_hat = np.asarray(jnp.mean(masks.astype(jnp.float32), axis=0))
    np.testing.assert_allclose(p_hat, pa, atol=0.05)


def test_participates_unknown_sampler():
    with pytest.raises(ValueError):
        participates("bogus", jax.random.key(0), 0, 4, 0.5)


# ----------------------------------------------------------------------
# BlockRandK reference compressor (the sharded wire, dense form)
# ----------------------------------------------------------------------


def test_block_randk_compressor_unbiased_and_bounded():
    d, bs, ratio = 256, 8, 0.25
    comp = BlockRandK(ratio=ratio, block_size=bs)
    x = jax.random.normal(jax.random.key(0), (d,))
    keys = jax.random.split(jax.random.key(1), 800)
    outs = jax.vmap(lambda k: comp.compress(k, x))(keys)
    mean = np.asarray(jnp.mean(outs, axis=0))
    rel = np.linalg.norm(mean - np.asarray(x)) / np.linalg.norm(x)
    assert rel < 0.15, rel
    # Definition-1 variance bound with omega = nb/kb - 1
    omega = comp.omega(d)
    var = float(jnp.mean(jnp.sum((outs - x) ** 2, axis=-1)))
    assert var <= 1.05 * omega * float(jnp.sum(x ** 2))
    # wire format: kb blocks of bs values + kb indices
    _, nb, kb = variants.block_plan(d, bs, ratio)
    assert comp.wire_bits(d) == kb * (bs * 32.0 + 32.0)
    vals, idx = comp.compress_sparse(jax.random.key(2), x)
    assert vals.shape == (kb, bs) and idx.shape == (kb,)


def test_block_randk_compressor_matches_engine_wire():
    """compress() is exactly the sharded engine's dense BlockRandK for
    the same key — the basis of reference<->sharded parity."""
    d, bs, ratio = 100, 8, 0.25     # ragged last block
    comp = BlockRandK(ratio=ratio, block_size=bs)
    x = jax.random.normal(jax.random.key(3), (d,))
    key = jax.random.key(4)
    _, nb, kb = variants.block_plan(d, bs, ratio)
    want = variants.block_randk_dense(key, x, kb, bs)
    np.testing.assert_array_equal(np.asarray(comp.compress(key, x)),
                                  np.asarray(want))


# ----------------------------------------------------------------------
# Randomness contract
# ----------------------------------------------------------------------


def test_round_keys_step_fold():
    """round_keys(key, step) == round_keys(fold_in(key, step)) — the
    sharded engine (run key + step) and the reference engine (per-round
    key) derive identical (k_part, k_oracle, k_comp)."""
    key = jax.random.key(7)
    a = variants.round_keys(key, jnp.asarray(3))
    b = variants.round_keys(jax.random.fold_in(key, 3))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(jax.random.key_data(x),
                                      jax.random.key_data(y))
