"""The hierarchical edge-aggregator fleet (repro/fl/tree.py, DESIGN.md
§12): depth-1 sync-limit parity for all four variants (pallas on/off),
the out-of-core client store, edge-partitioned participation, mid-flight
dropout/rejoin at the tree runtime, forced-flush progress, and the
depth-2 memmap smoke at fleet scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LogisticSigmoidProblem, RandK, SNice,
                        make_synthetic_classification)
from repro.core.dasha_pp import DashaPP, DashaPPConfig
from repro.core.participation import EdgeSNice
from repro.fl import (ClientStore, ConstantLatency, DenseProblemWorkload,
                      FleetConfig, HierarchicalFleet, LatencyModel,
                      LognormalLatency, StreamedGradientWorkload,
                      TierConfig, edge_partition)

N, M, D = 6, 5, 16


@pytest.fixture(scope="module")
def fleet_problem():
    feats, y = make_synthetic_classification(jax.random.key(0),
                                             n_nodes=N, m_per_node=M, d=D)
    return LogisticSigmoidProblem(feats, y)


def _cfg(variant, use_pallas=False):
    return DashaPPConfig(variant, gamma=0.02, a=0.1, b=0.3, p_page=0.4,
                         batch_size=2, use_pallas=use_pallas)


def _fleet(prob, cfg, fcfg, latency, rounds=6, key=7, **kw):
    wl = DenseProblemWorkload(prob, RandK(k=4), SNice(n=N, s=3), cfg)
    fleet = HierarchicalFleet(wl, fcfg, latency, **kw)
    return fleet.run(jax.random.key(key), jnp.zeros(D), rounds)


# ----------------------------------------------------------------------
# The parity anchor: depth-1 zero-jitter tree == sync DashaPP
# ----------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True], ids=["jnp", "pallas"])
@pytest.mark.parametrize("variant",
                         ["gradient", "page", "finite_mvr", "mvr"])
def test_depth1_tree_sync_limit_parity(fleet_problem, variant, use_pallas):
    """A depth-1 tree with zero jitter and barrier buffers everywhere
    reproduces the synchronous DashaPP trajectory allclose (x, g, g_i,
    h_i, and h_ij for finite_mvr) — the fleet is an anchored
    generalization of the reference engine, through the same
    dispatch."""
    cfg = _cfg(variant, use_pallas)
    alg = DashaPP(fleet_problem, RandK(k=4), SNice(n=N, s=3), cfg)
    st_sync = jax.jit(lambda k: alg.run(k, jnp.zeros(D), 6))(
        jax.random.key(7))[0]

    fs, res = _fleet(fleet_problem, cfg,
                     FleetConfig(tiers=(TierConfig(aggregators=2),)),
                     ConstantLatency(compute_s=1.0))
    pairs = [("x", fs.x, st_sync.x), ("g", fs.g, st_sync.g),
             ("g_i", fs.store.dense("g_i"), st_sync.g_i),
             ("h_i", fs.store.dense("h_i"), st_sync.h_i)]
    if variant == "finite_mvr":
        pairs.append(("h_ij", fs.store.dense("h_ij"), st_sync.h_ij))
    for name, a, b in pairs:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6, err_msg=name)
    assert set(res.staleness_hist) <= {0}
    assert res.dropped == 0 and res.discarded_stale == 0
    assert len(res.message_log) > 0      # contributions went via edges


def test_depth0_flat_topology_runs(fleet_problem):
    """tiers=() feeds clients straight to the root (the flat
    semantics): zero jitter + barrier still reproduces sync, and the
    only hop's bits are the client uplinks."""
    cfg = _cfg("mvr")
    alg = DashaPP(fleet_problem, RandK(k=4), SNice(n=N, s=3), cfg)
    st_sync = jax.jit(lambda k: alg.run(k, jnp.zeros(D), 6))(
        jax.random.key(7))[0]
    fs, res = _fleet(fleet_problem, cfg, FleetConfig(),
                     ConstantLatency(compute_s=1.0))
    np.testing.assert_allclose(fs.x, np.asarray(st_sync.x),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(fs.store.dense("h_i"),
                               np.asarray(st_sync.h_i),
                               rtol=1e-4, atol=1e-6)
    assert len(res.tier_bits) == 1
    assert res.tier_bits[0] == res.root_bits_cum[-1]
    assert len(res.message_log) == 0


# ----------------------------------------------------------------------
# Out-of-core client store
# ----------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ram", "memmap"])
def test_client_store_gather_scatter(backend):
    rng = np.random.default_rng(0)
    bounds = edge_partition(10, 3)
    store = ClientStore(bounds, {"a": (4,), "b": (2, 3)}, backend=backend)
    assert store.n == 10 and store.num_edges == 3
    va = rng.standard_normal((10, 4)).astype(np.float32)
    store.scatter_set("a", np.arange(10), va)
    idx = np.asarray([9, 0, 4, 7])        # crosses every chunk, unsorted
    np.testing.assert_array_equal(store.gather("a", idx), va[idx])
    store.scatter_add("a", idx, np.ones((4, 4), np.float32))
    va[idx] += 1.0
    np.testing.assert_array_equal(store.dense("a"), va)
    assert store.gather("b", [3]).shape == (1, 2, 3)
    np.testing.assert_array_equal(store.edge_of([0, 3, 4, 9]),
                                  [0, 0, 1, 2])
    with pytest.raises(IndexError):
        store.gather("a", [10])
    store.flush()
    store.close()


def test_client_store_backend_equivalence(tmp_path):
    """ram and memmap backends are interchangeable bit-for-bit."""
    bounds = edge_partition(17, 4)
    rng = np.random.default_rng(1)
    stores = [ClientStore(bounds, {"h": (5,)}, backend="ram"),
              ClientStore(bounds, {"h": (5,)}, backend="memmap",
                          directory=str(tmp_path))]
    for _ in range(5):
        idx = rng.choice(17, size=6, replace=False)
        vals = rng.standard_normal((6, 5)).astype(np.float32)
        for s in stores:
            s.scatter_add("h", idx, vals)
    np.testing.assert_array_equal(stores[0].dense("h"),
                                  stores[1].dense("h"))
    assert stores[1].nbytes == 17 * 5 * 4


def test_edge_partition_and_sampler():
    bounds = edge_partition(10, 3)
    np.testing.assert_array_equal(bounds, [0, 4, 7, 10])
    with pytest.raises(ValueError):
        edge_partition(2, 3)

    samp = EdgeSNice(bounds=(0, 5, 10, 15), s=2)
    assert samp.n == 15 and samp.num_edges == 3
    assert samp.p_a == pytest.approx(6 / 15)
    assert samp.p_aa == pytest.approx((2 / 5) ** 2)
    assert 0.0 <= samp.one_pa <= 1.0
    m1 = samp.sample(jax.random.key(3))
    m2 = samp.sample(jax.random.key(3))
    np.testing.assert_array_equal(m1, m2)           # deterministic in key
    for e in range(3):
        assert m1[5 * e:5 * (e + 1)].sum() == 2     # exactly s per edge
    assert not np.array_equal(m1, samp.sample(jax.random.key(4)))
    with pytest.raises(ValueError):
        EdgeSNice(bounds=(0, 2, 4), s=3)


# ----------------------------------------------------------------------
# Mid-flight dropout / rejoin at the tree runtime
# ----------------------------------------------------------------------

def test_fleet_total_dropout_no_leak_no_freeze(fleet_problem):
    """dropout=1.0: every contribution is lost mid-flight.  g and the
    store must stay EXACTLY at init (nothing leaks), the clock must
    keep advancing (no freeze), and rejoins must re-enter clients into
    later cohorts."""
    cfg = _cfg("gradient")
    eng = DashaPP(fleet_problem, RandK(k=4), SNice(n=N, s=3), cfg)
    st0 = eng.init(jax.random.split(jax.random.key(7))[0], jnp.zeros(D))
    fs, res = _fleet(fleet_problem, cfg,
                     FleetConfig(tiers=(TierConfig(aggregators=2),)),
                     ConstantLatency(compute_s=1.0, dropout=1.0,
                                     rejoin_s=2.0), rounds=8)
    np.testing.assert_array_equal(fs.g, np.asarray(st0.g, np.float64))
    np.testing.assert_array_equal(fs.store.dense("g_i"),
                                  np.asarray(st0.g_i))
    np.testing.assert_array_equal(fs.store.dense("h_i"),
                                  np.asarray(st0.h_i))
    assert res.committed.sum() == 0
    assert res.dropped == int(res.participants.sum()) > 0
    assert res.total_time > 0.0
    assert (res.participants > 0).sum() > 1     # rejoins re-dispatched
    assert any(e[2] == "rejoin" for e in res.event_log)
    # x still walked: the broadcast happens regardless of commits
    assert np.any(fs.x != 0.0)


def test_fleet_partial_dropout_conservation_and_replay(fleet_problem):
    """Every dispatched contribution commits, drops, or is discarded —
    nothing is lost or double-counted — and the same seed replays the
    identical event log and final iterate."""
    cfg = _cfg("mvr")
    fcfg = FleetConfig(tiers=(TierConfig(aggregators=2, buffer_size=2),),
                       buffer_size=2, max_staleness=3)
    lat = LognormalLatency(compute_s=1.0, sigma=1.0, client_sigma=1.0,
                           dropout=0.3, seed=11)
    fs1, r1 = _fleet(fleet_problem, cfg, fcfg, lat, rounds=10)
    fs2, r2 = _fleet(fleet_problem, cfg, fcfg, lat, rounds=10)
    assert r1.dropped > 0
    total = int(r1.participants.sum())
    assert int(r1.committed.sum()) + r1.dropped + r1.discarded_stale \
        == total
    assert r1.event_log == r2.event_log and len(r1.event_log) > 0
    np.testing.assert_array_equal(fs1.x, fs2.x)
    np.testing.assert_array_equal(fs1.g, fs2.g)
    assert np.all(np.isfinite(r1.loss))


@dataclasses.dataclass(frozen=True)
class OneSlowClient(LatencyModel):
    """Client ``slow_client`` takes ``slow_s`` to compute; everyone
    else is the zero-jitter constant — a deterministic straggler."""
    slow_client: int = 0
    slow_s: float = 100.0

    def _compute(self, client, rng):
        return self.slow_s if client == self.slow_client \
            else self.compute_s


def test_edge_discard_is_whole(fleet_problem):
    """A contribution discarded for staleness AT ITS OWN EDGE is
    discarded whole: no h_i write, no g_i write — the straggler's rows
    still equal their init values after the run."""
    cfg = _cfg("gradient")
    eng = DashaPP(fleet_problem, RandK(k=4), SNice(n=N, s=N), cfg)
    st0 = eng.init(jax.random.split(jax.random.key(7))[0], jnp.zeros(D))
    # Full participation makes the schedule deterministic: client 0 is
    # dispatched at round 0, stays busy until its arrival at t=100 —
    # during the drain, far past the tier's staleness bound — and is
    # never re-dispatched (no dispatches during drain).
    wl = DenseProblemWorkload(fleet_problem, RandK(k=4),
                              SNice(n=N, s=N), cfg)
    fleet = HierarchicalFleet(
        wl,
        FleetConfig(tiers=(TierConfig(aggregators=2, buffer_size=1,
                                      max_staleness=2),),
                    buffer_size=3),
        OneSlowClient(compute_s=1.0, slow_client=0, slow_s=100.0))
    fs, res = fleet.run(jax.random.key(7), jnp.zeros(D), 5)
    assert res.discarded_stale >= 1
    np.testing.assert_array_equal(fs.store.gather("h_i", [0])[0],
                                  np.asarray(st0.h_i)[0])
    np.testing.assert_array_equal(fs.store.gather("g_i", [0])[0],
                                  np.asarray(st0.g_i)[0])
    total = int(res.participants.sum())
    assert int(res.committed.sum()) + res.dropped \
        + res.discarded_stale == total


def test_forced_flush_progress(fleet_problem):
    """Under-full K-buffers cannot deadlock the root: when the heap
    dries up the runtime force-flushes the lowest buffered aggregator
    (the timeout path), and conservation still holds."""
    cfg = _cfg("gradient")
    fs, res = _fleet(
        fleet_problem, cfg,
        FleetConfig(tiers=(TierConfig(aggregators=2, buffer_size=4),)),
        ConstantLatency(compute_s=1.0), rounds=5)
    assert res.forced_flushes > 0
    total = int(res.participants.sum())
    assert int(res.committed.sum()) == total
    assert np.all(np.isfinite(res.loss))


# ----------------------------------------------------------------------
# Fleet scale: depth-2 tree over a memmap store
# ----------------------------------------------------------------------

@pytest.mark.slow
def test_depth2_memmap_fleet_smoke():
    """The acceptance-scale smoke: a depth-2 tree over n = 1e5
    memmap-backed clients completes, conserves contributions, prices
    every hop, and the streamed workload's loss stays finite — without
    ever materializing an (n, d) array in RAM."""
    n, d, E = 100_000, 16, 8
    bounds = edge_partition(n, E)
    samp = EdgeSNice(bounds=tuple(int(b) for b in bounds), s=4)
    wl = StreamedGradientWorkload(sampler=samp, d=d,
                                  compressor=RandK(k=4), gamma=0.1,
                                  a=0.1, b=0.5, m_per_client=1)
    fleet = HierarchicalFleet(
        wl, FleetConfig(tiers=(TierConfig(aggregators=E, buffer_size=2),
                               TierConfig(aggregators=2)),
                        buffer_size=2, max_staleness=4),
        LognormalLatency(compute_s=1.0, sigma=0.6, client_sigma=0.6,
                         dropout=0.05, seed=3),
        store_backend="memmap")
    fs, res = fleet.run(jax.random.key(0), np.zeros(d, np.float32), 8)
    assert fs.store.backend == "memmap"
    assert fs.store.n == n
    total = int(res.participants.sum())
    assert total > 0
    assert int(res.committed.sum()) + res.dropped \
        + res.discarded_stale == total
    assert np.all(np.isfinite(res.loss))
    assert len(res.tier_bits) == 3 and np.all(res.tier_bits > 0)
    # pre-reduction: the root hop is cheaper than the client hop
    assert res.tier_bits[-1] < res.tier_bits[0]
    fs.store.close()
