"""DecodeServer correctness (the serving satellite of the async-cohort
PR): continuous-batching prefill must not corrupt live slots, reused
slots restart their ring position, empty prompts decode from BOS.

The isolation asserts are BITWISE on cache bytes within one server
instance.  Greedy token ids are deliberately NOT compared across
separately-run decodes here; the run-to-run divergence this suite
originally dodged turned out to be a live host-buffer race (jnp.array's
copy happens inside the async dispatch, so mutating _next_tok on the
next loop iteration could corrupt the in-flight step — fixed in the
paged-serving PR with a synchronous numpy snapshot; the paged parity
suite, tests/test_paged_engine.py, now does compare greedy tokens
across engines).  The byte asserts remain the strongest isolation
check and also pin that fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, get_smoke_config
from repro.serving.decode import DecodeServer, Request


def _model(arch="granite-3-2b"):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _slot_rows(caches, i):
    """Slot ``i``'s rows of every cache/state leaf (smoke models are
    unscanned: batch axis 0 everywhere)."""
    return [np.asarray(l)[i].copy()
            for l in jax.tree_util.tree_leaves(caches)]


@pytest.mark.parametrize("arch", ["granite-3-2b", "xlstm-350m"])
def test_prefill_isolated_from_live_decodes(arch):
    """Refilling a freed slot mid-decode leaves the in-flight slot's
    KV cache / recurrent state, ring position, and pending token
    byte-identical — pre-fix, every per-token prefill _step advanced
    ALL slots, appending stale garbage to live caches and positions."""
    cfg, model, params = _model(arch)
    srv = DecodeServer(model, params, batch_size=2, max_seq_len=32)
    live = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=10)
    srv.prefill(0, live)
    srv.step()
    srv.step()   # slot 0 is now mid-decode
    rows_before = _slot_rows(srv.state.caches, 0)
    pos_before = int(np.asarray(srv.state.position)[0])
    tok_before = int(srv._next_tok[0, 0])
    gen_before = list(live.generated)

    # the continuous-batching refill: prefill slot 1 while slot 0 lives
    srv.prefill(1, Request(uid=1, prompt=[7, 5, 9, 2], max_new_tokens=2))

    for before, after in zip(rows_before, _slot_rows(srv.state.caches, 0)):
        np.testing.assert_array_equal(before, after)
    assert int(np.asarray(srv.state.position)[0]) == pos_before
    assert int(srv._next_tok[0, 0]) == tok_before
    assert live.generated == gen_before
    # and the batch keeps decoding to completion
    while not (live.done and srv.slots[1].done):
        srv.step()
    assert len(live.generated) == 10


@pytest.mark.parametrize("arch", ["granite-3-2b", "xlstm-350m"])
def test_slot_reuse_resets_cache_position(arch):
    """A reused slot restarts its ring at 0 (pre-fix it inherited the
    previous occupant's offset, eventually wrapping mid-sequence) AND
    its cache rows return to their initial values — including the
    recurrent xLSTM states, which have no positions to mask — so its
    fresh prefill matches a never-used server's allclose."""
    cfg, model, params = _model(arch)
    max_seq = 12   # one request fits; several sequential ones would not
    srv = DecodeServer(model, params, batch_size=1, max_seq_len=max_seq)
    srv.run([Request(uid=0, prompt=[1, 2, 3], max_new_tokens=6)])
    assert int(np.asarray(srv.state.position)[0]) == 9   # 3 + 6

    srv.prefill(0, Request(uid=1, prompt=[4, 5], max_new_tokens=6))
    # position restarted at 0 and advanced by the new prompt only
    assert int(np.asarray(srv.state.position)[0]) == 2

    fresh = DecodeServer(model, params, batch_size=1, max_seq_len=max_seq)
    fresh.prefill(0, Request(uid=1, prompt=[4, 5], max_new_tokens=6))
    # the reused slot carries ONLY the new prompt: every cache/state
    # leaf matches a fresh server (allclose: separate jit compilations)
    for a, b in zip(_slot_rows(srv.state.caches, 0),
                    _slot_rows(fresh.state.caches, 0)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-5)

    # many sequential requests never grow the position past one sequence
    srv.run([Request(uid=i, prompt=[i % 5 + 1, 2], max_new_tokens=6)
             for i in range(2, 5)])
    assert int(np.asarray(srv.state.position)[0]) <= max_seq


def test_empty_prompt_decodes_from_bos():
    """An empty prompt is seeded with BOS=0 instead of dying on unbound
    logits (the pre-fix NameError)."""
    cfg, model, params = _model()
    srv = DecodeServer(model, params, batch_size=2, max_seq_len=16)
    req = Request(uid=0, prompt=[], max_new_tokens=3)
    srv.run([req])
    assert len(req.generated) == 3
    assert all(0 <= t < cfg.padded_vocab for t in req.generated)


def test_serve_step_update_mask_freezes_slots():
    """Model-level contract: a masked-out slot's cache bytes and
    position are bit-identical before and after a serve_step."""
    cfg, model, params = _model()
    B = 2
    state = model.init_decode_state(B, 16, position=0)._replace(
        position=jnp.asarray([3, 5], jnp.int32))
    # write a recognizable token into both slots first (all-on mask)
    tok = jnp.asarray([[4], [9]], jnp.int32)
    step = jax.jit(model.serve_step)
    _, state = step(params, tok, state, jnp.asarray([True, True]))
    frozen = jax.tree.map(lambda x: np.asarray(x).copy(), state.caches)
    _, state2 = step(params, tok, state, jnp.asarray([True, False]))
    assert int(state2.position[0]) == int(state.position[0]) + 1
    assert int(state2.position[1]) == int(state.position[1])

    for a, b in zip(_slot_rows(frozen, 1), _slot_rows(state2.caches, 1)):
        np.testing.assert_array_equal(a, b)
    # ...while slot 0 did change
    changed = any(not np.array_equal(a, b)
                  for a, b in zip(_slot_rows(frozen, 0),
                                  _slot_rows(state2.caches, 0)))
    assert changed


def test_serve_step_scalar_position_unchanged():
    """The legacy lockstep path (scalar position, no update mask) is
    untouched: position stays scalar and advances by one."""
    cfg, model, params = _model()
    state = model.init_decode_state(2, 16, position=0)
    tok = jnp.asarray([[4], [9]], jnp.int32)
    logits, state2 = jax.jit(model.serve_step)(params, tok, state)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.asarray(state2.position).ndim == 0
    assert int(state2.position) == 1
