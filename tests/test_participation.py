"""Assumption-8 property tests for the participation samplers, and the
sampling Lemma 1 identity checked by Monte-Carlo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.core.participation import FullParticipation, Independent, SNice


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 30), seed=st.integers(0, 10))
def test_snice_exact_count(n, seed):
    s = max(1, n // 3)
    samp = SNice(n=n, s=s)
    mask = samp.sample(jax.random.key(seed))
    assert int(jnp.sum(mask)) == s


@pytest.mark.parametrize("samp", [SNice(n=12, s=4), Independent(n=12, p=0.3),
                                  FullParticipation(n=12)])
def test_assumption8_probabilities(samp):
    trials = 4000
    keys = jax.random.split(jax.random.key(0), trials)
    masks = jax.vmap(samp.sample)(keys).astype(jnp.float32)
    p_hat = jnp.mean(masks, axis=0)
    np.testing.assert_allclose(np.asarray(p_hat), samp.p_a, atol=0.04)
    # pairwise
    pair = jnp.einsum("ti,tj->ij", masks, masks) / trials
    off = np.asarray(pair)[~np.eye(samp.n, dtype=bool)]
    np.testing.assert_allclose(off, samp.p_aa, atol=0.05)
    # eq. (5): p_aa <= p_a^2
    assert samp.p_aa <= samp.p_a ** 2 + 1e-12


def test_one_pa_definition():
    samp = SNice(n=10, s=5)
    expected = np.sqrt(1 - samp.p_aa / samp.p_a)
    assert np.isclose(samp.one_pa, expected)
    assert np.isclose(FullParticipation(n=7).one_pa, 0.0)


def test_sampling_lemma_variance():
    """Lemma 1 (the workhorse of every proof): for v_i = r_i + s_i/p_a on
    participation, Var(mean v) equals the three-term closed form."""
    n, d = 8, 5
    key = jax.random.key(0)
    r = jax.random.normal(key, (n, d))
    mu = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    sigma = 0.3
    samp = SNice(n=n, s=3)
    pa, paa = samp.p_a, samp.p_aa

    def one(k):
        k1, k2 = jax.random.split(k)
        s_i = mu + sigma * jax.random.normal(k1, (n, d))
        mask = samp.sample(k2)[:, None]
        v = r + jnp.where(mask, s_i / pa, 0.0)
        return jnp.mean(v, axis=0)

    trials = 20000
    outs = jax.vmap(one)(jax.random.split(key, trials))
    emp_var = float(jnp.mean(jnp.sum(
        (outs - jnp.mean(outs, axis=0)) ** 2, axis=-1)))
    # closed form (equality line of Lemma 1)
    term1 = (1 / (n ** 2 * pa)) * n * sigma ** 2 * d
    term2 = (pa - paa) / (n ** 2 * pa ** 2) * float(jnp.sum(mu ** 2))
    term3 = (paa - pa ** 2) / pa ** 2 * float(
        jnp.sum(jnp.mean(mu, axis=0) ** 2))
    closed = term1 + term2 + term3
    assert np.isclose(emp_var, closed, rtol=0.08), (emp_var, closed)
