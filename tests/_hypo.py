"""``hypothesis`` shim: the real library when installed, otherwise a
tiny deterministic fallback so the tier-1 suite collects and runs on a
clean checkout (the container does not ship hypothesis).

The fallback turns ``@given(x=st.floats(0, 1), ...)`` into a loop over a
fixed number of seeded pseudo-random draws — no shrinking, no database,
but the same property gets exercised across the same ranges, and runs
are reproducible.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 — mimic `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xDA5A)
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # Hide the strategy-filled parameters from pytest, which
            # would otherwise look for fixtures named after them.
            sig = inspect.signature(fn)
            kept = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper
        return deco

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
